#!/usr/bin/env bash
# Regenerate the committed bench snapshots (BENCH_wire.json /
# BENCH_step.json / BENCH_compress.json / BENCH_optim.json, schema
# comp-ams-bench-v1) from a real run.
#
# Run on an otherwise-idle box from the repo root:
#
#   scripts/bench_snapshots.sh            # full iteration counts
#   scripts/bench_snapshots.sh --fast     # CI-sized quick pass
#
# The bench harness overwrites each file in place, sets
# `measured: true`, and fills `benches` with one row per bench
# (name, iters, median_ns, mean_ns, p95_ns, per_sec). Commit the
# refreshed files so the perf trajectory is visible across PRs —
# bench_wire's "uplink ... before/after" rows are the zero-copy
# wire-path speedup.
set -euo pipefail
cd "$(dirname "$0")/.."
root=$(pwd)

for suite in wire step compress optim; do
    COMP_AMS_BENCH_JSON="$root/BENCH_${suite}.json" \
        cargo bench --bench "bench_${suite}" -- "$@"
done

echo "wrote $root/BENCH_{wire,step,compress,optim}.json"
