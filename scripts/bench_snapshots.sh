#!/usr/bin/env bash
# Regenerate the committed bench snapshots (BENCH_wire.json /
# BENCH_step.json, schema comp-ams-bench-v1) from a real run.
#
# Run on an otherwise-idle box from the repo root:
#
#   scripts/bench_snapshots.sh            # full iteration counts
#   scripts/bench_snapshots.sh --fast     # CI-sized quick pass
#
# The bench harness overwrites each file in place, sets
# `measured: true`, and fills `benches` with one row per bench
# (name, iters, median_ns, mean_ns, p95_ns, per_sec). Commit the
# refreshed files so the perf trajectory is visible across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."
root=$(pwd)

COMP_AMS_BENCH_JSON="$root/BENCH_wire.json" \
    cargo bench --bench bench_wire -- "$@"
COMP_AMS_BENCH_JSON="$root/BENCH_step.json" \
    cargo bench --bench bench_step -- "$@"

echo "wrote $root/BENCH_wire.json and $root/BENCH_step.json"
