//! Sentiment workload (the paper's IMDB motivation): LSTM over sparse
//! padded token sequences, Top-k vs Block-Sign. On text, embedding
//! gradients are extremely sparse, so Top-k should converge faster at
//! equal (or lower) communication — the paper's §5.2 observation.
//!
//! Run: `make artifacts && cargo run --release --example sentiment`

use anyhow::Result;
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;

fn main() -> Result<()> {
    let rounds = 40;
    let mut results = Vec::new();
    for algo in ["comp-ams-topk:0.01", "comp-ams-blocksign:4096", "dist-ams"] {
        let mut cfg = TrainConfig::preset("imdb_lstm", algo);
        cfg.workers = 8;
        cfg.rounds = rounds;
        cfg.eval_every = 10;
        cfg.eval_batches = 4;
        cfg.log_every = 10;
        println!("== {algo} ==");
        results.push((algo, train(&cfg)?));
    }

    println!("\nsentiment LSTM after {rounds} rounds on 8 workers:");
    println!("{:<28} {:>10} {:>8} {:>12}", "method", "loss", "acc", "uplink MB");
    for (algo, run) in &results {
        println!(
            "{:<28} {:>10.4} {:>8.4} {:>12.2}",
            algo,
            run.final_train_loss(5),
            run.final_eval.accuracy,
            run.uplink_bits() as f64 / 8e6
        );
    }
    Ok(())
}
