//! End-to-end validation driver (EXPERIMENTS.md §E2E): pretrain the
//! byte-level transformer LM (`lm_small`, ~3.3M params, Pallas tiled
//! matmuls in its MLP blocks) with COMP-AMS on 4 workers over the
//! procedural corpus, logging the loss curve to `results/lm_pretrain.csv`.
//!
//! Uniform-random bytes would give ln(256) ≈ 5.55 nats; the corpus's
//! structure lets the LM reach well under that within a few hundred
//! rounds, proving all three layers compose on a real training loop.
//!
//! Run: `make artifacts && cargo run --release --example lm_pretrain
//!       [-- --rounds 300 --workers 4 --algo comp-ams-topk:0.01]`

use anyhow::Result;
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;
use comp_ams::util::cli::Args;
use comp_ams::util::csv::CsvWriter;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let rounds = args.u64_or("rounds", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let algo = args.str_or("algo", "comp-ams-topk:0.01");

    let mut cfg = TrainConfig::preset("lm_small", &algo);
    cfg.workers = workers;
    cfg.rounds = rounds;
    cfg.lr = args.f32_or("lr", 3e-4)?;
    cfg.eval_every = (rounds / 10).max(1);
    cfg.eval_batches = 4;
    cfg.log_every = 10;
    // Server-update backend: pure Rust by default. The Pallas fused
    // artifact is the right backend on a real TPU (bandwidth-bound, one
    // pass over HBM), but under interpret-mode-on-CPU its grid loop
    // costs ~24 s/call at P=3.25M vs ~1 ms for the Rust loop
    // (EXPERIMENTS.md §Perf, L1). `--fused true` opts in.
    cfg.fused_update = args.bool_or("fused", false)?;

    eprintln!(
        "pretraining lm_small ({} workers, {} rounds, {}) — uniform baseline 5.545 nats",
        workers, rounds, algo
    );
    let run = train(&cfg)?;

    let mut w = CsvWriter::create(
        "results/lm_pretrain.csv",
        &["round", "train_loss", "test_loss", "token_acc", "uplink_bits"],
    )?;
    for m in &run.metrics {
        let (tl, ta) = m
            .eval
            .map(|e| (format!("{:.4}", e.loss), format!("{:.4}", e.accuracy)))
            .unwrap_or_default();
        w.row(&[
            m.round.to_string(),
            format!("{:.4}", m.train_loss),
            tl,
            ta,
            m.uplink_bits.to_string(),
        ])?;
    }
    w.flush()?;

    let first = run.metrics.first().unwrap().train_loss;
    let last = run.final_train_loss(10);
    println!("\nloss {first:.3} -> {last:.3} nats (uniform 5.545)");
    println!(
        "test loss {:.3}, token accuracy {:.3}",
        run.final_eval.loss, run.final_eval.accuracy
    );
    println!(
        "uplink {:.1} MB over {} rounds | wall {:.1}s | curve -> results/lm_pretrain.csv",
        run.uplink_bits() as f64 / 8e6,
        rounds,
        run.total_wall_ms / 1e3
    );
    Ok(())
}
