//! Linear-speedup demo (Corollary 2 / Figure 3): rounds-to-target vs.
//! number of workers with lr = η₀·√n, on the analytic logistic substrate
//! so a 5-point sweep finishes in seconds.
//!
//! Run: `cargo run --release --example speedup`

use anyhow::Result;
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;

fn main() -> Result<()> {
    let target = 1.0f32;
    println!("COMP-AMS linear speedup: rounds to reach train loss {target}");
    println!("{:>8} {:>10} {:>16} {:>14}", "workers", "lr", "rounds_to_loss", "ideal (T1/n)");
    let mut base: Option<u64> = None;
    for n in [1usize, 2, 4, 8, 16] {
        let mut cfg = TrainConfig::preset("logistic", "comp-ams-blocksign:64");
        cfg.workers = n;
        cfg.lr = 0.02 * (n as f32).sqrt();
        cfg.rounds = 4000;
        cfg.eval_every = 0;
        cfg.threaded = n > 1; // exercise the threaded leader/worker path
        let run = train(&cfg)?;
        let hit = run.rounds_to_loss(target, 10);
        let ideal = base.map(|b| (b / n as u64).max(1));
        if n == 1 {
            base = hit;
        }
        println!(
            "{:>8} {:>10.4} {:>16} {:>14}",
            n,
            cfg.lr,
            hit.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
            ideal.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
        );
    }
    println!("\n(≈halving per doubling of n reproduces the paper's Figure 3.)");
    Ok(())
}
