//! Quickstart: train the MNIST-shaped CNN with COMP-AMS (Top-k 1%) on 8
//! workers via the full three-layer stack (Rust coordinator → PJRT →
//! AOT-compiled JAX model with the Pallas fused server update), and
//! compare the communication bill against full-precision Dist-AMS.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;

fn main() -> Result<()> {
    let rounds = 30;

    let mut cfg = TrainConfig::preset("mnist_cnn", "comp-ams-topk:0.01");
    cfg.workers = 8;
    cfg.rounds = rounds;
    cfg.eval_every = 10;
    cfg.eval_batches = 4;
    cfg.log_every = 5;
    cfg.fused_update = true; // L1 Pallas fused AMSGrad on the server

    println!("== COMP-AMS (top-k 1%, error feedback) ==");
    let compressed = train(&cfg)?;

    cfg.algo = "dist-ams".into();
    cfg.fused_update = false;
    println!("== Dist-AMS (full precision) ==");
    let dense = train(&cfg)?;

    println!("\nafter {rounds} rounds on 8 workers:");
    println!(
        "  comp-ams   loss {:.4}  acc {:.4}  uplink {:>8.2} MB",
        compressed.final_train_loss(5),
        compressed.final_eval.accuracy,
        compressed.uplink_bits() as f64 / 8e6
    );
    println!(
        "  dist-ams   loss {:.4}  acc {:.4}  uplink {:>8.2} MB",
        dense.final_train_loss(5),
        dense.final_eval.accuracy,
        dense.uplink_bits() as f64 / 8e6
    );
    println!(
        "  communication saving: {:.0}x",
        dense.uplink_bits() as f64 / compressed.uplink_bits() as f64
    );
    Ok(())
}
