"""AOT compiler: lower every model's grad/eval/update closures to HLO text.

Run once at build time (``make artifacts``); the Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` through PJRT and never touches Python.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Besides the HLO, this writes:
  - ``{model}.init.bin``  — the flat f32 initial parameter vector
    (little-endian), so Rust and Python start from bit-identical weights;
  - ``manifest.json``     — shapes/dtypes/paths for the Rust loader.
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ref import BETA1, BETA2, EPS
from .model import DEFAULT_BUILD, REGISTRY


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def build_model(spec, out_dir):
    t0 = time.time()
    theta0, unravel = spec.flat_init()
    p = int(theta0.shape[0])
    x_spec, y_spec, seed_spec = spec.example_args()
    theta_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    vec = theta_spec
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    files = {}
    grad_hlo = to_hlo_text(jax.jit(spec.grad_fn(unravel)).lower(
        theta_spec, x_spec, y_spec, seed_spec))
    files["grad"] = f"{spec.name}.grad.hlo.txt"
    _write(os.path.join(out_dir, files["grad"]), grad_hlo)

    eval_hlo = to_hlo_text(jax.jit(spec.eval_fn(unravel)).lower(
        theta_spec, x_spec, y_spec))
    files["eval"] = f"{spec.name}.eval.hlo.txt"
    _write(os.path.join(out_dir, files["eval"]), eval_hlo)

    ams_hlo = to_hlo_text(jax.jit(spec.amsgrad_fn()).lower(
        vec, vec, vec, vec, vec, lr_spec))
    files["amsgrad"] = f"{spec.name}.amsgrad.hlo.txt"
    _write(os.path.join(out_dir, files["amsgrad"]), ams_hlo)

    files["init"] = f"{spec.name}.init.bin"
    with open(os.path.join(out_dir, files["init"]), "wb") as f:
        f.write(bytes(memoryview(jnp.asarray(theta0))))

    entry = {
        "name": spec.name,
        "p": p,
        "batch": spec.batch,
        "x_shape": list(spec.x_shape),
        "x_dtype": spec.x_dtype,
        "y_shape": list(spec.y_shape),
        "classes": spec.classes,
        "token_level": spec.token_level,
        "files": files,
    }
    print(f"  {spec.name}: P={p} ({time.time()-t0:.1f}s)")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of models to build (default: DEFAULT_BUILD)")
    ap.add_argument("--large", action="store_true",
                    help="also build lm_large (compile-only config)")
    args = ap.parse_args()

    names = args.models or list(DEFAULT_BUILD)
    if args.large and "lm_large" not in names:
        names.append("lm_large")

    os.makedirs(args.out, exist_ok=True)
    print(f"AOT-lowering {len(names)} models -> {args.out}")
    entries = [build_model(REGISTRY[n], args.out) for n in names]

    manifest = {
        "version": 1,
        "optimizer": {"beta1": BETA1, "beta2": BETA2, "eps": EPS},
        "models": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
