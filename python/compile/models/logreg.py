"""Tiny logistic-regression model.

Not part of the paper's evaluation: this is the smoke-test workload the
Rust integration tests and micro-benches use, so that exercising the full
PJRT round-trip (grad, eval, fused AMSGrad update) takes milliseconds."""

import jax

from . import common as cm

NUM_CLASSES = 4
DIM = 64


def init(rng):
    return {"d": cm.dense_init(rng, DIM, NUM_CLASSES)}


def apply(params, x, *, train, seed):
    return cm.dense(params["d"], x)
