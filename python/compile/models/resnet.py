"""Mini pre-activation ResNet for the appendix Fig. 4 workload.

The paper uses ResNet-18 (11M params); at 1-CPU-core scale we keep the
structural ingredients that matter for the compression/optimizer study
(depth, skip connections, stage-wise widening, stride-2 downsampling) in a
3-stage residual net (16/32/64 channels, ~80k params). Normalization is a
stateless channel LayerNorm (no BatchNorm running stats: the AOT artifact
must be a pure function of (theta, batch))."""

import jax
import jax.numpy as jnp

from . import common as cm

NUM_CLASSES = 10
IMG = (32, 32, 3)
STAGES = (16, 32, 64)


def _block_init(rng, c_in, c_out):
    k = jax.random.split(rng, 3)
    p = {
        "ln1": cm.layernorm_init(c_in),
        "c1": cm.conv_init(k[0], 3, 3, c_in, c_out),
        "ln2": cm.layernorm_init(c_out),
        "c2": cm.conv_init(k[1], 3, 3, c_out, c_out),
    }
    if c_in != c_out:
        p["proj"] = cm.conv_init(k[2], 1, 1, c_in, c_out)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(cm.layernorm(p["ln1"], x))
    h = cm.conv2d(p["c1"], h, stride=stride)
    h = jax.nn.relu(cm.layernorm(p["ln2"], h))
    h = cm.conv2d(p["c2"], h)
    if "proj" in p:
        x = cm.conv2d(p["proj"], x, stride=stride)
    return x + h


def init(rng):
    k = jax.random.split(rng, 2 + len(STAGES))
    params = {"stem": cm.conv_init(k[0], 3, 3, 3, STAGES[0])}
    c_in = STAGES[0]
    for i, c_out in enumerate(STAGES):
        params[f"s{i}"] = _block_init(k[1 + i], c_in, c_out)
        c_in = c_out
    params["head"] = cm.dense_init(k[-1], STAGES[-1], NUM_CLASSES)
    return params


def apply(params, x, *, train, seed):
    h = cm.conv2d(params["stem"], x)
    for i in range(len(STAGES)):
        h = _block_apply(params[f"s{i}"], h, stride=1 if i == 0 else 2)
    h = jax.nn.relu(h)
    h = cm.avgpool_global(h)
    return cm.dense(params["head"], h)
