"""Shared layer primitives for the L2 JAX models.

Hand-rolled (no flax/haiku in the image): explicit param pytrees, glorot
init, conv/pool/layernorm/dense helpers. Every model exposes

    init(rng) -> params (pytree of f32 arrays)
    apply(params, x, *, train, seed) -> logits

and the registry in ``compile.model`` ravels the pytree into the flat
f32[P] vector the Rust coordinator owns.
"""

import jax
import jax.numpy as jnp


def glorot(rng, shape, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(rng, shape, dtype=jnp.float32)


def dense_init(rng, d_in, d_out):
    wk, _ = jax.random.split(rng)
    return {
        "w": glorot(wk, (d_in, d_out), d_in, d_out),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


def conv_init(rng, kh, kw, c_in, c_out):
    fan_in = kh * kw * c_in
    fan_out = kh * kw * c_out
    return {
        "w": glorot(rng, (kh, kw, c_in, c_out), fan_in, fan_out),
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def conv2d(p, x, stride=1, padding="SAME"):
    """x: f32[B,H,W,C]; kernel HWIO."""
    y = jax.lax.conv_general_dilated(
        x, p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def layernorm_init(dim):
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def dropout(x, rate, train, seed, salt):
    """Deterministic-at-eval dropout keyed off an i32 scalar seed input."""
    if not train:
        return x
    key = jax.random.fold_in(jax.random.PRNGKey(seed), salt)
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


def softmax_xent(logits, labels, num_classes):
    """Mean cross-entropy. logits f32[B,C] (or [B,L,C]); labels i32 same prefix."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    ll = jnp.sum(logp * onehot, axis=-1)
    return -jnp.mean(ll)


def correct_count(logits, labels):
    """Number of argmax-correct predictions (token-level for 3-D logits)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels).astype(jnp.int32))
