"""LeNet-5 for the CIFAR-10-shaped workload (paper §5.1)."""

import jax

from . import common as cm

NUM_CLASSES = 10
IMG = (32, 32, 3)


def init(rng):
    k = jax.random.split(rng, 5)
    return {
        "c1": cm.conv_init(k[0], 5, 5, 3, 6),
        "c2": cm.conv_init(k[1], 5, 5, 6, 16),
        "d1": cm.dense_init(k[2], 16 * 5 * 5, 120),
        "d2": cm.dense_init(k[3], 120, 84),
        "d3": cm.dense_init(k[4], 84, NUM_CLASSES),
    }


def apply(params, x, *, train, seed):
    h = jax.nn.relu(cm.conv2d(params["c1"], x, padding="VALID"))
    h = cm.maxpool2(h)
    h = jax.nn.relu(cm.conv2d(params["c2"], h, padding="VALID"))
    h = cm.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(cm.dense(params["d1"], h))
    h = jax.nn.relu(cm.dense(params["d2"], h))
    return cm.dense(params["d3"], h)
