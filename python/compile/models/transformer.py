"""Byte-level transformer LM for the end-to-end training driver.

Decoder-only pre-LN transformer. The MLP blocks route their matmuls
through the L1 Pallas tiled kernel (kernels.matmul) when ``use_pallas`` is
set, so the AOT grad artifact contains the hand-tiled schedule; attention
projections use jnp.einsum (XLA fuses those well and their shapes are
small at this scale).

Two configs: ``lm_small`` (d=256, L=4, ~3.3M params — the one the e2e
example trains) and ``lm_large`` (d=768, L=12, GPT-2-small class ~85M —
compile-only on this box; see DESIGN.md §4)."""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import common as cm
from ..kernels.matmul import matmul as pallas_matmul


@dataclass(frozen=True)
class LmConfig:
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    use_pallas: bool = True


SMALL = LmConfig()
LARGE = LmConfig(d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq_len=256,
                 use_pallas=False)


def init(rng, cfg: LmConfig = SMALL):
    keys = jax.random.split(rng, 2 + cfg.n_layers)
    params = {
        "embed": 0.02 * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)),
        "pos": 0.02 * jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)),
        "ln_f": cm.layernorm_init(cfg.d_model),
    }
    for i in range(cfg.n_layers):
        k = jax.random.split(keys[2 + i], 6)
        d, f = cfg.d_model, cfg.d_ff
        params[f"l{i}"] = {
            "ln1": cm.layernorm_init(d),
            "wqkv": cm.glorot(k[0], (d, 3 * d), d, 3 * d),
            "wo": cm.glorot(k[1], (d, d), d, d),
            "ln2": cm.layernorm_init(d),
            "w1": cm.glorot(k[2], (d, f), d, f),
            "b1": jnp.zeros((f,), jnp.float32),
            "w2": cm.glorot(k[3], (f, d), f, d),
            "b2": jnp.zeros((d,), jnp.float32),
        }
    return params


def _mm(a, w, use_pallas):
    """[.., K] @ [K, N], optionally through the Pallas tiled kernel."""
    if not use_pallas:
        return a @ w
    lead = a.shape[:-1]
    flat = a.reshape(-1, a.shape[-1])
    out = pallas_matmul(flat, w)
    return out.reshape(*lead, w.shape[-1])


def _attn(p, h, cfg):
    b, l, d = h.shape
    nh = cfg.n_heads
    hd = d // nh
    qkv = h @ p["wqkv"]                              # [B, L, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((l, l), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, d)
    return out @ p["wo"]


def _block(p, h, cfg):
    h = h + _attn(p, cm.layernorm(p["ln1"], h), cfg)
    x = cm.layernorm(p["ln2"], h)
    x = jax.nn.gelu(_mm(x, p["w1"], cfg.use_pallas) + p["b1"])
    x = _mm(x, p["w2"], cfg.use_pallas) + p["b2"]
    return h + x


def apply(params, x, *, train, seed, cfg: LmConfig = SMALL):
    """x: i32[B, L] byte ids -> logits f32[B, L, vocab]."""
    h = params["embed"][x] + params["pos"][None, : x.shape[1]]
    for i in range(cfg.n_layers):
        h = _block(params[f"l{i}"], h, cfg)
    h = cm.layernorm(params["ln_f"], h)
    return h @ params["embed"].T
