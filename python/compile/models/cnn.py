"""MNIST CNN (paper §5.1): two conv layers + two dense layers, ReLU,
max-pooling, dropout 0.5 after the pooled conv stack."""

import jax
import jax.numpy as jnp

from . import common as cm

NUM_CLASSES = 10
IMG = (28, 28, 1)


def init(rng):
    k = jax.random.split(rng, 4)
    return {
        "c1": cm.conv_init(k[0], 3, 3, 1, 8),
        "c2": cm.conv_init(k[1], 3, 3, 8, 16),
        "d1": cm.dense_init(k[2], 7 * 7 * 16, 64),
        "d2": cm.dense_init(k[3], 64, NUM_CLASSES),
    }


def apply(params, x, *, train, seed):
    h = jax.nn.relu(cm.conv2d(params["c1"], x))
    h = cm.maxpool2(h)
    h = jax.nn.relu(cm.conv2d(params["c2"], h))
    h = cm.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = cm.dropout(h, 0.5, train, seed, salt=1)
    h = jax.nn.relu(cm.dense(params["d1"], h))
    return cm.dense(params["d2"], h)
