"""IMDB-shaped sentiment LSTM (paper §5.1): 32-d embedding, 64 LSTM cells,
two dense layers before the binary output.

The synthetic text substrate (Rust data::text) feeds padded i32[B,L] token
sequences over a 2000-word vocabulary; the classifier reads the final
hidden state of a lax.scan LSTM. Sequence length is fixed at AOT time
(64 here vs. the paper's 500 — 1-core budget; see DESIGN.md §4)."""

import jax
import jax.numpy as jnp

from . import common as cm

NUM_CLASSES = 2
VOCAB = 2000
EMBED = 32
HIDDEN = 64
SEQ_LEN = 64


def init(rng):
    k = jax.random.split(rng, 5)
    return {
        "embed": 0.1 * jax.random.normal(k[0], (VOCAB, EMBED), jnp.float32),
        # Fused LSTM weights: [x, h] -> 4*HIDDEN gates (i, f, g, o).
        "wx": cm.glorot(k[1], (EMBED, 4 * HIDDEN), EMBED, 4 * HIDDEN),
        "wh": cm.glorot(k[2], (HIDDEN, 4 * HIDDEN), HIDDEN, 4 * HIDDEN),
        "bias": jnp.zeros((4 * HIDDEN,), jnp.float32),
        "d1": cm.dense_init(k[3], HIDDEN, 16),
        "d2": cm.dense_init(k[4], 16, NUM_CLASSES),
    }


def _cell(params, carry, x_t):
    h, c = carry
    gates = x_t @ params["wx"] + h @ params["wh"] + params["bias"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), None


def apply(params, x, *, train, seed):
    """x: i32[B, L] token ids."""
    emb = params["embed"][x]                      # [B, L, E]
    b = emb.shape[0]
    h0 = jnp.zeros((b, HIDDEN), jnp.float32)
    c0 = jnp.zeros((b, HIDDEN), jnp.float32)
    (h, _), _ = jax.lax.scan(
        lambda carry, xt: _cell(params, carry, xt),
        (h0, c0),
        jnp.swapaxes(emb, 0, 1),                  # [L, B, E]
    )
    h = jax.nn.relu(cm.dense(params["d1"], h))
    return cm.dense(params["d2"], h)
