"""L1 Pallas kernel: fused AMSGrad server update.

The server update is the per-round numeric hot spot on the leader: one pass
over the flat parameter vector updating four state vectors. On GPU the
reference implementation is a fused elementwise CUDA kernel; here we tile
the flat vector into VMEM-sized blocks with a BlockSpec grid — each grid
step streams one (BLOCK,) slice of all five inputs HBM->VMEM, does the
elementwise math, and streams four outputs back. Arithmetic intensity is
O(1) flops/byte, so the kernel is bandwidth-bound: the roofline target is
"touch every element exactly once".

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact executes
on the Rust PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BETA1, BETA2, EPS

# 65536 f32 = 256 KiB per operand; 5 inputs + 4 outputs = 2.25 MiB of VMEM
# live per grid step, comfortably inside a ~16 MiB VMEM budget. Chosen
# large to amortize grid-step overhead: at P=3.25M this is 50 grid steps
# instead of 398 with the original 8192 block (§Perf L1 iteration 2 —
# 8x fewer interpret-mode loop iterations, same single-pass HBM traffic
# on real hardware).
BLOCK = 65536


def _amsgrad_kernel(lr_ref, theta_ref, m_ref, v_ref, vhat_ref, g_ref,
                    theta_out, m_out, v_out, vhat_out, *, beta1, beta2, eps):
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    vhat = jnp.maximum(vhat_ref[...], v)
    m_out[...] = m
    v_out[...] = v
    vhat_out[...] = vhat
    theta_out[...] = theta_ref[...] - lr_ref[0] * m * jax.lax.rsqrt(vhat + eps)


def amsgrad_update(theta, m, v, vhat, g, lr,
                   beta1=BETA1, beta2=BETA2, eps=EPS, block=BLOCK):
    """Fused AMSGrad step over flat f32[P] state vectors.

    P need not be a multiple of `block`: inputs are zero-padded, the kernel
    runs on the padded length, and outputs are sliced back. Padding lanes
    are exact fixed points of the update when g=0, m=0, v=0, vhat=0 (the
    padded theta would get -lr*0*rsqrt(eps) = 0 update), so no garbage
    leaks into real lanes.
    """
    p = theta.shape[0]
    pad = (-p) % block
    if pad:
        z = jnp.zeros((pad,), theta.dtype)
        theta, m, v, vhat, g = (jnp.concatenate([a, z]) for a in (theta, m, v, vhat, g))
    n_blocks = theta.shape[0] // block

    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    lr_spec = pl.BlockSpec((1,), lambda i: (0,))
    kernel = functools.partial(_amsgrad_kernel, beta1=beta1, beta2=beta2, eps=eps)
    out_shape = [jax.ShapeDtypeStruct(theta.shape, theta.dtype)] * 4
    lr_arr = jnp.reshape(lr.astype(jnp.float32) if hasattr(lr, "astype")
                         else jnp.float32(lr), (1,))
    theta_n, m_n, v_n, vhat_n = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[lr_spec] + [vec_spec] * 5,
        out_specs=[vec_spec] * 4,
        out_shape=out_shape,
        interpret=True,
    )(lr_arr, theta, m, v, vhat, g)
    if pad:
        theta_n, m_n, v_n, vhat_n = (a[:p] for a in (theta_n, m_n, v_n, vhat_n))
    return theta_n, m_n, v_n, vhat_n
