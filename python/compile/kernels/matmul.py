"""L1 Pallas kernel: MXU-shaped tiled matmul with a custom VJP.

The transformer LM's dense layers route through this kernel so that the
paper's compute hot spot (the model fwd/bwd) exercises a hand-tiled
matmul. Tiling follows the TPU MXU shape: (bm, bn) output tiles with a
bk-deep reduction, fp32 accumulation carried in the output VMEM block
across the innermost grid axis (the Pallas idiom for a systolic-array
matmul — the analogue of the CUDA threadblock + WMMA schedule a GPU paper
would use).

jax.grad does not differentiate through pallas_call, so `matmul` carries a
custom_vjp whose backward pass re-uses the same kernel:
dx = dy @ w.T, dw = x.T @ dy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128x128 matches the MXU systolic array; the reduction
# depth 128 keeps x/w/acc tiles at 64 KiB each in VMEM.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def _pad2(a, mult0, mult1):
    p0 = (-a.shape[0]) % mult0
    p1 = (-a.shape[1]) % mult1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def _matmul_raw(x, w, bm, bn, bk):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, bm=BM, bn=BN, bk=BK):
    """f32[M,K] @ f32[K,N] -> f32[M,N] through the tiled Pallas kernel."""
    return _matmul_raw(x, w, bm, bn, bk)


def _matmul_fwd(x, w, bm, bn, bk):
    return _matmul_raw(x, w, bm, bn, bk), (x, w)


def _matmul_bwd(bm, bn, bk, res, dy):
    x, w = res
    dx = _matmul_raw(dy, w.T, bm, bn, bk)
    dw = _matmul_raw(x.T, dy, bm, bn, bk)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
