"""L1 Pallas kernel: uniform-block Block-Sign encoder (paper Definition 2).

Dense form of the Block-Sign compressor: each block of the flat gradient is
replaced by sign(x_B) * mean(|x_B|). The wire codec (1 bit/coordinate +
one f32 scale per block) lives in the Rust coordinator; this kernel is the
decode-side dense reconstruction, shipped as an AOT artifact so the leader
can offload decompression of very large models to PJRT, and benchmarked
against the pure-Rust codec in `bench_compress`.

One grid step per block: the block is streamed to VMEM, reduced (L1 mean),
and rewritten as +/-scale.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _blocksign_kernel(x_ref, o_ref):
    x = x_ref[...]
    scale = jnp.mean(jnp.abs(x))
    o_ref[...] = jnp.where(x >= 0, scale, -scale)


def blocksign(x, block=BLOCK):
    """f32[P] -> f32[P] block-sign dense reconstruction, P % block == 0."""
    p = x.shape[0]
    assert p % block == 0, (p, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _blocksign_kernel,
        grid=(p // block,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(x)
