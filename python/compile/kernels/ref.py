"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes and dtypes (hypothesis) and asserts the Pallas kernels
(interpret=True) match these to float32 tolerance. The AOT artifacts embed
the Pallas versions; the oracles never ship.
"""

import jax.numpy as jnp

# AMSGrad hyper-parameters used across the whole repo (paper defaults).
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def amsgrad_update_ref(theta, m, v, vhat, g, lr, beta1=BETA1, beta2=BETA2, eps=EPS):
    """One fused AMSGrad step (Reddi et al. 2018, Algorithm 1 lines 5-8).

    theta/m/v/vhat/g: f32[P] flat vectors; lr: scalar.
    Returns (theta', m', v', vhat').
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    vhat_new = jnp.maximum(vhat, v_new)
    theta_new = theta - lr * m_new / (jnp.sqrt(vhat_new + eps))
    return theta_new, m_new, v_new, vhat_new


def matmul_ref(x, w):
    """Plain f32 matmul oracle for the tiled Pallas kernel."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def blocksign_ref(x, block_size):
    """Uniform-block Block-Sign compressor (paper Definition 2).

    x: f32[P] with P % block_size == 0. Each block becomes
    sign(x_B) * mean(|x_B|); sign(0) := +1 (matches the Rust codec).
    """
    xb = x.reshape(-1, block_size)
    scale = jnp.mean(jnp.abs(xb), axis=1, keepdims=True)
    sgn = jnp.where(xb >= 0, 1.0, -1.0)
    return (sgn * scale).reshape(-1)
