"""L2 model registry: flat-parameter training/eval closures per model.

Every model is exported to the Rust coordinator through three pure
functions of fixed shapes (AOT-lowered to HLO text by ``aot.py``):

    grad(theta f32[P], x, y, seed i32[])   -> (loss f32[], grad f32[P])
    eval(theta f32[P], x, y)               -> (loss f32[], correct i32[])
    amsgrad(theta,m,v,vhat f32[P], g f32[P], lr f32[]) -> 4 x f32[P]

The flat view makes the coordinator uniform over architectures: a model is
just (P, input spec). `jax.flatten_util.ravel_pytree` provides the
bijection; the same unravel closure is baked into the lowered HLO.
"""

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import amsgrad as amsgrad_kernel
from .models import cnn, lenet, lstm, logreg, resnet, transformer
from .models import common as cm

INIT_SEED = 42


@dataclass(frozen=True)
class ModelSpec:
    name: str
    module: Any
    batch: int
    x_shape: Tuple[int, ...]        # without batch dim
    x_dtype: str                    # "f32" | "i32"
    y_shape: Tuple[int, ...]        # without batch dim ( () or (L,) )
    classes: int
    token_level: bool = False       # LM: per-token labels/accuracy
    apply_kwargs: Dict[str, Any] = field(default_factory=dict)

    def init_params(self):
        rng = jax.random.PRNGKey(INIT_SEED)
        if self.apply_kwargs:
            return self.module.init(rng, **self.apply_kwargs)
        return self.module.init(rng)

    def flat_init(self):
        theta, unravel = ravel_pytree(self.init_params())
        return theta.astype(jnp.float32), unravel

    # ---- closures over the flat parameterization -------------------------

    def _logits(self, unravel, theta, x, train, seed):
        params = unravel(theta)
        return self.module.apply(params, x, train=train, seed=seed,
                                 **self.apply_kwargs)

    def grad_fn(self, unravel) -> Callable:
        def loss_fn(theta, x, y, seed):
            logits = self._logits(unravel, theta, x, train=True, seed=seed)
            loss = cm.softmax_xent(logits, y, self.classes)
            # Keep `seed` alive for models without dropout: XLA would
            # otherwise DCE the parameter out of the lowered HLO and the
            # Rust caller's 4-input calling convention would break.
            return loss + 0.0 * seed.astype(jnp.float32)

        def grad(theta, x, y, seed):
            loss, g = jax.value_and_grad(loss_fn)(theta, x, y, seed)
            return loss, g

        return grad

    def eval_fn(self, unravel) -> Callable:
        def evaluate(theta, x, y):
            logits = self._logits(unravel, theta, x, train=False, seed=0)
            loss = cm.softmax_xent(logits, y, self.classes)
            return loss, cm.correct_count(logits, y)

        return evaluate

    def amsgrad_fn(self) -> Callable:
        def update(theta, m, v, vhat, g, lr):
            return amsgrad_kernel.amsgrad_update(theta, m, v, vhat, g, lr)

        return update

    # ---- example abstract inputs for lowering ----------------------------

    def example_args(self):
        xd = jnp.float32 if self.x_dtype == "f32" else jnp.int32
        x = jax.ShapeDtypeStruct((self.batch, *self.x_shape), xd)
        y = jax.ShapeDtypeStruct((self.batch, *self.y_shape), jnp.int32)
        seed = jax.ShapeDtypeStruct((), jnp.int32)
        return x, y, seed


def _lm_spec(name, cfg, batch):
    return ModelSpec(
        name=name, module=transformer, batch=batch,
        x_shape=(cfg.seq_len,), x_dtype="i32",
        y_shape=(cfg.seq_len,), classes=cfg.vocab, token_level=True,
        apply_kwargs={"cfg": cfg},
    )


REGISTRY: Dict[str, ModelSpec] = {
    s.name: s
    for s in [
        ModelSpec("logreg", logreg, batch=16, x_shape=(logreg.DIM,),
                  x_dtype="f32", y_shape=(), classes=logreg.NUM_CLASSES),
        ModelSpec("mnist_cnn", cnn, batch=32, x_shape=cnn.IMG,
                  x_dtype="f32", y_shape=(), classes=cnn.NUM_CLASSES),
        ModelSpec("cifar_lenet", lenet, batch=32, x_shape=lenet.IMG,
                  x_dtype="f32", y_shape=(), classes=lenet.NUM_CLASSES),
        ModelSpec("cifar_resnet", resnet, batch=32, x_shape=resnet.IMG,
                  x_dtype="f32", y_shape=(), classes=resnet.NUM_CLASSES),
        ModelSpec("imdb_lstm", lstm, batch=16, x_shape=(lstm.SEQ_LEN,),
                  x_dtype="i32", y_shape=(), classes=lstm.NUM_CLASSES),
        _lm_spec("lm_small", transformer.SMALL, batch=8),
        _lm_spec("lm_large", transformer.LARGE, batch=4),
    ]
}

# Models lowered by default (lm_large is compile-only, opt-in: ~85M params
# is out of the 1-core training budget — see DESIGN.md §4).
DEFAULT_BUILD = ["logreg", "mnist_cnn", "cifar_lenet", "cifar_resnet",
                 "imdb_lstm", "lm_small"]
