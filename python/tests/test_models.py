"""L2 correctness: model shapes, grad finiteness, flat-parameter bijection,
and determinism of the closures that get AOT-lowered."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import REGISTRY

jax.config.update("jax_platform_name", "cpu")

FAST = ["logreg", "mnist_cnn", "cifar_lenet", "imdb_lstm"]
ALL = FAST + ["cifar_resnet", "lm_small"]


def _batch(spec, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if spec.x_dtype == "f32":
        x = jax.random.normal(k1, (spec.batch, *spec.x_shape), jnp.float32)
    else:
        x = jax.random.randint(k1, (spec.batch, *spec.x_shape), 0, spec.classes
                               if spec.token_level else 2000).astype(jnp.int32)
    y = jax.random.randint(k2, (spec.batch, *spec.y_shape), 0,
                           spec.classes).astype(jnp.int32)
    return x, y


@pytest.mark.parametrize("name", ALL)
def test_grad_shapes_and_finite(name):
    spec = REGISTRY[name]
    theta, unravel = spec.flat_init()
    x, y = _batch(spec)
    loss, g = spec.grad_fn(unravel)(theta, x, y, jnp.int32(0))
    assert g.shape == theta.shape
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.linalg.norm(g)) > 0.0


@pytest.mark.parametrize("name", FAST)
def test_eval_counts_bounded(name):
    spec = REGISTRY[name]
    theta, unravel = spec.flat_init()
    x, y = _batch(spec)
    loss, correct = spec.eval_fn(unravel)(theta, x, y)
    total = spec.batch * int(np.prod(spec.y_shape)) if spec.y_shape else spec.batch
    assert 0 <= int(correct) <= total
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", FAST)
def test_flat_roundtrip_bijection(name):
    spec = REGISTRY[name]
    theta, unravel = spec.flat_init()
    params = unravel(theta)
    theta2 = jax.flatten_util.ravel_pytree(params)[0]
    np.testing.assert_array_equal(np.asarray(theta), np.asarray(theta2))


def test_eval_deterministic_under_dropout_model():
    # mnist_cnn has dropout: eval path must not depend on any seed.
    spec = REGISTRY["mnist_cnn"]
    theta, unravel = spec.flat_init()
    x, y = _batch(spec)
    f = spec.eval_fn(unravel)
    l1, c1 = f(theta, x, y)
    l2, c2 = f(theta, x, y)
    assert float(l1) == float(l2) and int(c1) == int(c2)


def test_train_grad_depends_on_dropout_seed():
    spec = REGISTRY["mnist_cnn"]
    theta, unravel = spec.flat_init()
    x, y = _batch(spec)
    g = spec.grad_fn(unravel)
    _, g1 = g(theta, x, y, jnp.int32(1))
    _, g2 = g(theta, x, y, jnp.int32(2))
    assert not np.allclose(np.asarray(g1), np.asarray(g2))


def test_sgd_steps_reduce_loss_logreg():
    # Sanity: following the exported grad closure actually optimizes.
    spec = REGISTRY["logreg"]
    theta, unravel = spec.flat_init()
    grad = jax.jit(spec.grad_fn(unravel))
    x, y = _batch(spec, seed=3)
    losses = []
    for i in range(30):
        loss, g = grad(theta, x, y, jnp.int32(i))
        theta = theta - 0.5 * g
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7


def test_lm_logits_are_token_level():
    spec = REGISTRY["lm_small"]
    theta, unravel = spec.flat_init()
    x, y = _batch(spec)
    loss, correct = spec.eval_fn(unravel)(theta, x, y)
    # random init: token accuracy should be ~1/256, correct counts tokens
    total = spec.batch * spec.x_shape[0]
    assert 0 <= int(correct) < total // 4
