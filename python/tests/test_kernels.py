"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and the kernels' block-padding edge cases);
assert_allclose at float32 tolerance. This is the core correctness signal
for the compute that ends up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import amsgrad, blocksign, ref
from compile.kernels.matmul import matmul

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def _vecs(rng, p):
    ks = jax.random.split(jax.random.PRNGKey(rng), 5)
    theta, m, g = (jax.random.normal(k, (p,), jnp.float32) for k in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], (p,)))
    vhat = v + jnp.abs(jax.random.normal(ks[4], (p,)))
    return theta, m, v, vhat, g


class TestAmsGradKernel:
    @settings(**SETTINGS)
    @given(p=st.integers(1, 3 * 8192 + 7), seed=st.integers(0, 2**31 - 1),
           lr=st.floats(1e-5, 1.0))
    def test_matches_ref(self, p, seed, lr):
        theta, m, v, vhat, g = _vecs(seed, p)
        got = amsgrad.amsgrad_update(theta, m, v, vhat, g, jnp.float32(lr))
        want = ref.amsgrad_update_ref(theta, m, v, vhat, g, lr)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_vhat_monotone(self):
        theta, m, v, vhat, g = _vecs(0, 1000)
        _, _, _, vhat_n = amsgrad.amsgrad_update(theta, m, v, vhat, g, 1e-3)
        assert bool(jnp.all(vhat_n >= vhat))

    def test_zero_grad_moves_with_momentum_only(self):
        theta, m, v, vhat, _ = _vecs(1, 64)
        g = jnp.zeros((64,))
        theta_n, m_n, _, _ = amsgrad.amsgrad_update(theta, m, v, vhat, g, 1e-3)
        np.testing.assert_allclose(m_n, ref.BETA1 * m, rtol=1e-6)
        assert not np.allclose(theta_n, theta)  # momentum still moves

    def test_exact_block_multiple(self):
        p = 2 * amsgrad.BLOCK
        theta, m, v, vhat, g = _vecs(2, p)
        got = amsgrad.amsgrad_update(theta, m, v, vhat, g, 1e-2)
        want = ref.amsgrad_update_ref(theta, m, v, vhat, g, 1e-2)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestMatmulKernel:
    @settings(**SETTINGS)
    @given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (m, k), jnp.float32)
        w = jax.random.normal(k2, (k, n), jnp.float32)
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(m=st.integers(2, 64), k=st.integers(2, 64), n=st.integers(2, 64),
           seed=st.integers(0, 2**31 - 1))
    def test_vjp_matches_xla(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (m, k), jnp.float32)
        w = jax.random.normal(k2, (k, n), jnp.float32)
        f_pl = lambda x, w: jnp.sum(jnp.tanh(matmul(x, w)))
        f_rf = lambda x, w: jnp.sum(jnp.tanh(x @ w))
        gx, gw = jax.grad(f_pl, argnums=(0, 1))(x, w)
        gx2, gw2 = jax.grad(f_rf, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, gx2, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gw, gw2, rtol=1e-3, atol=1e-4)

    def test_multiple_of_tiles(self):
        x = jnp.ones((256, 256))
        w = jnp.eye(256)
        np.testing.assert_allclose(matmul(x, w), x, rtol=1e-6)


class TestBlockSignKernel:
    @settings(**SETTINGS)
    @given(nblocks=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, nblocks, seed):
        p = nblocks * blocksign.BLOCK
        x = jax.random.normal(jax.random.PRNGKey(seed), (p,), jnp.float32)
        np.testing.assert_allclose(
            blocksign.blocksign(x), ref.blocksign_ref(x, blocksign.BLOCK),
            rtol=1e-5, atol=1e-7)

    def test_sign_of_zero_is_positive(self):
        x = jnp.zeros((blocksign.BLOCK,))
        got = blocksign.blocksign(x)
        np.testing.assert_array_equal(got, x)  # scale 0 -> all zeros

    def test_q_deviate_bound(self):
        # ||C(x) - x|| <= q ||x|| with q^2 = 1 - 1/block (paper Remark 1
        # gives q^2 = 1 - min_i 1/d_i; uniform blocks => 1 - 1/block).
        x = jax.random.normal(jax.random.PRNGKey(7), (2 * blocksign.BLOCK,))
        c = blocksign.blocksign(x)
        q2 = 1.0 - 1.0 / blocksign.BLOCK
        assert float(jnp.sum((c - x) ** 2)) <= q2 * float(jnp.sum(x**2)) + 1e-4
