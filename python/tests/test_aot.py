"""Manifest + artifact invariants: the contract between aot.py and the
Rust loader (runtime::manifest). Skipped when artifacts were not built."""

import json
import os
import struct

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import REGISTRY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_schema():
    m = _manifest()
    assert m["version"] == 1
    opt = m["optimizer"]
    assert opt["beta1"] == 0.9 and opt["beta2"] == 0.999
    for e in m["models"]:
        for key in ["name", "p", "batch", "x_shape", "x_dtype", "y_shape",
                    "classes", "token_level", "files"]:
            assert key in e, (e["name"], key)
        for f in e["files"].values():
            assert os.path.exists(os.path.join(ART, f)), f


def test_init_bin_matches_registry():
    m = _manifest()
    for e in m["models"]:
        spec = REGISTRY[e["name"]]
        theta, _ = spec.flat_init()
        path = os.path.join(ART, e["files"]["init"])
        raw = np.fromfile(path, dtype="<f4")
        assert raw.shape[0] == e["p"] == theta.shape[0]
        np.testing.assert_array_equal(raw, np.asarray(theta))


def test_manifest_shapes_match_registry():
    m = _manifest()
    for e in m["models"]:
        spec = REGISTRY[e["name"]]
        assert e["batch"] == spec.batch
        assert tuple(e["x_shape"]) == spec.x_shape
        assert e["x_dtype"] == spec.x_dtype
        assert e["classes"] == spec.classes
        assert e["token_level"] == spec.token_level


def test_grad_hlo_keeps_all_four_parameters():
    # Regression: models without dropout don't *use* the seed input, and
    # XLA DCE'd the parameter out of the lowered HLO, breaking the Rust
    # caller's fixed (theta, x, y, seed) calling convention. model.py now
    # keeps the seed alive; every grad artifact must have 4 params.
    m = _manifest()
    for e in m["models"]:
        path = os.path.join(ART, e["files"]["grad"])
        with open(path) as f:
            text = f.read()
        assert "parameter(3)" in text, f"{e['name']}: seed param was DCE'd"


def test_hlo_text_parses_as_hlo_module():
    # Every emitted artifact must start with an HLO module header: the
    # text (not proto) format is the xla_extension-0.5.1-safe interchange.
    m = _manifest()
    for e in m["models"]:
        for kind in ["grad", "eval", "amsgrad"]:
            path = os.path.join(ART, e["files"][kind])
            with open(path) as f:
                head = f.read(200)
            assert head.startswith("HloModule"), (e["name"], kind, head[:40])
