//! Compressor throughput (Fig. 2's cost side): compress a gradient-like
//! vector at several dimensions, per compressor. Prints MB/s of input
//! consumed — the §Perf target is ≥100 MB/s Block-Sign, ≥50 MB/s Top-k
//! on one core.

use comp_ams::compress::{BlockSign, Compressor, RandomK, TopK};
use comp_ams::testing::bench::bench_main;
use comp_ams::util::rng::Rng;

fn main() {
    let mut b = bench_main("bench_compress");
    let mut rng = Rng::seed(7);
    for &d in &[10_000usize, 100_000, 1_000_000] {
        let x = rng.normal_vec(d);
        let bytes = d * 4;

        let mut topk = TopK::new(0.01);
        let r = b.bench(&format!("topk(0.01) d={d}"), || {
            std::hint::black_box(topk.compress(&x));
        });
        b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(bytes)));

        let mut bs = BlockSign::new(4096);
        let r = b.bench(&format!("blocksign(4096) d={d}"), || {
            std::hint::black_box(bs.compress(&x));
        });
        b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(bytes)));

        let mut rk = RandomK::new(0.01, 3);
        let r = b.bench(&format!("randomk(0.01) d={d}"), || {
            std::hint::black_box(rk.compress(&x));
        });
        b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(bytes)));
    }

    // Partial select vs a full-sort reference: the O(d) claim behind
    // topk's `select_nth_unstable_by` path, on the Fig-2 shape.
    {
        let d = 1_000_000;
        let k = 10_000; // ratio 0.01
        let x = rng.normal_vec(d);
        let mut topk = TopK::new(0.01);
        let r = b.bench("topk partial-select d=1000000", || {
            std::hint::black_box(topk.compress(&x));
        });
        b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(d * 4)));
        let r = b.bench("topk full-sort reference d=1000000", || {
            let mut order: Vec<u32> = (0..d as u32).collect();
            order.sort_unstable_by(|&a, &b| {
                x[b as usize]
                    .abs()
                    .total_cmp(&x[a as usize].abs())
                    .then(a.cmp(&b))
            });
            let mut idx = order[..k].to_vec();
            idx.sort_unstable();
            let val: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
            std::hint::black_box((idx, val));
        });
        b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(d * 4)));
    }

    // Error-feedback overhead on top of compression.
    let d = 1_000_000;
    let x = rng.normal_vec(d);
    let mut ef = comp_ams::compress::ErrorFeedback::new(d, true);
    let mut topk = TopK::new(0.01);
    let r = b.bench("ef+topk(0.01) d=1000000", || {
        std::hint::black_box(ef.compress(&x, &mut topk).unwrap());
    });
    b.note(&format!("  -> {:.1} MB/s", r.mb_per_sec(d * 4)));
}
