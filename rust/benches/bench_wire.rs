//! Wire codec micro-benches: encode/decode/add_into throughput for each
//! payload kind, the zero-copy uplink path raced against the old
//! copy-per-hop path, the server-side averaging hot loop, and the
//! sharded server's slice-by-range routing primitive.

use comp_ams::compress::{as_views, BlockSign, Compressor, Payload, PayloadView, TopK};
use comp_ams::coordinator::transport::{encode_envelope_into, Envelope, EnvelopeView};
use comp_ams::testing::bench::bench_main;
use comp_ams::util::rng::Rng;

fn main() {
    let mut b = bench_main("bench_wire");
    let mut rng = Rng::seed(11);
    let d = 500_000usize;
    let x = rng.normal_vec(d);

    let payloads: Vec<(&str, Payload)> = vec![
        ("dense", Payload::Dense(x.clone())),
        ("sparse(topk 1%)", TopK::new(0.01).compress(&x)),
        ("signs(4096)", BlockSign::new(4096).compress(&x)),
    ];

    for (name, p) in &payloads {
        let bytes = p.wire_bits() as usize / 8;
        let r = b.bench(&format!("encode {name}"), || {
            std::hint::black_box(p.encode());
        });
        b.note(&format!("  -> {:.1} MB/s on-wire", r.mb_per_sec(bytes)));

        let buf = p.encode();
        let r = b.bench(&format!("decode {name}"), || {
            std::hint::black_box(Payload::decode(&buf).unwrap());
        });
        b.note(&format!("  -> {:.1} MB/s on-wire", r.mb_per_sec(bytes)));

        // Borrowed decode: header validation only, no owned vectors.
        let r = b.bench(&format!("decode-view {name}"), || {
            std::hint::black_box(PayloadView::parse(&buf).unwrap());
        });
        b.note(&format!("  -> {:.1} MB/s on-wire", r.mb_per_sec(bytes)));

        let mut acc = vec![0.0f32; d];
        let r = b.bench(&format!("add_into {name}"), || {
            p.add_into(&mut acc).unwrap();
        });
        b.note(&format!("  -> {:.1} M coord/s", d as f64 / r.mean.as_secs_f64() / 1e6));
    }

    // Zero-copy uplink race (one envelope: 16-byte header + dense body).
    // "before" re-enacts the pre-zero-copy hop: encode the payload into
    // its own Vec, copy it into a fresh envelope buffer, decode back to
    // an owned Vec<f32>, then consume. "after" is the only path the
    // transports take now: serialize straight into a pooled scratch
    // buffer and consume a borrowed EnvelopeView over it.
    let dense = Payload::Dense(x.clone());
    let env_bytes = 16 + dense.wire_bits() as usize / 8;
    let mut acc = vec![0.0f32; d];
    let r = b.bench("uplink d=500k dense before (copy/hop + owned decode)", || {
        let body = dense.encode();
        let mut buf = Vec::with_capacity(16 + body.len());
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.extend_from_slice(&0.5f32.to_le_bytes());
        buf.extend_from_slice(&body);
        let env = Envelope::decode(&buf).unwrap();
        env.payload.add_into(&mut acc).unwrap();
    });
    b.note(&format!("  -> {:.1} MB/s on-wire", r.mb_per_sec(env_bytes)));

    let mut scratch: Vec<u8> = Vec::new();
    let r = b.bench("uplink d=500k dense after (pooled scratch + view)", || {
        scratch.clear();
        encode_envelope_into(3, 7, 0.5, &dense.view(), &mut scratch);
        let env = EnvelopeView::parse(&scratch).unwrap();
        env.payload.add_into(&mut acc).unwrap();
    });
    b.note(&format!("  -> {:.1} MB/s on-wire", r.mb_per_sec(env_bytes)));

    // n-worker averaging (the leader aggregation loop, n=16).
    let msgs: Vec<Payload> = (0..16).map(|_| TopK::new(0.01).compress(&x)).collect();
    let mut out = Vec::new();
    let r = b.bench("average 16x sparse(1%) d=500k", || {
        comp_ams::algo::average_payloads(&as_views(&msgs), d, &mut out).unwrap();
    });
    b.note(&format!("  -> {:.2} ms/round", r.mean.as_secs_f64() * 1e3));

    // Shard routing: slice each payload kind into 8 ranges (what the
    // sharded server does to every uplink, once per shard per round).
    let shards = 8usize;
    for (name, p) in &payloads {
        let r = b.bench(&format!("slice_range x{shards} {name}"), || {
            for s in 0..shards {
                let lo = s * d / shards;
                let hi = (s + 1) * d / shards;
                std::hint::black_box(p.slice_range(lo, hi).unwrap());
            }
        });
        b.note(&format!(
            "  -> {:.2} ms per n=1 round of S={shards} routing",
            r.mean.as_secs_f64() * 1e3
        ));
    }

    // Single-pass routing (the path the sharded server actually takes):
    // split into all S shards at once. Sorted sparse payloads walk their
    // k indices once instead of S times — the race above vs. below is
    // the O(S·k) → O(k) win on the sparse rows.
    let bounds: Vec<usize> = (0..=shards).map(|s| s * d / shards).collect();
    for (name, p) in &payloads {
        let r = b.bench(&format!("slice_into_shards x{shards} {name}"), || {
            std::hint::black_box(p.slice_into_shards(&bounds).unwrap());
        });
        b.note(&format!(
            "  -> {:.2} ms per n=1 round of S={shards} routing",
            r.mean.as_secs_f64() * 1e3
        ));
    }
}
