//! Server-optimizer backends: pure-Rust AMSGrad loop vs. the AOT-compiled
//! L1 Pallas fused-update artifact via PJRT, per model size. Requires
//! `make artifacts`.

use std::path::Path;
use std::rc::Rc;

use comp_ams::optim::{AmsGrad, ServerOpt};
use comp_ams::runtime::{ModelBundle, Runtime};
use comp_ams::testing::bench::bench_main;
use comp_ams::util::rng::Rng;

fn main() {
    let mut b = bench_main("bench_optim");
    let mut rng = Rng::seed(13);

    // Pure-Rust loop across sizes.
    for &p in &[52_138usize, 1_000_000] {
        let mut opt = AmsGrad::default_hp(p);
        let mut theta = rng.normal_vec(p);
        let g = rng.normal_vec(p);
        let r = b.bench(&format!("amsgrad rust P={p}"), || {
            opt.step(&mut theta, &g, 1e-3);
        });
        // 5 reads + 4 writes of f32 per element.
        b.note(&format!(
            "  -> {:.2} GB/s state traffic",
            9.0 * 4.0 * p as f64 / r.mean.as_secs_f64() / 1e9
        ));
    }

    // PJRT fused kernel (artifacts required).
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts` first)");
        return;
    }
    // lm_small (P=3.25M) is excluded: interpret-mode Pallas costs ~24 s
    // per update there (recorded in EXPERIMENTS.md §Perf) and would
    // dominate the bench wall-clock for no extra signal.
    let rt = Rc::new(Runtime::cpu().expect("pjrt cpu client"));
    for model in ["logreg", "mnist_cnn"] {
        let bundle = match ModelBundle::load(&rt, artifacts, model) {
            Ok(b) => b,
            Err(_) => continue,
        };
        let p = bundle.entry.p;
        let theta = rng.normal_vec(p);
        let m = vec![0.0f32; p];
        let v = vec![0.0f32; p];
        let vhat = vec![0.0f32; p];
        let g = rng.normal_vec(p);
        let r = b.bench(&format!("amsgrad pallas/pjrt {model} P={p}"), || {
            std::hint::black_box(
                bundle.amsgrad.run(&theta, &m, &v, &vhat, &g, 1e-3).unwrap(),
            );
        });
        b.note(&format!(
            "  -> {:.2} GB/s state traffic",
            9.0 * 4.0 * p as f64 / r.mean.as_secs_f64() / 1e9
        ));
    }
}
