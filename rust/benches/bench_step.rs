//! End-to-end round latency per protocol — the paper's per-iteration cost
//! table, on both the analytic substrate (coordinator-dominated) and the
//! PJRT smoke model (gradient-dominated). One bench per Fig. 1 method.

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::Trainer;
use comp_ams::testing::bench::bench_main;

fn main() {
    let mut b = bench_main("bench_step");

    let methods = [
        "dist-ams",
        "comp-ams-topk:0.01",
        "comp-ams-blocksign:4096",
        "qadam",
        "1bitadam:5",
        "dist-sgd",
    ];

    // Analytic substrate: isolates the coordinator (compress + EF +
    // aggregate + optimizer) because the quadratic gradient is trivial.
    for algo in methods {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 16;
        cfg.rounds = 1_000_000; // never reached; we drive steps manually
        cfg.eval_every = 0;
        let mut t = Trainer::new(&cfg).expect("trainer");
        let mut round = 0u64;
        b.bench(&format!("round quadratic n=16 {algo}"), || {
            t.step(round).unwrap();
            round += 1;
        });
    }

    // PJRT path (artifacts required): full grad + protocol round.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for algo in ["dist-ams", "comp-ams-topk:0.01"] {
            let mut cfg = TrainConfig::preset("logreg", algo);
            cfg.workers = 4;
            cfg.rounds = 1_000_000;
            cfg.eval_every = 0;
            let mut t = Trainer::new(&cfg).expect("trainer");
            let mut round = 0u64;
            b.bench(&format!("round logreg/pjrt n=4 {algo}"), || {
                t.step(round).unwrap();
                round += 1;
            });
        }
        for model in ["mnist_cnn", "cifar_lenet"] {
            let mut cfg = TrainConfig::preset(model, "comp-ams-topk:0.01");
            cfg.workers = 2;
            cfg.rounds = 1_000_000;
            cfg.eval_every = 0;
            if let Ok(mut t) = Trainer::new(&cfg) {
                let mut round = 0u64;
                b.bench(&format!("round {model}/pjrt n=2 comp-ams-topk"), || {
                    t.step(round).unwrap();
                    round += 1;
                });
            }
        }
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
