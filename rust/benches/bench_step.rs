//! End-to-end round latency per protocol — the paper's per-iteration cost
//! table, on both the analytic substrate (coordinator-dominated) and the
//! PJRT smoke model (gradient-dominated). One bench per Fig. 1 method,
//! plus a sequential-vs-threaded race of the full worker pipeline
//! (grad + EF + compress + encode) now that compression runs on worker
//! threads, a sharded-server race (the leader's dense update split
//! across S parallel θ shards), and a quorum race of the event-driven
//! runtime (K ∈ {n, n−1, n/2} partial participation).

use comp_ams::algo::{AlgoSpec, RoundCtx, ServerAlgo, ShardedServer};
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::cluster::WorkerPool;
use comp_ams::coordinator::runtime::ClusterRuntime;
use comp_ams::coordinator::trainer::Trainer;
use comp_ams::coordinator::transport::InProc;
use comp_ams::coordinator::CommLedger;
use comp_ams::grad::quadratic::QuadraticProblem;
use comp_ams::grad::GradSource;
use comp_ams::testing::bench::bench_main;

fn main() {
    let mut b = bench_main("bench_step");

    let methods = [
        "dist-ams",
        "comp-ams-topk:0.01",
        "comp-ams-blocksign:4096",
        "qadam",
        "1bitadam:5",
        "dist-sgd",
    ];

    // Analytic substrate: isolates the coordinator (compress + EF +
    // aggregate + optimizer) because the quadratic gradient is trivial.
    for algo in methods {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 16;
        cfg.rounds = 1_000_000; // never reached; we drive steps manually
        cfg.eval_every = 0;
        let mut t = Trainer::new(&cfg).expect("trainer");
        let mut round = 0u64;
        b.bench(&format!("round quadratic n=16 {algo}"), || {
            t.step(round).unwrap();
            round += 1;
        });
    }

    // Sequential vs. threaded full-pipeline race on a large synthetic
    // model: the per-worker stage (grad + EF + compress + encode) is the
    // dominant cost at this dimension, so the threaded backend's speedup
    // measures how well the split API parallelizes compression.
    let dim = 400_000;
    let n = 8;
    let spec = AlgoSpec::parse("comp-ams-topk:0.01").expect("spec");
    let problem = QuadraticProblem::new(11, dim, n, 10.0, 1.0, 0.5);
    let mut means = Vec::new();
    for threaded in [false, true] {
        let (workers, mut server) = spec.build(dim, n, 1_000_000);
        let mut pool = if threaded {
            let sources: Vec<Box<dyn GradSource + Send>> = (0..n)
                .map(|w| Box::new(problem.source_for(w, 11)) as _)
                .collect();
            WorkerPool::threaded(sources, workers).expect("pool")
        } else {
            let sources: Vec<Box<dyn GradSource>> = (0..n)
                .map(|w| Box::new(problem.source_for(w, 11)) as _)
                .collect();
            WorkerPool::sequential(sources, workers).expect("pool")
        };
        let mut theta = vec![0.2f32; dim];
        let mut round = 0u64;
        let label = if threaded { "threaded" } else { "sequential" };
        let r = b.bench(
            &format!("full-pipeline d={dim} n={n} comp-ams-topk:0.01 {label}"),
            || {
                let ctx = RoundCtx::sync(round, 0.01);
                let rounds = pool.run_round(&theta, &ctx).unwrap();
                let msgs: Vec<_> = rounds.into_iter().map(|w| w.payload).collect();
                server.step(&mut theta, &comp_ams::compress::as_views(&msgs), &ctx).unwrap();
                round += 1;
            },
        );
        means.push(r.mean.as_secs_f64());
    }
    b.note(&format!(
        "  -> threaded speedup over sequential: {:.2}x (n={n} workers)",
        means[0] / means[1]
    ));

    // Sharded-server race: with the worker pipeline off the leader, the
    // dense server update is the serial remainder. Split θ across S
    // shard servers (threaded backend for S > 1) and time *only* the
    // server step over a fixed set of top-k uplinks — trajectories are
    // bitwise identical across S, so this is pure systems speedup.
    let (mut sh_workers, _) = spec.build(dim, n, 1_000_000);
    let ctx0 = RoundCtx::sync(0, 0.01);
    let mut rng = comp_ams::util::rng::Rng::seed(17);
    let uplinks: Vec<_> = sh_workers
        .iter_mut()
        .map(|w| {
            let g = rng.normal_vec(dim);
            w.process(&g, &ctx0).expect("worker payload")
        })
        .collect();
    let mut shard_means = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        // S=1 is the honest baseline: the plain unsharded server, no
        // slice-routing on its path at all.
        let mut server: Box<dyn ServerAlgo> = if shards == 1 {
            spec.build(dim, n, 1_000_000).1
        } else {
            Box::new(
                ShardedServer::new(&spec, dim, 1_000_000, shards, true)
                    .expect("sharded server"),
            )
        };
        let mut theta = vec![0.2f32; dim];
        let mut round = 0u64;
        let label = if shards > 1 { "threaded" } else { "unsharded" };
        let r = b.bench(
            &format!("server-step d={dim} n={n} comp-ams-topk:0.01 S={shards} {label}"),
            || {
                let ctx = RoundCtx::sync(round, 0.01);
                server.step(&mut theta, &comp_ams::compress::as_views(&uplinks), &ctx).unwrap();
                round += 1;
            },
        );
        shard_means.push(r.mean.as_secs_f64());
    }
    b.note(&format!(
        "  -> sharded server speedup over S=1: S=2 {:.2}x, S=4 {:.2}x, S=8 {:.2}x",
        shard_means[0] / shard_means[1],
        shard_means[0] / shard_means[2],
        shard_means[0] / shard_means[3],
    ));

    // Quorum race: the event-driven runtime at K ∈ {n, n-1, n/2} on the
    // threaded pool. K = n is the lockstep-equivalent baseline; smaller
    // quorums step on the first K arrivals and absorb the stragglers as
    // stale gradients next round, so the mean round latency tracks the
    // K-th fastest worker instead of the slowest.
    let mut quorum_means = Vec::new();
    for quorum in [n, n - 1, n / 2] {
        let (workers, mut server) = spec.build(dim, n, 1_000_000);
        let sources: Vec<Box<dyn GradSource + Send>> = (0..n)
            .map(|w| Box::new(problem.source_for(w, 11)) as _)
            .collect();
        let pool = WorkerPool::threaded(sources, workers).expect("pool");
        let mut rt = ClusterRuntime::new(Box::new(InProc::new(pool)), quorum, 2)
            .expect("runtime");
        let mut ledger = CommLedger::new();
        let mut theta = vec![0.2f32; dim];
        let mut round = 0u64;
        let r = b.bench(
            &format!("event-round d={dim} n={n} comp-ams-topk:0.01 K={quorum}"),
            || {
                rt.run_round(&mut theta, server.as_mut(), round, 0.01, &mut ledger)
                    .unwrap();
                round += 1;
            },
        );
        quorum_means.push(r.mean.as_secs_f64());
    }
    b.note(&format!(
        "  -> quorum speedup over K={n}: K={} {:.2}x, K={} {:.2}x",
        n - 1,
        quorum_means[0] / quorum_means[1],
        n / 2,
        quorum_means[0] / quorum_means[2],
    ));

    // Sim event-queue overhead: the seeded network simulator re-times
    // every uplink through a barrier-collect queue on a virtual clock
    // (no real sleeps), so the only cost is stamping + sorting the
    // batch. Race the bare transport against the ideal wrapper (pure
    // queue overhead) and the lossy-wan profile (adds the seeded delay
    // draws and retransmit bookkeeping) on an otherwise identical round.
    let mut sim_means = Vec::new();
    for (transport, profile) in
        [("inproc", "ideal"), ("sim:inproc", "ideal"), ("sim:inproc", "lossy-wan")]
    {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.01");
        cfg.workers = 16;
        cfg.rounds = 1_000_000;
        cfg.eval_every = 0;
        cfg.transport = transport.into();
        cfg.sim_profile = profile.into();
        cfg.sim_seed = 7;
        let mut t = Trainer::new(&cfg).expect("trainer");
        let mut round = 0u64;
        let label = if transport == "inproc" {
            "bare".to_string()
        } else {
            format!("sim:{profile}")
        };
        let r = b.bench(&format!("round quadratic n=16 comp-ams-topk:0.01 {label}"), || {
            t.step(round).unwrap();
            round += 1;
        });
        sim_means.push(r.mean.as_secs_f64());
    }
    b.note(&format!(
        "  -> sim event-queue overhead vs bare inproc: ideal {:+.1}%, lossy-wan {:+.1}%",
        (sim_means[1] / sim_means[0] - 1.0) * 100.0,
        (sim_means[2] / sim_means[0] - 1.0) * 100.0,
    ));

    // Topology race: the flat star's root consumes all n uplinks per
    // round; at degree 4 it consumes n/4 forwarded group aggregates
    // instead (the group rounds run synchronously inside each dispatch,
    // so total work is conserved — this measures the tree layer's
    // coordination overhead; the bit savings live in the ledger's
    // by-level split, see tests/tree.rs).
    let mut topo_means = Vec::new();
    for topology in ["flat", "tree:4", "tree:4:topk:0.05"] {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.01");
        cfg.workers = 16;
        cfg.rounds = 1_000_000;
        cfg.eval_every = 0;
        cfg.topology = topology.into();
        let mut t = Trainer::new(&cfg).expect("trainer");
        let mut round = 0u64;
        let r = b.bench(
            &format!("round quadratic n=16 comp-ams-topk:0.01 topo={topology}"),
            || {
                t.step(round).unwrap();
                round += 1;
            },
        );
        topo_means.push(r.mean.as_secs_f64());
    }
    b.note(&format!(
        "  -> tree overhead vs flat: tree:4 {:+.1}%, tree:4:topk:0.05 {:+.1}%",
        (topo_means[1] / topo_means[0] - 1.0) * 100.0,
        (topo_means[2] / topo_means[0] - 1.0) * 100.0,
    ));

    // PJRT path (artifacts required): full grad + protocol round.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for algo in ["dist-ams", "comp-ams-topk:0.01"] {
            let mut cfg = TrainConfig::preset("logreg", algo);
            cfg.workers = 4;
            cfg.rounds = 1_000_000;
            cfg.eval_every = 0;
            let mut t = Trainer::new(&cfg).expect("trainer");
            let mut round = 0u64;
            b.bench(&format!("round logreg/pjrt n=4 {algo}"), || {
                t.step(round).unwrap();
                round += 1;
            });
        }
        for model in ["mnist_cnn", "cifar_lenet"] {
            let mut cfg = TrainConfig::preset(model, "comp-ams-topk:0.01");
            cfg.workers = 2;
            cfg.rounds = 1_000_000;
            cfg.eval_every = 0;
            if let Ok(mut t) = Trainer::new(&cfg) {
                let mut round = 0u64;
                b.bench(&format!("round {model}/pjrt n=2 comp-ams-topk"), || {
                    t.step(round).unwrap();
                    round += 1;
                });
            }
        }
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
