//! `comp-ams` — launcher for the COMP-AMS distributed training framework.
//!
//! ```text
//! comp-ams train --model mnist_cnn --algo comp-ams-topk:0.01 --workers 16 \
//!                --rounds 200 --lr 0.001 [--sharding dirichlet:0.5]
//! comp-ams train --config run.json
//! comp-ams train --model quadratic --transport tcp --spawn-workers
//! comp-ams worker --leader 127.0.0.1:7000
//! comp-ams serve --workers 4 --spawn-workers --transport tcp:0
//! comp-ams submit --control 127.0.0.1:7100 --model quadratic --algo qadam
//! comp-ams status --control 127.0.0.1:7100 [--json]
//! comp-ams exp fig1|fig2|fig3|fig4|table1|ablation [--fast]
//! comp-ams inspect [--artifacts artifacts]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use comp_ams::config::{LrSchedule, TrainConfig};
use comp_ams::coordinator::scheduler::{self, ServeOpts};
use comp_ams::coordinator::trainer::train;
use comp_ams::coordinator::transport::TransportSpec;
use comp_ams::exp::{self, ExpOpts};
use comp_ams::runtime::Manifest;
use comp_ams::util::cli::Args;
use comp_ams::util::json::Json;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("status") => cmd_status(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("drain") => cmd_drain(&args),
        Some("exp") => cmd_exp(&args),
        Some("inspect") => cmd_inspect(&args),
        Some(other) => bail!(
            "unknown command '{other}' (train | worker | serve | submit | \
             status | cancel | drain | exp | inspect)"
        ),
        None => {
            eprintln!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
comp-ams — COMP-AMS distributed adaptive training (ICLR 2022 reproduction)

commands:
  train    run one training job
           --model <name>      mnist_cnn|cifar_lenet|cifar_resnet|imdb_lstm|
                               lm_small|logreg|quadratic|logistic
           --algo <spec>       dist-ams|comp-ams-topk:R|comp-ams-blocksign:B|
                               qadam|1bitadam[:W]|dist-sgd
           --workers N --rounds N --lr F --seed N
           --sharding iid|dirichlet:A   --eval-every N --log-every N
           --fused true        use the Pallas fused AMSGrad artifact
           --server-shards S   split the server update across S parallel
                               θ shards (bitwise-identical trajectories)
           --server-threaded t run shard updates on a leader thread pool
           --transport T       inproc | loopback (byte-framed envelopes,
                               bitwise-identical trajectories) | tcp[:port]
                               (real worker processes over localhost
                               sockets; port 0/omitted = ephemeral) |
                               sim:inproc | sim:loopback (seeded network
                               simulator wrapping the inner transport)
           --sim-seed N        simulator RNG seed: same seed + profile =
                               bit-for-bit identical schedules and stats
           --sim-profile P     ideal | lan | wan | lossy-wan
           --byzantine SPECS   adversarial workers, comma-separated
                               wid:mode (0:scale:-3 | 1:signflip | 2:stale)
           --robust-agg M      server batch estimator: mean | median |
                               trimmed:<k> (byzantine-tolerant)
           --topology T        flat | tree:<degree>[:<group-compressor>]
                               (sub-leaders aggregate groups of <degree>
                               workers and forward one re-compressed
                               uplink to the root)
           --downlink-compress C  compress the tree root's θ broadcast as
                               a θ-delta payload (any compressor spec,
                               e.g. topk:0.1; tree topology only)
           --tree-kill G:R     fault injection: kill sub-leader G before
                               its round-R dispatch (tree topology only)
           --spawn-workers t   with tcp: spawn the worker daemons as child
                               processes (otherwise the leader waits for
                               `comp-ams worker` processes to connect)
           --quorum K          server steps once K on-time uplinks arrive
                               (0 = full participation, the default)
           --max-staleness S   apply straggler uplinks up to S rounds
                               late; drop (and count) beyond
           --decay-at r1,r2 --decay-factor F
           --config file.json  load a config (flags override)
  worker   run one worker daemon of a tcp cluster
           --leader HOST:PORT  the leader's listener address
           --exit-after N      fault injection: crash at round N before
                               uplinking (tests the straggler machinery)
  serve    run the resident leader daemon: one worker fleet, many jobs
           --workers N         fleet size (default 4)
           --spawn-workers t   spawn the fleet as child processes
           --transport tcp[:port]  fleet listener (default tcp, ephemeral;
                               the bound address is announced on stdout
                               as `fleet-addr HOST:PORT`)
           --control PORT      control listener port (default 0 =
                               ephemeral, announced as `control-addr`)
           SIGINT checkpoints the active job and releases the fleet.
  submit   queue a job on a serve daemon (accepts the train flags above,
           analytic models only)
           --control HOST:PORT the daemon's control address (required)
           --priority N        higher runs first; strictly higher
                               preempts the running job (default 0)
           --name S            label shown in status
  status   show a serve daemon's jobs   --control HOST:PORT [--json]
  cancel   cancel a job                 --control HOST:PORT --id N
  drain    finish queued jobs, then let the daemon exit
           --control HOST:PORT
  exp      regenerate a paper artifact: fig1|fig2|fig3|fig4|table1|ablation
           [--fast] [--seed N] [--artifacts DIR] [--results DIR] [--verbose]
  inspect  print the artifact manifest";

/// The `train`-style config flags, shared verbatim by `submit` (a job is
/// just a config shipped to the daemon instead of run in-process).
const CFG_FLAGS: &[&str] = &[
    "model", "algo", "workers", "rounds", "lr", "seed", "sharding",
    "eval-every", "eval-batches", "log-every", "fused", "threaded",
    "server-shards", "server-threaded", "transport", "spawn-workers",
    "quorum", "max-staleness", "sim-seed", "sim-profile", "byzantine",
    "robust-agg", "topology", "downlink-compress", "tree-kill",
    "artifacts", "config", "decay-at", "decay-factor",
    "rounds-per-epoch",
];

/// Build a [`TrainConfig`] from `--config` (if given) plus flag
/// overrides — the common front half of `train` and `submit`.
fn cfg_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            TrainConfig::from_json(&comp_ams::util::json::parse(&text)?)?
        }
        None => TrainConfig::preset(
            args.get("model").unwrap_or("quadratic"),
            args.get("algo").unwrap_or("comp-ams-topk:0.01"),
        ),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.into();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = a.into();
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.rounds = args.u64_or("rounds", cfg.rounds)?;
    cfg.lr = args.f32_or("lr", cfg.lr)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.sharding = args.str_or("sharding", &cfg.sharding);
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every)?;
    cfg.eval_batches = args.usize_or("eval-batches", cfg.eval_batches)?;
    cfg.log_every =
        args.u64_or("log-every", if cfg.log_every == 0 { 10 } else { cfg.log_every })?;
    cfg.fused_update = args.bool_or("fused", cfg.fused_update)?;
    cfg.threaded = args.bool_or("threaded", cfg.threaded)?;
    cfg.server_shards = args.usize_or("server-shards", cfg.server_shards)?;
    cfg.server_threaded = args.bool_or("server-threaded", cfg.server_threaded)?;
    cfg.transport = args.str_or("transport", &cfg.transport);
    cfg.spawn_workers = args.bool_or("spawn-workers", cfg.spawn_workers)?;
    cfg.quorum = args.usize_or("quorum", cfg.quorum)?;
    cfg.max_staleness = args.u64_or("max-staleness", cfg.max_staleness)?;
    cfg.sim_seed = args.u64_or("sim-seed", cfg.sim_seed)?;
    cfg.sim_profile = args.str_or("sim-profile", &cfg.sim_profile);
    cfg.byzantine = args.str_or("byzantine", &cfg.byzantine);
    cfg.robust_agg = args.str_or("robust-agg", &cfg.robust_agg);
    cfg.topology = args.str_or("topology", &cfg.topology);
    cfg.downlink_compress = args.str_or("downlink-compress", &cfg.downlink_compress);
    cfg.tree_kill = args.str_or("tree-kill", &cfg.tree_kill);
    cfg.rounds_per_epoch = args.u64_or("rounds-per-epoch", cfg.rounds_per_epoch)?;
    cfg.artifacts = PathBuf::from(args.str_or("artifacts", &cfg.artifacts.to_string_lossy()));
    if let Some(at) = args.get("decay-at") {
        let at: Vec<u64> = at
            .split(',')
            .map(|s| s.trim().parse().context("bad --decay-at"))
            .collect::<Result<_>>()?;
        cfg.schedule = LrSchedule::StepDecay {
            at,
            factor: args.f32_or("decay-factor", 10.0)?,
        };
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    args.ensure_known(CFG_FLAGS)?;
    let cfg = cfg_from_args(args)?;

    eprintln!(
        "training {} with {} on {} workers, {} rounds (seed {})",
        cfg.model, cfg.algo, cfg.workers, cfg.rounds, cfg.seed
    );
    let run = train(&cfg)?;
    eprintln!(
        "done: final train loss {:.4}, test loss {:.4}, test acc {:.4}",
        run.final_train_loss(10),
        run.final_eval.loss,
        run.final_eval.accuracy
    );
    eprintln!(
        "comm: uplink {:.2} MB, downlink {:.2} MB | wall {:.1}s | coord overhead {:.1}%",
        run.uplink_bits() as f64 / 8e6,
        run.metrics.last().map(|m| m.downlink_bits).unwrap_or(0) as f64 / 8e6,
        run.total_wall_ms / 1e3,
        run.coord_overhead * 100.0
    );
    if run.framing_bits > 0 {
        eprintln!(
            "framing: {:.3} MB transport overhead (envelope + frame headers, \
             billed outside the uplink ledger)",
            run.framing_bits as f64 / 8e6
        );
    }
    if run.stale_uplinks > 0 || run.dropped_uplinks > 0 {
        eprintln!(
            "quorum: {} stale uplinks applied, {} dropped past --max-staleness",
            run.stale_uplinks, run.dropped_uplinks
        );
    }
    if !run.sim_links.is_empty() {
        let delivered: u64 = run.sim_links.iter().map(|l| l.delivered).sum();
        let drops: u64 = run.sim_links.iter().map(|l| l.drops).sum();
        let reordered: u64 = run.sim_links.iter().map(|l| l.reordered).sum();
        let delay_ms: f64 =
            run.sim_links.iter().map(|l| l.delay_us).sum::<u64>() as f64 / 1e3;
        let down_ms: f64 = run
            .sim_links
            .iter()
            .map(|l| l.downlink_delay_us)
            .sum::<u64>() as f64
            / 1e3;
        eprintln!(
            "sim: {} uplinks delivered | {} drops (retransmitted) | {} reordered \
             | {:.1} virtual-ms uplink + {:.1} virtual-ms downlink delay",
            delivered, drops, reordered, delay_ms, down_ms
        );
    }
    if run.uplink_bits_by_level.len() > 1 {
        let fmt = |v: &[u64]| {
            v.iter()
                .map(|b| format!("{:.2}", *b as f64 / 8e6))
                .collect::<Vec<_>>()
                .join(" / ")
        };
        eprintln!(
            "tree: uplink MB by level [{}] | downlink MB by level [{}] \
             (level 0 = into the root)",
            fmt(&run.uplink_bits_by_level),
            fmt(&run.downlink_bits_by_level)
        );
    }
    if !run.server_ms_by_shard.is_empty() {
        let ms: Vec<String> =
            run.server_ms_by_shard.iter().map(|m| format!("{m:.0}")).collect();
        eprintln!(
            "server: {} shards | step ms/shard [{}]",
            run.server_ms_by_shard.len(),
            ms.join(", ")
        );
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.ensure_known(&["leader", "exit-after"])?;
    let leader = args
        .get("leader")
        .context("usage: comp-ams worker --leader HOST:PORT [--exit-after N]")?;
    let exit_after = match args.get("exit-after") {
        Some(v) => Some(v.parse::<u64>().context("bad --exit-after")?),
        None => None,
    };
    comp_ams::coordinator::worker::run_worker(leader, exit_after)
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.ensure_known(&["workers", "spawn-workers", "transport", "control"])?;
    let spec = TransportSpec::parse(args.str_or("transport", "tcp").as_str())?;
    let TransportSpec::Tcp { port } = spec else {
        bail!("serve drives a worker fleet over sockets: --transport tcp[:port] only")
    };
    let opts = ServeOpts {
        workers: args.usize_or("workers", 4)?,
        spawn_workers: args.bool_or("spawn-workers", false)?,
        fleet_port: port,
        control_port: match args.get("control") {
            Some(v) => v.parse::<u16>().context("bad --control port")?,
            None => 0,
        },
    };
    scheduler::serve(&opts)
}

/// `--control HOST:PORT`, shared by every client subcommand.
fn control_addr(args: &Args) -> Result<String> {
    Ok(args
        .get("control")
        .context("--control HOST:PORT (printed by `comp-ams serve` as `control-addr`)")?
        .to_string())
}

fn cmd_submit(args: &Args) -> Result<()> {
    let mut known = CFG_FLAGS.to_vec();
    known.extend(["control", "priority", "name"]);
    args.ensure_known(&known)?;
    let addr = control_addr(args)?;
    let cfg = cfg_from_args(args)?;
    let priority: i64 = match args.get("priority") {
        Some(v) => v.parse().context("bad --priority (integer)")?,
        None => 0,
    };
    let mut pairs = vec![
        ("cmd", Json::str("submit")),
        ("config", cfg.to_json()),
        ("priority", Json::num(priority as f64)),
    ];
    if let Some(name) = args.get("name") {
        pairs.push(("name", Json::str(name)));
    }
    let resp = scheduler::request(&addr, &Json::obj(pairs))?;
    let id = resp.req("id")?.as_usize()?;
    println!("{id}");
    eprintln!("submitted job {id}: {} {} (priority {priority})", cfg.model, cfg.algo);
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    args.ensure_known(&["control", "json"])?;
    let addr = control_addr(args)?;
    let resp = scheduler::request(&addr, &Json::obj(vec![("cmd", Json::str("status"))]))?;
    if args.bool_or("json", false)? {
        println!("{}", resp.to_string_compact());
        return Ok(());
    }
    let draining = resp.req("draining")?.as_bool()?;
    let fleet = resp.req("fleet_workers")?.as_usize()?;
    println!(
        "fleet: {fleet} worker(s){}",
        if draining { " [draining]" } else { "" }
    );
    println!(
        "{:>4}  {:<16} {:<10} {:>4}  {:<26} {:>11} {:>5}",
        "id", "name", "state", "prio", "model/algo", "rounds", "pre"
    );
    for job in resp.req("jobs")?.as_arr()? {
        let note = if let Some(e) = job.get("error") {
            format!("  error: {}", e.as_str()?)
        } else if let Some(r) = job.get("result") {
            format!(
                "  uplink {:.2} MB",
                r.req("uplink_bits")?.as_f64()? / 8e6
            )
        } else {
            String::new()
        };
        println!(
            "{:>4}  {:<16} {:<10} {:>4}  {:<26} {:>5}/{:<5} {:>5}{}",
            job.req("id")?.as_usize()?,
            job.req("name")?.as_str()?,
            job.req("state")?.as_str()?,
            job.req("priority")?.as_f64()?,
            format!(
                "{}/{}",
                job.req("model")?.as_str()?,
                job.req("algo")?.as_str()?
            ),
            job.req("rounds_done")?.as_usize()?,
            job.req("rounds_total")?.as_usize()?,
            job.req("preemptions")?.as_usize()?,
            note
        );
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    args.ensure_known(&["control", "id"])?;
    let addr = control_addr(args)?;
    let id = args.get("id").context("--id N")?.parse::<u64>().context("bad --id")?;
    scheduler::request(
        &addr,
        &Json::obj(vec![("cmd", Json::str("cancel")), ("id", Json::num(id as f64))]),
    )?;
    eprintln!("cancelled job {id}");
    Ok(())
}

fn cmd_drain(args: &Args) -> Result<()> {
    args.ensure_known(&["control"])?;
    let addr = control_addr(args)?;
    scheduler::request(&addr, &Json::obj(vec![("cmd", Json::str("drain"))]))?;
    eprintln!("draining: the daemon will exit once queued jobs finish");
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    args.ensure_known(&["fast", "seed", "artifacts", "results", "verbose"])?;
    let name = args
        .positional
        .get(1)
        .context("usage: comp-ams exp <fig1|fig2|fig3|fig4|table1|ablation>")?;
    let opts = ExpOpts {
        fast: args.bool_or("fast", false)?,
        artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
        results_dir: PathBuf::from(args.str_or("results", "results")),
        seed: args.u64_or("seed", 42)?,
        verbose: args.bool_or("verbose", false)?,
    };
    exp::run(name, &opts)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.ensure_known(&["artifacts"])?;
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir.join("manifest.json"))?;
    println!(
        "optimizer: beta1={} beta2={} eps={}",
        m.optimizer.beta1, m.optimizer.beta2, m.optimizer.eps
    );
    println!(
        "{:<14} {:>10} {:>6}  {:<16} {:<8}",
        "model", "params", "batch", "x_shape", "dtype"
    );
    for e in &m.models {
        println!(
            "{:<14} {:>10} {:>6}  {:<16} {:<8}",
            e.name,
            e.p,
            e.batch,
            format!("{:?}", e.x_shape),
            format!("{:?}", e.x_dtype),
        );
    }
    Ok(())
}
