//! `comp-ams` — launcher for the COMP-AMS distributed training framework.
//!
//! ```text
//! comp-ams train --model mnist_cnn --algo comp-ams-topk:0.01 --workers 16 \
//!                --rounds 200 --lr 0.001 [--sharding dirichlet:0.5]
//! comp-ams train --config run.json
//! comp-ams train --model quadratic --transport tcp --spawn-workers
//! comp-ams worker --leader 127.0.0.1:7000
//! comp-ams exp fig1|fig2|fig3|fig4|table1|ablation [--fast]
//! comp-ams inspect [--artifacts artifacts]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use comp_ams::config::{LrSchedule, TrainConfig};
use comp_ams::coordinator::trainer::train;
use comp_ams::exp::{self, ExpOpts};
use comp_ams::runtime::Manifest;
use comp_ams::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("worker") => cmd_worker(&args),
        Some("exp") => cmd_exp(&args),
        Some("inspect") => cmd_inspect(&args),
        Some(other) => bail!("unknown command '{other}' (train | worker | exp | inspect)"),
        None => {
            eprintln!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
comp-ams — COMP-AMS distributed adaptive training (ICLR 2022 reproduction)

commands:
  train    run one training job
           --model <name>      mnist_cnn|cifar_lenet|cifar_resnet|imdb_lstm|
                               lm_small|logreg|quadratic|logistic
           --algo <spec>       dist-ams|comp-ams-topk:R|comp-ams-blocksign:B|
                               qadam|1bitadam[:W]|dist-sgd
           --workers N --rounds N --lr F --seed N
           --sharding iid|dirichlet:A   --eval-every N --log-every N
           --fused true        use the Pallas fused AMSGrad artifact
           --server-shards S   split the server update across S parallel
                               θ shards (bitwise-identical trajectories)
           --server-threaded t run shard updates on a leader thread pool
           --transport T       inproc | loopback (byte-framed envelopes,
                               bitwise-identical trajectories) | tcp[:port]
                               (real worker processes over localhost
                               sockets; port 0/omitted = ephemeral)
           --spawn-workers t   with tcp: spawn the worker daemons as child
                               processes (otherwise the leader waits for
                               `comp-ams worker` processes to connect)
           --quorum K          server steps once K on-time uplinks arrive
                               (0 = full participation, the default)
           --max-staleness S   apply straggler uplinks up to S rounds
                               late; drop (and count) beyond
           --decay-at r1,r2 --decay-factor F
           --config file.json  load a config (flags override)
  worker   run one worker daemon of a tcp cluster
           --leader HOST:PORT  the leader's listener address
           --exit-after N      fault injection: crash at round N before
                               uplinking (tests the straggler machinery)
  exp      regenerate a paper artifact: fig1|fig2|fig3|fig4|table1|ablation
           [--fast] [--seed N] [--artifacts DIR] [--results DIR] [--verbose]
  inspect  print the artifact manifest";

fn cmd_train(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "model", "algo", "workers", "rounds", "lr", "seed", "sharding",
        "eval-every", "eval-batches", "log-every", "fused", "threaded",
        "server-shards", "server-threaded", "transport", "spawn-workers",
        "quorum", "max-staleness", "artifacts", "config", "decay-at",
        "decay-factor", "rounds-per-epoch",
    ])?;
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            TrainConfig::from_json(&comp_ams::util::json::parse(&text)?)?
        }
        None => TrainConfig::preset(
            args.get("model").unwrap_or("quadratic"),
            args.get("algo").unwrap_or("comp-ams-topk:0.01"),
        ),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.into();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = a.into();
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.rounds = args.u64_or("rounds", cfg.rounds)?;
    cfg.lr = args.f32_or("lr", cfg.lr)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.sharding = args.str_or("sharding", &cfg.sharding);
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every)?;
    cfg.eval_batches = args.usize_or("eval-batches", cfg.eval_batches)?;
    cfg.log_every =
        args.u64_or("log-every", if cfg.log_every == 0 { 10 } else { cfg.log_every })?;
    cfg.fused_update = args.bool_or("fused", cfg.fused_update)?;
    cfg.threaded = args.bool_or("threaded", cfg.threaded)?;
    cfg.server_shards = args.usize_or("server-shards", cfg.server_shards)?;
    cfg.server_threaded = args.bool_or("server-threaded", cfg.server_threaded)?;
    cfg.transport = args.str_or("transport", &cfg.transport);
    cfg.spawn_workers = args.bool_or("spawn-workers", cfg.spawn_workers)?;
    cfg.quorum = args.usize_or("quorum", cfg.quorum)?;
    cfg.max_staleness = args.u64_or("max-staleness", cfg.max_staleness)?;
    cfg.rounds_per_epoch = args.u64_or("rounds-per-epoch", cfg.rounds_per_epoch)?;
    cfg.artifacts = PathBuf::from(args.str_or("artifacts", &cfg.artifacts.to_string_lossy()));
    if let Some(at) = args.get("decay-at") {
        let at: Vec<u64> = at
            .split(',')
            .map(|s| s.trim().parse().context("bad --decay-at"))
            .collect::<Result<_>>()?;
        cfg.schedule = LrSchedule::StepDecay {
            at,
            factor: args.f32_or("decay-factor", 10.0)?,
        };
    }

    eprintln!(
        "training {} with {} on {} workers, {} rounds (seed {})",
        cfg.model, cfg.algo, cfg.workers, cfg.rounds, cfg.seed
    );
    let run = train(&cfg)?;
    eprintln!(
        "done: final train loss {:.4}, test loss {:.4}, test acc {:.4}",
        run.final_train_loss(10),
        run.final_eval.loss,
        run.final_eval.accuracy
    );
    eprintln!(
        "comm: uplink {:.2} MB, downlink {:.2} MB | wall {:.1}s | coord overhead {:.1}%",
        run.uplink_bits() as f64 / 8e6,
        run.metrics.last().map(|m| m.downlink_bits).unwrap_or(0) as f64 / 8e6,
        run.total_wall_ms / 1e3,
        run.coord_overhead * 100.0
    );
    if run.framing_bits > 0 {
        eprintln!(
            "framing: {:.3} MB transport overhead (envelope + frame headers, \
             billed outside the uplink ledger)",
            run.framing_bits as f64 / 8e6
        );
    }
    if run.stale_uplinks > 0 || run.dropped_uplinks > 0 {
        eprintln!(
            "quorum: {} stale uplinks applied, {} dropped past --max-staleness",
            run.stale_uplinks, run.dropped_uplinks
        );
    }
    if !run.server_ms_by_shard.is_empty() {
        let ms: Vec<String> =
            run.server_ms_by_shard.iter().map(|m| format!("{m:.0}")).collect();
        eprintln!(
            "server: {} shards | step ms/shard [{}]",
            run.server_ms_by_shard.len(),
            ms.join(", ")
        );
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.ensure_known(&["leader", "exit-after"])?;
    let leader = args
        .get("leader")
        .context("usage: comp-ams worker --leader HOST:PORT [--exit-after N]")?;
    let exit_after = match args.get("exit-after") {
        Some(v) => Some(v.parse::<u64>().context("bad --exit-after")?),
        None => None,
    };
    comp_ams::coordinator::worker::run_worker(leader, exit_after)
}

fn cmd_exp(args: &Args) -> Result<()> {
    args.ensure_known(&["fast", "seed", "artifacts", "results", "verbose"])?;
    let name = args
        .positional
        .get(1)
        .context("usage: comp-ams exp <fig1|fig2|fig3|fig4|table1|ablation>")?;
    let opts = ExpOpts {
        fast: args.bool_or("fast", false)?,
        artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
        results_dir: PathBuf::from(args.str_or("results", "results")),
        seed: args.u64_or("seed", 42)?,
        verbose: args.bool_or("verbose", false)?,
    };
    exp::run(name, &opts)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.ensure_known(&["artifacts"])?;
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir.join("manifest.json"))?;
    println!(
        "optimizer: beta1={} beta2={} eps={}",
        m.optimizer.beta1, m.optimizer.beta2, m.optimizer.eps
    );
    println!(
        "{:<14} {:>10} {:>6}  {:<16} {:<8}",
        "model", "params", "batch", "x_shape", "dtype"
    );
    for e in &m.models {
        println!(
            "{:<14} {:>10} {:>6}  {:<16} {:<8}",
            e.name,
            e.p,
            e.batch,
            format!("{:?}", e.x_shape),
            format!("{:?}", e.x_dtype),
        );
    }
    Ok(())
}
