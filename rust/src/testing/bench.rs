//! Criterion-style micro-bench harness (criterion is not in the offline
//! registry). Used by the `rust/benches/*.rs` targets (harness = false).
//!
//! Method: warm up, then run timed batches until `target_time` elapses;
//! report median / mean / p95 of per-iteration times plus derived
//! throughput. Deterministic enough for before/after comparisons in
//! EXPERIMENTS.md §Perf on an otherwise idle box.
//!
//! Set `COMP_AMS_BENCH_JSON=<path>` to additionally dump the suite's
//! results as a machine-readable JSON file when the bench exits
//! (schema `comp-ams-bench-v1`, written by [`Bencher::write_json`]) —
//! this is how the committed `BENCH_wire.json` / `BENCH_step.json`
//! snapshots at the repo root are produced:
//!
//! ```text
//! COMP_AMS_BENCH_JSON=BENCH_wire.json cargo bench --bench bench_wire
//! ```

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }

    /// Throughput given per-iteration payload bytes.
    pub fn mb_per_sec(&self, bytes: usize) -> f64 {
        bytes as f64 / self.mean.as_secs_f64() / 1e6
    }
}

pub struct Bencher {
    title: String,
    fast: bool,
    target: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::titled("bench")
    }

    pub fn titled(title: &str) -> Self {
        // `cargo bench -- --fast` style control via env var.
        let fast = std::env::var("COMP_AMS_BENCH_FAST").is_ok();
        Bencher {
            title: title.to_string(),
            fast,
            target: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(250) },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which must do one unit of work per call. A returned
    /// value should be wrapped in `std::hint::black_box` by the caller.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.target || samples.len() < 10 {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
            if samples.len() >= 1_000_000 {
                break;
            }
        }
        samples.sort_unstable();
        let iters = samples.len() as u64;
        let median = samples[samples.len() / 2];
        let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        self.results.push(BenchResult { name: name.to_string(), iters, median, mean, p95 });
        println!(
            "{:<44} {:>10} iters   median {:>10}   mean {:>10}   p95 {:>10}",
            name,
            iters,
            crate::util::timer::fmt_duration(median),
            crate::util::timer::fmt_duration(mean),
            crate::util::timer::fmt_duration(p95),
        );
        self.results.last().unwrap().clone()
    }

    /// Print a one-line throughput annotation for the last benchmark.
    pub fn note(&self, text: &str) {
        println!("{:<44} {}", "", text);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The suite's results in the `comp-ams-bench-v1` JSON schema: suite
    /// metadata plus one row per bench with nanosecond-resolution stats.
    pub fn results_json(&self) -> Json {
        let benches: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("iters", Json::num(r.iters as f64)),
                    ("median_ns", Json::num(r.median.as_nanos() as f64)),
                    ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
                    ("p95_ns", Json::num(r.p95.as_nanos() as f64)),
                    ("per_sec", Json::num(r.per_sec())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("comp-ams-bench-v1")),
            ("suite", Json::str(&self.title)),
            ("fast", Json::Bool(self.fast)),
            ("measured", Json::Bool(true)),
            ("benches", Json::Arr(benches)),
        ])
    }

    /// Dump [`Bencher::results_json`] to `path` (pretty-printed).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.results_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

impl Drop for Bencher {
    /// Honor `COMP_AMS_BENCH_JSON` when the bench binary finishes — a
    /// drop hook because `harness = false` benches are plain `main`s
    /// with no epilogue to call.
    fn drop(&mut self) {
        let Ok(path) = std::env::var("COMP_AMS_BENCH_JSON") else { return };
        if path.is_empty() || self.results.is_empty() {
            return;
        }
        match self.write_json(&path) {
            Ok(()) => println!("wrote {} bench results to {path}", self.results.len()),
            Err(e) => eprintln!("failed to write bench JSON {path}: {e}"),
        }
    }
}

/// Standard bench-main prologue: print header, honor --fast.
pub fn bench_main(title: &str) -> Bencher {
    for a in std::env::args() {
        if a == "--fast" {
            std::env::set_var("COMP_AMS_BENCH_FAST", "1");
        }
    }
    println!("=== {title} ===");
    Bencher::titled(title)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        std::env::set_var("COMP_AMS_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.median <= r.p95);
        assert!(r.per_sec() > 0.0);
    }

    #[test]
    fn json_dump_round_trips() {
        std::env::set_var("COMP_AMS_BENCH_FAST", "1");
        let mut b = Bencher::titled("suite-x");
        b.bench("unit", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let j = b.results_json();
        assert_eq!(j.req("schema").unwrap().as_str().unwrap(), "comp-ams-bench-v1");
        assert_eq!(j.req("suite").unwrap().as_str().unwrap(), "suite-x");
        assert!(j.req("measured").unwrap().as_bool().unwrap());
        let rows = j.req("benches").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "unit");
        assert!(rows[0].req("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        // The dump must parse back (it is a committed artifact).
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
    }
}
