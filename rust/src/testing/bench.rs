//! Criterion-style micro-bench harness (criterion is not in the offline
//! registry). Used by the `rust/benches/*.rs` targets (harness = false).
//!
//! Method: warm up, then run timed batches until `target_time` elapses;
//! report median / mean / p95 of per-iteration times plus derived
//! throughput. Deterministic enough for before/after comparisons in
//! EXPERIMENTS.md §Perf on an otherwise idle box.

use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }

    /// Throughput given per-iteration payload bytes.
    pub fn mb_per_sec(&self, bytes: usize) -> f64 {
        bytes as f64 / self.mean.as_secs_f64() / 1e6
    }
}

pub struct Bencher {
    target: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // `cargo bench -- --fast` style control via env var.
        let fast = std::env::var("COMP_AMS_BENCH_FAST").is_ok();
        Bencher {
            target: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(250) },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which must do one unit of work per call. A returned
    /// value should be wrapped in `std::hint::black_box` by the caller.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.target || samples.len() < 10 {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
            if samples.len() >= 1_000_000 {
                break;
            }
        }
        samples.sort_unstable();
        let iters = samples.len() as u64;
        let median = samples[samples.len() / 2];
        let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        self.results.push(BenchResult { name: name.to_string(), iters, median, mean, p95 });
        println!(
            "{:<44} {:>10} iters   median {:>10}   mean {:>10}   p95 {:>10}",
            name,
            iters,
            crate::util::timer::fmt_duration(median),
            crate::util::timer::fmt_duration(mean),
            crate::util::timer::fmt_duration(p95),
        );
        self.results.last().unwrap().clone()
    }

    /// Print a one-line throughput annotation for the last benchmark.
    pub fn note(&self, text: &str) {
        println!("{:<44} {}", "", text);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Standard bench-main prologue: print header, honor --fast.
pub fn bench_main(title: &str) -> Bencher {
    for a in std::env::args() {
        if a == "--fast" {
            std::env::set_var("COMP_AMS_BENCH_FAST", "1");
        }
    }
    println!("=== {title} ===");
    Bencher::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        std::env::set_var("COMP_AMS_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.median <= r.p95);
        assert!(r.per_sec() > 0.0);
    }
}
