//! Miniature property-based testing harness.
//!
//! A property is a closure over a [`Gen`] (seeded value generator). The
//! driver runs `cases` random cases; on failure it re-runs with the same
//! seed to confirm, then reports the seed so the case can be replayed
//! with [`check_seeded`]. Generators bias toward boundary sizes
//! (0/1/2, powers of two ± 1) the way real shrinkers find bugs.

use crate::util::rng::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// A size in [lo, hi], biased toward boundary values.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo + 1;
        if self.rng.next_f32() < 0.25 {
            // Boundary bias: lo, hi, and powers of two ±1 inside range.
            let candidates = [
                lo,
                hi,
                lo + 1.min(span - 1),
                (lo + span / 2).min(hi),
                (lo + 1).next_power_of_two().clamp(lo, hi),
                ((lo + 1).next_power_of_two() + 1).clamp(lo, hi),
            ];
            candidates[self.rng.gen_range(candidates.len())]
        } else {
            lo + self.rng.gen_range(span)
        }
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// A gradient-like vector: mixture of gaussian / heavy-tailed /
    /// sparse-with-zeros — shapes that stress compressors.
    pub fn grad_vec(&mut self, d: usize) -> Vec<f32> {
        let style = self.rng.gen_range(4);
        (0..d)
            .map(|_| match style {
                0 => self.rng.normal(),
                1 => self.rng.normal().powi(3), // heavy tail
                2 => {
                    if self.rng.next_f32() < 0.9 {
                        0.0
                    } else {
                        self.rng.normal() * 10.0
                    }
                }
                _ => self.rng.uniform(-1.0, 1.0),
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    // Base seed is fixed for CI determinism; override with COMP_AMS_PROP_SEED.
    let base = std::env::var("COMP_AMS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut gen = Gen { rng: Rng::seed(seed), seed };
            prop(&mut gen);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n\
                 replay: testing::prop::check_seeded({seed:#x}, ..)"
            );
        }
    }
}

/// Replay a single case by seed.
pub fn check_seeded<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut gen = Gen { rng: Rng::seed(seed), seed };
    prop(&mut gen);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |g| {
            let n = g.size(0, 100);
            assert!(n <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsifiable' failed")]
    fn failing_property_reports_seed() {
        check("falsifiable", 200, |g| {
            let n = g.size(0, 10);
            assert!(n != 0, "found the zero");
        });
    }

    #[test]
    fn grad_vec_has_requested_len() {
        check("grad_vec_len", 30, |g| {
            let d = g.size(1, 2000);
            assert_eq!(g.grad_vec(d).len(), d);
        });
    }

    #[test]
    fn size_hits_boundaries() {
        let mut seen_lo = false;
        let mut seen_hi = false;
        check_seeded(42, |g| {
            for _ in 0..500 {
                match g.size(3, 17) {
                    3 => seen_lo = true,
                    17 => seen_hi = true,
                    v => assert!((3..=17).contains(&v)),
                }
            }
        });
        assert!(seen_lo && seen_hi);
    }
}
