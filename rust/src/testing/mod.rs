//! Test & bench substrates (no proptest/criterion in the offline
//! registry — DESIGN.md §2).

pub mod bench;
pub mod prop;
