//! Synthetic image classification (MNIST-like and CIFAR-like).
//!
//! Each class gets a smooth random template built by bilinearly upsampling
//! a coarse random grid (per-channel), normalized to zero mean / unit
//! variance. A sample is its class template under a random ±2px shift
//! plus Gaussian pixel noise. SNR (template/noise ratio) controls task
//! difficulty: MNIST-like is easy (high SNR), CIFAR-like harder.
//!
//! This preserves what the paper's experiments need from image data:
//! dense informative gradients in the conv stack, class structure, and a
//! generalization gap that differentiates optimizers/compressors.

use crate::util::rng::Rng;

use super::Dataset;

pub struct SyntheticImages {
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    /// `modes` prototypes per class, each h*w*c (NHWC), indexed
    /// `[class * modes + mode]`.
    templates: Vec<Vec<f32>>,
    modes: usize,
    noise: f32,
    /// Probability a sample carries a corrupted label (irreducible Bayes
    /// error — keeps loss curves informative instead of collapsing to 0,
    /// and supplies the persistent gradient variance σ² of Assumption 4).
    label_flip: f32,
    max_shift: i32,
}

impl SyntheticImages {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        seed: u64,
        h: usize,
        w: usize,
        c: usize,
        classes: usize,
        modes: usize,
        noise: f32,
        label_flip: f32,
    ) -> Self {
        let mut rng = Rng::seed(seed ^ 0x1A4A6E);
        let templates = (0..classes * modes)
            .map(|_| smooth_template(&mut rng, h, w, c))
            .collect();
        SyntheticImages {
            h,
            w,
            c,
            classes,
            templates,
            modes,
            noise,
            label_flip,
            max_shift: 2,
        }
    }

    /// 28x28x1, 10 classes (MNIST stand-in): moderate noise, 4 modes per
    /// class, 2% label corruption — easy but not instant.
    pub fn mnist_like(seed: u64) -> Self {
        Self::new(seed, 28, 28, 1, 10, 4, 2.5, 0.02)
    }

    /// 32x32x3, 10 classes (CIFAR-10 stand-in): lower SNR, more intra-
    /// class variation and 10% label corruption so methods separate the
    /// way they do on CIFAR in the paper.
    pub fn cifar_like(seed: u64) -> Self {
        Self::new(seed, 32, 32, 3, 10, 6, 2.8, 0.10)
    }

    fn render(&self, rng: &mut Rng, label: usize, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.h * self.w * self.c);
        let mode = rng.gen_range(self.modes);
        let t = &self.templates[label * self.modes + mode];
        let dy = rng.gen_range((2 * self.max_shift + 1) as usize) as i32 - self.max_shift;
        let dx = rng.gen_range((2 * self.max_shift + 1) as usize) as i32 - self.max_shift;
        for y in 0..self.h as i32 {
            for x in 0..self.w as i32 {
                let sy = (y - dy).clamp(0, self.h as i32 - 1) as usize;
                let sx = (x - dx).clamp(0, self.w as i32 - 1) as usize;
                for ch in 0..self.c {
                    let src = (sy * self.w + sx) * self.c + ch;
                    let dst = (y as usize * self.w + x as usize) * self.c + ch;
                    buf[dst] = t[src] + self.noise * rng.normal();
                }
            }
        }
    }
}

impl Dataset for SyntheticImages {
    fn x_len(&self) -> usize {
        self.h * self.w * self.c
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn sample(&self, rng: &mut Rng, buf: &mut [f32]) -> i32 {
        let label = rng.gen_range(self.classes);
        self.render(rng, label, buf);
        self.maybe_flip(rng, label) as i32
    }

    fn sample_class(&self, rng: &mut Rng, label: i32, buf: &mut [f32]) {
        self.render(rng, label as usize, buf);
    }
}

impl SyntheticImages {
    fn maybe_flip(&self, rng: &mut Rng, label: usize) -> usize {
        if self.label_flip > 0.0 && rng.next_f32() < self.label_flip {
            (label + 1 + rng.gen_range(self.classes - 1)) % self.classes
        } else {
            label
        }
    }
}

/// Bilinear upsample of a coarse `g x g` random grid, standardized.
fn smooth_template(rng: &mut Rng, h: usize, w: usize, c: usize) -> Vec<f32> {
    let g = 7usize;
    let mut out = vec![0.0f32; h * w * c];
    for ch in 0..c {
        let coarse: Vec<f32> = (0..g * g).map(|_| rng.normal()).collect();
        for y in 0..h {
            for x in 0..w {
                let fy = y as f32 / (h - 1) as f32 * (g - 1) as f32;
                let fx = x as f32 / (w - 1) as f32 * (g - 1) as f32;
                let (y0, x0) = (fy as usize, fx as usize);
                let (y1, x1) = ((y0 + 1).min(g - 1), (x0 + 1).min(g - 1));
                let (ty, tx) = (fy - y0 as f32, fx - x0 as f32);
                let v = coarse[y0 * g + x0] * (1.0 - ty) * (1.0 - tx)
                    + coarse[y0 * g + x1] * (1.0 - ty) * tx
                    + coarse[y1 * g + x0] * ty * (1.0 - tx)
                    + coarse[y1 * g + x1] * ty * tx;
                out[(y * w + x) * c + ch] = v;
            }
        }
    }
    // Standardize the template.
    let n = out.len() as f32;
    let mean: f32 = out.iter().sum::<f32>() / n;
    let var: f32 = out.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / var.sqrt().max(1e-6);
    for v in &mut out {
        *v = (*v - mean) * inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_are_distinct_per_class() {
        let ds = SyntheticImages::mnist_like(1);
        let d = crate::util::math::dist_sq(&ds.templates[0], &ds.templates[1]);
        assert!(d > 10.0, "templates too similar: {d}");
    }

    #[test]
    fn same_seed_same_dataset() {
        let a = SyntheticImages::cifar_like(5);
        let b = SyntheticImages::cifar_like(5);
        assert_eq!(a.templates, b.templates);
    }

    #[test]
    fn samples_correlate_with_own_class_prototypes() {
        let ds = SyntheticImages::mnist_like(2);
        let mut rng = Rng::seed(3);
        let mut buf = vec![0.0f32; ds.x_len()];
        let corr = |t: &[f32], b: &[f32]| -> f32 {
            t.iter().zip(b).map(|(&a, &x)| a * x).sum::<f32>()
        };
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            ds.sample_class(&mut rng, 4, &mut buf);
            // Best-matching prototype overall should belong to class 4
            // most of the time (noise makes it probabilistic).
            let best = ds
                .templates
                .iter()
                .enumerate()
                .max_by(|a, b| {
                    corr(a.1, &buf).partial_cmp(&corr(b.1, &buf)).unwrap()
                })
                .unwrap()
                .0;
            if best / ds.modes == 4 {
                hits += 1;
            }
        }
        assert!(hits > trials / 2, "only {hits}/{trials} matched class 4");
    }

    #[test]
    fn template_standardized() {
        let ds = SyntheticImages::cifar_like(9);
        for t in &ds.templates {
            let n = t.len() as f32;
            let mean: f32 = t.iter().sum::<f32>() / n;
            let var: f32 = t.iter().map(|&v| (v - mean).powi(2)).sum::<f32>() / n;
            assert!(mean.abs() < 1e-3);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }
}
