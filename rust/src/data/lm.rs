//! Procedural byte corpus for the transformer LM end-to-end driver.
//!
//! Generates deterministic pseudo-English: a seeded vocabulary of word
//! forms composed into sentences with Zipf word frequencies and light
//! punctuation structure. The corpus has real next-byte structure
//! (within-word character transitions, spaces, sentence boundaries), so a
//! byte LM's loss drops well below the uniform 5.545 nats as it learns —
//! which is what the e2e example's loss curve demonstrates.

use crate::util::rng::Rng;

use super::Dataset;

pub struct ByteCorpus {
    corpus: Vec<u8>,
    seq_len: usize,
}

impl ByteCorpus {
    pub fn generate(seed: u64, target_bytes: usize, seq_len: usize) -> Self {
        let mut rng = Rng::seed(seed ^ 0xB17E);
        // Seeded word list: 2-4 syllables of consonant+vowel pairs.
        const CONS: &[u8] = b"bcdfghklmnprstvwz";
        const VOWS: &[u8] = b"aeiou";
        let n_words = 512;
        let words: Vec<Vec<u8>> = (0..n_words)
            .map(|_| {
                let syll = 1 + rng.gen_range(3);
                let mut w = Vec::new();
                for _ in 0..=syll {
                    w.push(CONS[rng.gen_range(CONS.len())]);
                    w.push(VOWS[rng.gen_range(VOWS.len())]);
                }
                w
            })
            .collect();
        let mut corpus = Vec::with_capacity(target_bytes + 64);
        let mut sentence_left = 4 + rng.gen_range(12);
        while corpus.len() < target_bytes {
            let w = &words[rng.zipf(n_words, 1.5)];
            corpus.extend_from_slice(w);
            sentence_left -= 1;
            if sentence_left == 0 {
                corpus.extend_from_slice(b". ");
                sentence_left = 4 + rng.gen_range(12);
            } else {
                corpus.push(b' ');
            }
        }
        corpus.truncate(target_bytes);
        ByteCorpus { corpus, seq_len }
    }

    pub fn len_bytes(&self) -> usize {
        self.corpus.len()
    }

    /// Sample a window: x = bytes[i..i+L], y = bytes[i+1..i+L+1].
    pub fn sample_window(&self, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let max_start = self.corpus.len() - self.seq_len - 1;
        let start = rng.gen_range(max_start);
        let x = self.corpus[start..start + self.seq_len]
            .iter()
            .map(|&b| b as i32)
            .collect();
        let y = self.corpus[start + 1..start + self.seq_len + 1]
            .iter()
            .map(|&b| b as i32)
            .collect();
        (x, y)
    }

    /// Assemble an LM batch (y is the shifted window, token-level labels).
    pub fn make_lm_batch(&self, rng: &mut Rng, batch: usize) -> super::Batch {
        let mut xs = Vec::with_capacity(batch * self.seq_len);
        let mut ys = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let (x, y) = self.sample_window(rng);
            xs.extend(x);
            ys.extend(y);
        }
        super::Batch { x: super::BatchData::I32(xs), y: ys }
    }
}

/// `Dataset` impl so the LM corpus can flow through the generic sharder
/// (class = always 0; the LM task has no labels).
impl Dataset for ByteCorpus {
    fn x_len(&self) -> usize {
        self.seq_len
    }

    fn classes(&self) -> usize {
        1
    }

    fn integer_x(&self) -> bool {
        true
    }

    fn sample(&self, rng: &mut Rng, buf: &mut [f32]) -> i32 {
        let (x, _) = self.sample_window(rng);
        for (b, v) in buf.iter_mut().zip(x) {
            *b = v as f32;
        }
        0
    }

    fn sample_class(&self, rng: &mut Rng, _label: i32, buf: &mut [f32]) {
        self.sample(rng, buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_printable_ascii() {
        let c = ByteCorpus::generate(1, 10_000, 32);
        assert_eq!(c.len_bytes(), 10_000);
        assert!(c.corpus.iter().all(|&b| b == b' ' || b == b'.' || b.is_ascii_lowercase()));
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let c = ByteCorpus::generate(2, 5_000, 16);
        let mut rng = Rng::seed(4);
        for _ in 0..10 {
            let (x, y) = c.sample_window(&mut rng);
            assert_eq!(x.len(), 16);
            assert_eq!(&x[1..], &y[..15]);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ByteCorpus::generate(9, 2_000, 8);
        let b = ByteCorpus::generate(9, 2_000, 8);
        assert_eq!(a.corpus, b.corpus);
    }

    #[test]
    fn lm_batch_shapes() {
        let c = ByteCorpus::generate(3, 4_000, 32);
        let mut rng = Rng::seed(5);
        let b = c.make_lm_batch(&mut rng, 4);
        match &b.x {
            super::super::BatchData::I32(v) => assert_eq!(v.len(), 4 * 32),
            _ => panic!(),
        }
        assert_eq!(b.y.len(), 4 * 32);
    }

    #[test]
    fn corpus_has_structure() {
        // Bigram entropy must be far below uniform log(96) for the LM to
        // have something to learn.
        let c = ByteCorpus::generate(7, 50_000, 32);
        let mut counts = std::collections::BTreeMap::new();
        for w in c.corpus.windows(2) {
            *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let n = (c.corpus.len() - 1) as f64;
        let h: f64 = counts
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum();
        assert!(h < 5.0, "bigram entropy {h}");
    }
}
