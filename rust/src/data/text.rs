//! Synthetic sentiment text (IMDB stand-in, DESIGN.md §4).
//!
//! Binary classification over padded i32 token sequences, vocab 2000.
//! Each class owns a random permutation of the vocabulary; tokens are
//! drawn Zipf-distributed through that permutation, so the two classes
//! put high probability on (mostly) disjoint token subsets — like
//! sentiment-bearing words. Sequence lengths are uniform in
//! [L/4, L], remainder padded with token 0.
//!
//! What matters for the paper's Top-k-wins-on-text claim is preserved:
//! a batch touches only a small vocab subset, so embedding-row gradients
//! are extremely sparse and padding adds exact zeros.

use crate::util::rng::Rng;

use super::Dataset;

pub struct SyntheticText {
    vocab: usize,
    seq_len: usize,
    classes: usize,
    /// Per-class vocab permutation (rank -> token id).
    perms: Vec<Vec<u32>>,
    zipf_s: f32,
    /// Fraction of tokens drawn from the class distribution (the rest are
    /// "neutral" tokens shared across classes).
    class_frac: f32,
}

impl SyntheticText {
    pub fn new(seed: u64, vocab: usize, seq_len: usize, classes: usize) -> Self {
        let mut rng = Rng::seed(seed ^ 0x7E47);
        let perms = (0..classes)
            .map(|_| {
                let mut p: Vec<u32> = (1..vocab as u32).collect(); // 0 = pad
                rng.shuffle(&mut p);
                p
            })
            .collect();
        SyntheticText { vocab, seq_len, classes, perms, zipf_s: 1.3, class_frac: 0.5 }
    }

    /// Paper-shaped IMDB stand-in: vocab 2000, binary labels.
    pub fn imdb_like(seed: u64, seq_len: usize) -> Self {
        Self::new(seed, 2000, seq_len, 2)
    }

    fn render(&self, rng: &mut Rng, label: usize, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.seq_len);
        let len = self.seq_len / 4 + rng.gen_range(self.seq_len - self.seq_len / 4);
        for slot in buf.iter_mut().take(len) {
            let tok = if rng.next_f32() < self.class_frac {
                // Class-specific: low Zipf ranks through this class's perm.
                self.perms[label][rng.zipf(self.vocab - 1, self.zipf_s)]
            } else {
                // Neutral: shared Zipf head (perm of class 0 reversed tail
                // would re-correlate; use raw token ids).
                (1 + rng.zipf(self.vocab - 1, self.zipf_s)) as u32
            };
            *slot = tok as f32;
        }
        for slot in buf.iter_mut().skip(len) {
            *slot = 0.0; // pad
        }
    }
}

impl Dataset for SyntheticText {
    fn x_len(&self) -> usize {
        self.seq_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn integer_x(&self) -> bool {
        true
    }

    fn sample(&self, rng: &mut Rng, buf: &mut [f32]) -> i32 {
        let label = rng.gen_range(self.classes);
        self.render(rng, label, buf);
        label as i32
    }

    fn sample_class(&self, rng: &mut Rng, label: i32, buf: &mut [f32]) {
        self.render(rng, label as usize, buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_padded() {
        let ds = SyntheticText::imdb_like(3, 64);
        let mut rng = Rng::seed(1);
        let mut buf = vec![0.0f32; 64];
        for _ in 0..20 {
            ds.sample(&mut rng, &mut buf);
            assert!(buf.iter().all(|&t| t >= 0.0 && t < 2000.0));
            // Once padding starts it continues to the end.
            let first_pad = buf.iter().position(|&t| t == 0.0);
            if let Some(i) = first_pad {
                assert!(buf[i..].iter().all(|&t| t == 0.0));
                assert!(i >= 16, "min length L/4");
            }
        }
    }

    #[test]
    fn classes_use_different_token_heads() {
        let ds = SyntheticText::imdb_like(11, 64);
        let mut rng = Rng::seed(2);
        let mut buf = vec![0.0f32; 64];
        let mut head = |label: i32| -> std::collections::BTreeSet<u32> {
            let mut counts = std::collections::BTreeMap::new();
            for _ in 0..200 {
                ds.sample_class(&mut rng, label, &mut buf);
                for &t in buf.iter().filter(|&&t| t != 0.0) {
                    *counts.entry(t as u32).or_insert(0usize) += 1;
                }
            }
            let mut v: Vec<_> = counts.into_iter().collect();
            v.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
            v.into_iter().take(10).map(|(t, _)| t).collect()
        };
        let h0 = head(0);
        let h1 = head(1);
        let overlap = h0.intersection(&h1).count();
        assert!(overlap < 8, "class token heads overlap too much: {overlap}");
    }

    #[test]
    fn batch_touches_small_vocab_subset() {
        // The sparsity property Top-k exploits: one batch references far
        // fewer distinct tokens than the vocab.
        let ds = SyntheticText::imdb_like(5, 64);
        let mut rng = Rng::seed(3);
        let mut buf = vec![0.0f32; 64];
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..16 {
            ds.sample(&mut rng, &mut buf);
            for &t in buf.iter() {
                distinct.insert(t as u32);
            }
        }
        assert!(distinct.len() < 500, "batch touched {} tokens", distinct.len());
    }
}
