//! Shard assignment: how workers see the data distribution.
//!
//! - `Iid`: the paper's main setting — every worker samples from the full
//!   distribution (σ_g² = 0 in Assumption 4).
//! - `Dirichlet(α)`: federated-style label skew — worker i's label
//!   distribution is a Dirichlet(α) draw, giving σ_g² > 0. Used by the
//!   non-iid ablation (Theorem 1's global-variance term).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    Iid,
    Dirichlet { alpha: f32 },
}

impl Sharding {
    pub fn parse(s: &str) -> anyhow::Result<Sharding> {
        if s == "iid" {
            return Ok(Sharding::Iid);
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            return Ok(Sharding::Dirichlet { alpha: a.parse()? });
        }
        anyhow::bail!("unknown sharding '{s}' (iid | dirichlet:<alpha>)")
    }

    /// Per-worker label weights; `None` = sample the full distribution.
    pub fn worker_weights(
        &self,
        rng: &mut Rng,
        n_workers: usize,
        classes: usize,
    ) -> Vec<Option<Vec<f32>>> {
        match self {
            Sharding::Iid => vec![None; n_workers],
            Sharding::Dirichlet { alpha } => (0..n_workers)
                .map(|_| Some(rng.dirichlet(*alpha, classes)))
                .collect(),
        }
    }
}

/// Mean total-variation distance of worker label distributions from
/// uniform — a diagnostic for how non-iid a sharding draw is.
pub fn label_skew(weights: &[Option<Vec<f32>>], classes: usize) -> f32 {
    let uniform = 1.0 / classes as f32;
    let mut total = 0.0f32;
    let mut count = 0usize;
    for w in weights.iter().flatten() {
        total += 0.5 * w.iter().map(|&p| (p - uniform).abs()).sum::<f32>();
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_gives_no_weights() {
        let mut rng = Rng::seed(1);
        let w = Sharding::Iid.worker_weights(&mut rng, 4, 10);
        assert!(w.iter().all(|x| x.is_none()));
        assert_eq!(label_skew(&w, 10), 0.0);
    }

    #[test]
    fn dirichlet_weights_are_distributions() {
        let mut rng = Rng::seed(2);
        let w = Sharding::Dirichlet { alpha: 0.5 }.worker_weights(&mut rng, 8, 10);
        for wi in w.iter().flatten() {
            assert_eq!(wi.len(), 10);
            assert!((wi.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn smaller_alpha_is_more_skewed() {
        let mut rng = Rng::seed(3);
        let sharp = Sharding::Dirichlet { alpha: 0.05 }.worker_weights(&mut rng, 16, 10);
        let flat = Sharding::Dirichlet { alpha: 50.0 }.worker_weights(&mut rng, 16, 10);
        assert!(label_skew(&sharp, 10) > label_skew(&flat, 10) + 0.2);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Sharding::parse("iid").unwrap(), Sharding::Iid);
        assert_eq!(
            Sharding::parse("dirichlet:0.3").unwrap(),
            Sharding::Dirichlet { alpha: 0.3 }
        );
        assert!(Sharding::parse("x").is_err());
    }
}
