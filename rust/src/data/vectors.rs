//! Gaussian-cluster feature vectors — the workload for the tiny `logreg`
//! smoke model (integration tests / micro-benches of the full PJRT path).

use crate::util::rng::Rng;

use super::Dataset;

pub struct GaussianVectors {
    dim: usize,
    classes: usize,
    means: Vec<Vec<f32>>,
    noise: f32,
}

impl GaussianVectors {
    pub fn new(seed: u64, dim: usize, classes: usize, noise: f32) -> Self {
        let mut rng = Rng::seed(seed ^ 0x6A55);
        let means = (0..classes).map(|_| rng.normal_vec(dim)).collect();
        GaussianVectors { dim, classes, means, noise }
    }

    fn render(&self, rng: &mut Rng, label: usize, buf: &mut [f32]) {
        for (b, &m) in buf.iter_mut().zip(&self.means[label]) {
            *b = m + self.noise * rng.normal();
        }
    }
}

impl Dataset for GaussianVectors {
    fn x_len(&self) -> usize {
        self.dim
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn sample(&self, rng: &mut Rng, buf: &mut [f32]) -> i32 {
        let label = rng.gen_range(self.classes);
        self.render(rng, label, buf);
        label as i32
    }

    fn sample_class(&self, rng: &mut Rng, label: i32, buf: &mut [f32]) {
        self.render(rng, label as usize, buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_are_separable() {
        let ds = GaussianVectors::new(1, 16, 4, 0.3);
        let mut rng = Rng::seed(2);
        let mut buf = vec![0.0f32; 16];
        for _ in 0..50 {
            let y = ds.sample(&mut rng, &mut buf) as usize;
            // Nearest mean should be the true class.
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, m) in ds.means.iter().enumerate() {
                let d = crate::util::math::dist_sq(m, &buf);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            assert_eq!(best, y);
        }
    }
}
