//! Deterministic PRNG: xoshiro256** seeded via SplitMix64, with Box-Muller
//! normals and the sampling helpers the data generators need.
//!
//! Every stochastic component in the framework (data synthesis, sharding,
//! Random-k compression, dropout seeds) draws from a seeded [`Rng`] so a
//! run is reproducible from `TrainConfig::seed` alone, and the threaded
//! and sequential coordinators produce identical trajectories.

/// SplitMix64: seeds the main generator and derives sub-streams.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f32>,
}

impl Rng {
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per worker) from this one.
    pub fn split(&mut self, salt: u64) -> Rng {
        Rng::seed(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the full generator state (xoshiro lanes + the cached
    /// Box-Muller spare). [`Rng::restore`] of this snapshot continues the
    /// stream bitwise-identically — the basis of suspend/resume for every
    /// stochastic component.
    pub fn state(&self) -> ([u64; 4], Option<f32>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn restore(s: [u64; 4], spare_normal: Option<f32>) -> Rng {
        Rng { s, spare_normal }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1) with 24 bits of precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire-style).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = (self.next_f64().max(1e-300)) as f64;
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let t = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some((r * t.sin()) as f32);
        (r * t.cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample a Gamma(alpha, 1) variate (Marsaglia–Tsang; alpha > 0),
    /// used for Dirichlet non-iid shard allocation.
    pub fn gamma(&mut self, alpha: f32) -> f32 {
        if alpha < 1.0 {
            // Boosting: Gamma(a) = Gamma(a+1) * U^{1/a}.
            let u = self.next_f64().max(1e-12);
            return self.gamma(alpha + 1.0) * (u.powf(1.0 / alpha as f64)) as f32;
        }
        let d = alpha as f64 - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return (d * v) as f32;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) draw.
    pub fn dirichlet(&mut self, alpha: f32, k: usize) -> Vec<f32> {
        let mut g: Vec<f32> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f32 = g.iter().sum();
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Zipf-like rank sampler over [0, n): P(r) ∝ 1/(r+1)^s.
    pub fn zipf(&mut self, n: usize, s: f32) -> usize {
        // Inverse-CDF on a precomputable harmonic sum would be faster, but
        // text generation is off the hot path; rejection is fine here.
        loop {
            let u = self.next_f64();
            let r = ((n as f64).powf(u) - 1.0) as usize; // log-uniform skew
            let r = r.min(n - 1);
            let accept = 1.0 / ((r + 1) as f64).powf(s as f64 - 1.0);
            if self.next_f64() < accept {
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed(7);
        let mut b = Rng::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_restore_continues_the_stream_bitwise() {
        let mut a = Rng::seed(41);
        // Advance through normal() so the Box-Muller spare is cached.
        for _ in 0..7 {
            a.normal();
        }
        let (s, spare) = a.state();
        let mut b = Rng::restore(s, spare);
        for _ in 0..50 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::seed(3);
        let mut w0 = root.split(0);
        let mut w1 = root.split(1);
        assert_ne!(w0.next_u64(), w1.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed(11);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(13);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gen_range_covers_all_and_in_bounds() {
        let mut r = Rng::seed(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.gen_range(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed(19);
        for &alpha in &[0.1f32, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 8);
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_approx_alpha() {
        let mut r = Rng::seed(23);
        for &alpha in &[0.5f32, 2.0, 5.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(alpha) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha as f64).abs() < 0.1 * alpha as f64 + 0.05,
                "alpha={alpha} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed(29);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let mut r = Rng::seed(31);
        let mut counts = [0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[50].max(1) * 3);
    }

    #[test]
    fn sample_weighted_respects_weights() {
        let mut r = Rng::seed(37);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..12_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 2);
    }
}
