//! Minimal JSON parser/printer (no serde in the offline registry).
//!
//! Covers the full JSON grammar we produce and consume: the artifact
//! manifest written by `python/compile/aot.py`, experiment configs, and
//! metric dumps. Numbers are kept as f64 (the manifest only carries
//! integers within 2^53, which f64 represents exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- printing ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            bail!("expected '{}' got '{}' at byte {}", c as char, got as char, self.i);
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()?;
                            cp = cp * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        // Surrogate pairs: decode if a low surrogate follows.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump()? != b'\\' || self.bump()? != b'u' {
                                bail!("unpaired surrogate");
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()?;
                                lo = lo * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.i - 1;
                        for _ in 1..len {
                            self.bump()?;
                        }
                        s.push_str(
                            std::str::from_utf8(&self.b[start..self.i])
                                .map_err(|_| anyhow!("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let x: f64 = s.parse().map_err(|_| anyhow!("bad number '{s}'"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a":[1,2,{"b":false}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.req("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let j = parse(r#"{"m":[{"x":[1,2,3],"y":"s"}],"n":3.5,"t":true}"#).unwrap();
        for s in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(parse(&s).unwrap(), j);
        }
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
        let j2 = parse(&Json::str("é😀").to_string_compact()).unwrap();
        assert_eq!(j2.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(5.0).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn as_usize_guards() {
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(parse("7").unwrap().as_usize().unwrap(), 7);
    }
}
