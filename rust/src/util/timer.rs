//! Wall-clock timing helpers used by the coordinator metrics stream and
//! the hand-rolled bench harness ([`crate::testing::bench`]).

use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human format for durations in log lines: "1.23s", "45ms", "12.3us".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0us");
    }

    #[test]
    fn restart_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let first = sw.restart();
        assert!(first.as_micros() >= 1000);
        assert!(sw.elapsed() < first);
    }
}
