//! CSV writer for experiment outputs (`results/*.csv`), the format every
//! `exp::fig*` driver emits so curves can be re-plotted externally.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.w, "{}", escaped.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn escape(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Convenience macro-free row builder.
pub fn fields(items: &[&dyn std::fmt::Display]) -> Vec<String> {
    items.iter().map(|i| i.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("comp_ams_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&fields(&[&1.5, &"x,y"])).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1.5,\"x,y\"\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
