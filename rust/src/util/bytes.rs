//! Tiny length-prefixed binary codec for opaque state blobs.
//!
//! Suspend/resume serializes component state (RNG streams, error-feedback
//! residuals, optimizer moments) into self-describing byte blobs that can
//! be nested: each field is written with a fixed-width little-endian
//! encoding, and variable-length fields carry a `u32` length prefix. The
//! reader is a cursor that validates every read against the remaining
//! buffer, so a truncated or mismatched blob surfaces as an error instead
//! of garbage state.

use anyhow::{bail, Result};

/// Append a `u32` (little-endian).
pub fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a `u64` (little-endian).
pub fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append an `f32` (little-endian bit pattern — exact).
pub fn put_f32(out: &mut Vec<u8>, x: f32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a length-prefixed `f32` vector.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_f32(out, x);
    }
}

/// Append a length-prefixed byte blob.
pub fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

/// Bounds-checked reader over a state blob.
pub struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!(
                "state blob truncated: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Error unless the whole blob has been consumed (catches blobs from
    /// a component with a different state layout).
    pub fn finish(self) -> Result<()> {
        if self.i != self.b.len() {
            bail!(
                "state blob has {} trailing bytes (layout mismatch?)",
                self.b.len() - self.i
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_field_kinds() {
        let mut b = Vec::new();
        put_u32(&mut b, 7);
        put_u64(&mut b, u64::MAX - 3);
        put_f32(&mut b, -0.0);
        put_f32s(&mut b, &[1.5, f32::MIN_POSITIVE, -3.25]);
        put_bytes(&mut b, &[9, 8, 7]);
        let mut c = Cursor::new(&b);
        assert_eq!(c.u32().unwrap(), 7);
        assert_eq!(c.u64().unwrap(), u64::MAX - 3);
        assert_eq!(c.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        let xs = c.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert_eq!(c.bytes().unwrap(), &[9, 8, 7]);
        c.finish().unwrap();
    }

    #[test]
    fn truncated_and_trailing_blobs_error() {
        let mut b = Vec::new();
        put_u64(&mut b, 1);
        let mut c = Cursor::new(&b[..6]);
        assert!(c.u64().is_err());
        let mut c = Cursor::new(&b);
        c.u32().unwrap();
        assert!(c.finish().is_err());
    }

    #[test]
    fn length_prefix_is_validated() {
        let mut b = Vec::new();
        put_u32(&mut b, 100); // claims 100 f32s, delivers none
        let mut c = Cursor::new(&b);
        assert!(c.f32s().is_err());
    }
}
