//! Small dense-vector kernels shared across the coordinator hot path.
//!
//! These are the L3 inner loops (averaging, axpy, norms) — kept in one
//! place so the §Perf pass can optimize them once. All operate on plain
//! `&[f32]` slices; the compiler auto-vectorizes the simple loops.

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Sum of squares.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// L1 norm.
#[inline]
pub fn norm1(x: &[f32]) -> f64 {
    x.iter().map(|&v| v.abs() as f64).sum()
}

/// Squared distance ||a-b||^2.
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

/// Elementwise mean of rows into `out` (the gradient-averaging hot loop).
/// `rows` must all have `out.len()` elements.
pub fn mean_into(rows: &[&[f32]], out: &mut [f32]) {
    let n = rows.len();
    assert!(n > 0);
    let inv = 1.0 / n as f32;
    out.copy_from_slice(rows[0]);
    for row in &rows[1..] {
        debug_assert_eq!(row.len(), out.len());
        for (o, &r) in out.iter_mut().zip(*row) {
            *o += r;
        }
    }
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Softmax cross-entropy + argmax over one logits row (used by the
/// pure-Rust GradSources).
pub fn log_softmax_row(logits: &mut [f32]) {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for l in logits.iter_mut() {
        *l -= max;
        sum += l.exp();
    }
    let ln_sum = sum.ln();
    for l in logits.iter_mut() {
        *l -= ln_sum;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_norms() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((norm2_sq(&x) - 14.0).abs() < 1e-9);
        assert!((norm1(&x) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn mean_matches_manual() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn log_softmax_normalizes() {
        let mut row = [1.0f32, 2.0, 3.0];
        log_softmax_row(&mut row);
        let total: f32 = row.iter().map(|l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert_eq!(argmax(&row), 2);
    }

    #[test]
    fn dist_sq_zero_on_equal() {
        let a = [0.5f32; 10];
        assert_eq!(dist_sq(&a, &a), 0.0);
    }
}
