//! Tiny CLI argument parser for the launcher (no clap offline).
//!
//! Grammar: `comp-ams <positional...> [--key value | --flag]`.
//! `--key=value` is also accepted. Unknown flags are collected and can be
//! rejected by the caller via [`Args::ensure_known`].

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags.insert(rest.to_string(), it.next().unwrap());
                } else {
                    // boolean flag
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad usize '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad u64 '{v}'")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad f32 '{v}'")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => bail!("--{key}: bad bool '{v}'"),
        }
    }

    /// Error out on any flag not in `known` (catches typos in launch cmds).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("train fig1 --model mnist_cnn --workers 16 --fast");
        assert_eq!(a.positional, vec!["train", "fig1"]);
        assert_eq!(a.get("model"), Some("mnist_cnn"));
        assert_eq!(a.usize_or("workers", 1).unwrap(), 16);
        assert!(a.bool_or("fast", false).unwrap());
    }

    #[test]
    fn eq_form_and_defaults() {
        let a = parse("x --lr=0.001");
        assert_eq!(a.f32_or("lr", 0.0).unwrap(), 0.001);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("exp fig3 --fast");
        assert!(a.bool_or("fast", false).unwrap());
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("t --oops 1");
        assert!(a.ensure_known(&["model"]).is_err());
        assert!(a.ensure_known(&["oops"]).is_ok());
    }

    #[test]
    fn bad_values_error() {
        let a = parse("t --n abc");
        assert!(a.usize_or("n", 0).is_err());
    }
}
