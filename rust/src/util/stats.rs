//! Summary statistics for multi-seed experiment aggregation (the paper
//! reports best-of-grid *averaged over three independent runs*).

/// Mean, sample std, and a normal-approximation 95% CI half-width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|&x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let std = var.sqrt();
    Summary {
        n,
        mean,
        std,
        ci95: 1.96 * std / (n as f64).sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Ordinary least squares slope/intercept of y on x, plus R².
/// Used by the Fig. 3 analysis: regress log2(rounds-to-target) on
/// log2(n) — perfect linear speedup gives slope -1.
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(&a, &b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|&a| (a - mx).powi(2)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_tot: f64 = y.iter().map(|&b| (b - my).powi(2)).sum();
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(&a, &b)| (b - (intercept + slope * a)).powi(2))
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (slope, intercept, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_hand_check() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn single_sample_has_zero_spread() {
        let s = summarize(&[5.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn linreg_recovers_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (slope, intercept, r2) = linreg(&x, &y);
        assert!((slope - 2.0).abs() < 1e-12);
        assert!((intercept - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linreg_speedup_shape() {
        // rounds halving per doubling of n -> slope -1 in log2-log2.
        let x: Vec<f64> = [1, 2, 4, 8, 16].iter().map(|&n| (n as f64).log2()).collect();
        let y: Vec<f64> = [1600, 800, 400, 200, 100]
            .iter()
            .map(|&r| (r as f64).log2())
            .collect();
        let (slope, _, r2) = linreg(&x, &y);
        assert!((slope + 1.0).abs() < 1e-9);
        assert!(r2 > 0.999);
    }
}
