//! From-scratch utility substrates.
//!
//! The build image's crate registry only carries the `xla` dependency
//! closure, so everything a framework usually pulls from crates.io (RNG,
//! JSON, CSV, CLI parsing, timers) is implemented here (DESIGN.md §2).

pub mod bytes;
pub mod cli;
pub mod csv;
pub mod json;
pub mod math;
pub mod rng;
pub mod stats;
pub mod timer;
