//! Seeded network simulator: deterministic link impairments over any
//! in-process [`Transport`].
//!
//! [`Sim`] wraps a transport and re-times its uplink arrivals on a
//! **virtual clock** driven by a seeded model — per-link latency, jitter,
//! a bandwidth term proportional to the frame size, and seeded "drops"
//! that resurface as retransmit delay. No real time passes: unit tests
//! and CI get WAN-shaped schedules that are bit-for-bit reproducible from
//! `--sim-seed` alone, independent of thread scheduling and host load.
//!
//! ## Delivery model
//!
//! The wrapped transport owes exactly one uplink (or exit) per dispatched
//! downlink — the cluster runtime's core invariant. `Sim` preserves it
//! with a *barrier-collect* event queue:
//!
//! 1. [`Transport::send_downlink`] is forwarded and the virtual dispatch
//!    time of that link is stamped — shifted by a per-link **downlink
//!    delay** (latency + jitter + downlink-frame serialization, drawn
//!    from a downlink-salted stream), so the θ broadcast is not free on
//!    the virtual clock: the uplink leg starts only once θ arrived.
//! 2. The first [`Transport::recv_event`] of a batch physically drains
//!    **every** outstanding uplink from the inner transport, stamping
//!    each with `dispatch + latency + jitter + bits/bandwidth +
//!    drops·retransmit` drawn from an RNG keyed on `(seed, wid, round)` —
//!    never on physical arrival order.
//! 3. Buffered events are then handed to the runtime ordered by
//!    `(virtual arrival, wid)`, a total order that is a pure function of
//!    the seed, the profile, and the trajectory.
//!
//! Under `--quorum K < n` the runtime stops consuming once K fresh
//! uplinks are in, so the slowest links of a round stay buffered and are
//! delivered *next* round with their original round tag — staleness and
//! drop accounting then emerge from the existing runtime machinery
//! instead of wall-clock luck. A seeded "drop" is deliberately modeled as
//! a retransmit (large extra delay), never as message loss: every owed
//! uplink still arrives exactly once, which is what keeps the runtime's
//! collect/drain loops live.
//!
//! With the `ideal` profile every delay is zero, the delivery order
//! degenerates to wid order, and a wrapped run is bitwise identical to
//! the bare transport (property-tested across all protocol strings —
//! the runtime sorts each round's batch by wid before aggregating, so
//! within-batch delivery order never reaches the math).
//!
//! Per-link delivery counts, retransmits, reorderings, and cumulative
//! virtual delay are surfaced as [`LinkStats`] through
//! [`Transport::link_stats`], the [`CommLedger`](super::comm::CommLedger)
//! and [`RunResult`](super::metrics::RunResult) — the same path
//! `framing_bits` takes today.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::algo::RoundCtx;
use crate::util::rng::Rng;

use super::transport::{Event, Transport};

/// Retransmits are capped so a pathological `drop_prob` (e.g. 1.0 in a
/// stress test) still yields a finite delay instead of an unbounded loop.
const MAX_RETRANSMITS: u64 = 8;

/// Per-link (leader↔worker) delivery statistics, accumulated on the
/// virtual clock across the whole run. One entry per worker id.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinkStats {
    /// Uplinks delivered to the runtime over this link.
    pub delivered: u64,
    /// Seeded drop events — each one resurfaced as one retransmit delay
    /// ([`SimProfile::retransmit_us`]), never as a lost message.
    pub drops: u64,
    /// Uplinks delivered after an uplink of a higher wid within the same
    /// collect batch — the link's share of cross-worker reordering.
    pub reordered: u64,
    /// Cumulative virtual one-way delay (µs) over delivered uplinks.
    pub delay_us: u64,
    /// Cumulative virtual one-way delay (µs) over dispatched downlinks —
    /// the θ broadcast is no longer instantaneous on the virtual clock:
    /// each dispatch is stamped `now + latency + jitter + bits/bandwidth`
    /// (drawn from a downlink-salted RNG stream), which pushes the whole
    /// round-trip of that link later. Zero under the `ideal` profile.
    pub downlink_delay_us: u64,
}

/// The valid `--sim-profile` spellings, for every error message that has
/// to enumerate them.
pub const SIM_PROFILE_CHOICES: &str = "ideal | lan | wan | lossy-wan";

/// A named set of link impairments (`--sim-profile`). All quantities are
/// per uplink on the virtual clock; `ideal` (the default) is the
/// all-zero profile under which [`Sim`] is a transparent wrapper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimProfile {
    /// Base one-way latency (µs).
    pub latency_us: u64,
    /// Uniform extra delay in `[0, jitter_us]` (µs).
    pub jitter_us: u64,
    /// Link bandwidth in bits per virtual µs (1 bit/µs = 1 Mbit/s);
    /// 0 means infinite (no serialization delay).
    pub bandwidth_bits_per_us: u64,
    /// Per-uplink probability of a seeded drop; each drop adds one
    /// [`SimProfile::retransmit_us`] to the delivery delay (geometric,
    /// capped at [`MAX_RETRANSMITS`]).
    pub drop_prob: f32,
    /// Timeout-and-resend penalty per seeded drop (µs).
    pub retransmit_us: u64,
}

impl SimProfile {
    /// Parse a named profile; the error enumerates the accepted forms.
    pub fn parse(s: &str) -> Result<SimProfile> {
        match s {
            "ideal" => Ok(SimProfile {
                latency_us: 0,
                jitter_us: 0,
                bandwidth_bits_per_us: 0,
                drop_prob: 0.0,
                retransmit_us: 0,
            }),
            // 10 Gb/s switch fabric: sub-ms latency, no loss.
            "lan" => Ok(SimProfile {
                latency_us: 100,
                jitter_us: 50,
                bandwidth_bits_per_us: 10_000,
                drop_prob: 0.0,
                retransmit_us: 1_000,
            }),
            // 100 Mb/s cross-region path: 40 ms base RTT share, rare loss.
            "wan" => Ok(SimProfile {
                latency_us: 40_000,
                jitter_us: 10_000,
                bandwidth_bits_per_us: 100,
                drop_prob: 0.001,
                retransmit_us: 200_000,
            }),
            // Degraded 50 Mb/s path: heavy jitter, 5% loss — the profile
            // the straggler/staleness integration tests run under.
            "lossy-wan" => Ok(SimProfile {
                latency_us: 60_000,
                jitter_us: 30_000,
                bandwidth_bits_per_us: 50,
                drop_prob: 0.05,
                retransmit_us: 250_000,
            }),
            other => bail!(
                "unknown sim profile '{other}' (valid profiles: {SIM_PROFILE_CHOICES})"
            ),
        }
    }

    /// True when every impairment is zero — [`Sim`] then adds no delay
    /// and delivers each batch in wid order.
    pub fn is_ideal(&self) -> bool {
        self.latency_us == 0
            && self.jitter_us == 0
            && self.bandwidth_bits_per_us == 0
            && self.drop_prob == 0.0
            && self.retransmit_us == 0
    }
}

/// One re-timed event waiting in the delivery queue.
struct Delivery {
    /// Virtual arrival time (µs).
    at: u64,
    wid: usize,
    /// Physical pull order — the final tie-breaker so the sort is total.
    seq: u64,
    delay_us: u64,
    drops: u64,
    event: Event,
}

/// A [`Transport`] wrapper that injects seeded, deterministic link
/// impairments (see the module docs for the delivery model).
pub struct Sim<T: Transport> {
    inner: T,
    seed: u64,
    profile: SimProfile,
    /// Virtual clock: the arrival stamp of the last delivered event.
    now_us: u64,
    /// Virtual dispatch time of the last downlink per wid.
    dispatch_us: Vec<u64>,
    /// Links with a dispatched round the inner transport has not yet
    /// physically answered.
    owed: Vec<bool>,
    outstanding: usize,
    seq: u64,
    /// Current batch, sorted descending so `pop()` yields the earliest
    /// virtual arrival.
    ready: Vec<Delivery>,
    /// Highest wid delivered so far in the current batch (reorder stat).
    batch_max_wid: Option<usize>,
    links: Vec<LinkStats>,
}

impl<T: Transport> Sim<T> {
    pub fn new(inner: T, seed: u64, profile: SimProfile) -> Self {
        let n = inner.n_workers();
        Sim {
            inner,
            seed,
            profile,
            now_us: 0,
            dispatch_us: vec![0; n],
            owed: vec![false; n],
            outstanding: 0,
            seq: 0,
            ready: Vec::new(),
            batch_max_wid: None,
            links: vec![LinkStats::default(); n],
        }
    }

    fn grow_to(&mut self, wid: usize) {
        if wid >= self.links.len() {
            self.links.resize(wid + 1, LinkStats::default());
            self.dispatch_us.resize(wid + 1, 0);
            self.owed.resize(wid + 1, false);
        }
    }

    /// Delay and drop count for one uplink, drawn from an RNG keyed on
    /// `(seed, wid, round)` — a pure function of the trajectory, never of
    /// physical arrival order (which thread timing could perturb).
    fn link_delay(&self, wid: usize, round: u64, bits: u64) -> (u64, u64) {
        let p = &self.profile;
        if p.is_ideal() {
            return (0, 0);
        }
        let mut r = Rng::seed(
            self.seed
                ^ (wid as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ round.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let mut delay = p.latency_us;
        if p.jitter_us > 0 {
            delay += r.gen_range(p.jitter_us as usize + 1) as u64;
        }
        if p.bandwidth_bits_per_us > 0 {
            delay += bits / p.bandwidth_bits_per_us;
        }
        let mut drops = 0u64;
        while drops < MAX_RETRANSMITS && r.next_f32() < p.drop_prob {
            drops += 1;
        }
        delay += drops * p.retransmit_us;
        (delay, drops)
    }

    /// Downlink (θ broadcast) delay for one dispatch: latency + jitter +
    /// serialization of the downlink frame. Drawn from a stream salted
    /// away from the uplink draw so the two directions are independent;
    /// seeded drops stay an uplink-side concept (the broadcast is modeled
    /// as delay-only, keeping the one-uplink-per-dispatch invariant
    /// untouched).
    fn downlink_delay(&self, wid: usize, round: u64, bits: u64) -> u64 {
        let p = &self.profile;
        if p.is_ideal() {
            return 0;
        }
        let mut r = Rng::seed(
            self.seed
                ^ 0xA5A5_5A5A_C3C3_3C3C
                ^ (wid as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ round.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let mut delay = p.latency_us;
        if p.jitter_us > 0 {
            delay += r.gen_range(p.jitter_us as usize + 1) as u64;
        }
        if p.bandwidth_bits_per_us > 0 {
            delay += bits / p.bandwidth_bits_per_us;
        }
        delay
    }

    /// Barrier-collect: physically drain every outstanding event from the
    /// inner transport and stamp each with its virtual arrival.
    fn collect(&mut self) -> Result<()> {
        while self.outstanding > 0 {
            let event = self.inner.recv_event()?;
            let (wid, delay_us, drops) = match &event {
                Event::Uplink { wid, round, msg } => {
                    let (d, k) = self.link_delay(*wid, *round, msg.wire_bits());
                    (*wid, d, k)
                }
                // A death notice is control-plane: it surfaces at the
                // dispatch stamp, ahead of any delayed gradient.
                Event::Exit { wid } => (*wid, 0, 0),
            };
            self.grow_to(wid);
            if self.owed[wid] {
                self.owed[wid] = false;
                self.outstanding -= 1;
            }
            self.seq += 1;
            self.ready.push(Delivery {
                at: self.dispatch_us[wid] + delay_us,
                wid,
                seq: self.seq,
                delay_us,
                drops,
                event,
            });
        }
        // Descending (virtual arrival, wid, pull order): `pop()` delivers
        // the earliest, and the order is total and thread-independent.
        self.ready
            .sort_by(|a, b| (b.at, b.wid, b.seq).cmp(&(a.at, a.wid, a.seq)));
        self.batch_max_wid = None;
        Ok(())
    }
}

impl<T: Transport> Transport for Sim<T> {
    fn n_workers(&self) -> usize {
        self.inner.n_workers()
    }

    fn send_downlink(
        &mut self,
        wid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool> {
        let ok = self.inner.send_downlink(wid, theta, ctx)?;
        if ok {
            self.grow_to(wid);
            if !self.owed[wid] {
                self.owed[wid] = true;
                self.outstanding += 1;
            }
            // Per-link downlink impairment: the worker sees θ only after
            // the broadcast crosses its link, so the uplink leg starts
            // from the delayed stamp. Queried after the forward so a
            // per-round downlink cache (compressed tree broadcasts) is
            // already populated.
            let bits = self.inner.downlink_wire_bits(theta.len())
                + self.inner.frame_overhead_bits();
            let delay = self.downlink_delay(wid, ctx.round, bits);
            self.dispatch_us[wid] = self.now_us + delay;
            self.links[wid].downlink_delay_us += delay;
        }
        Ok(ok)
    }

    fn recv_event(&mut self) -> Result<Event> {
        if self.ready.is_empty() {
            if self.outstanding == 0 {
                bail!("sim: recv_event with no uplinks in flight");
            }
            self.collect()?;
        }
        let d = self.ready.pop().expect("collect left the queue empty");
        self.now_us = self.now_us.max(d.at);
        if matches!(d.event, Event::Uplink { .. }) {
            self.grow_to(d.wid);
            let reordered = self.batch_max_wid.is_some_and(|m| d.wid < m);
            let link = &mut self.links[d.wid];
            link.delivered += 1;
            link.drops += d.drops;
            link.delay_us += d.delay_us;
            if reordered {
                link.reordered += 1;
            }
            self.batch_max_wid =
                Some(self.batch_max_wid.map_or(d.wid, |m| m.max(d.wid)));
        }
        Ok(d.event)
    }

    fn frame_overhead_bits(&self) -> u64 {
        self.inner.frame_overhead_bits()
    }

    fn downlink_wire_bits(&self, dim: usize) -> u64 {
        // The wrapped transport may compress its downlinks (tree root);
        // the simulator re-times, never re-prices.
        self.inner.downlink_wire_bits(dim)
    }

    fn shutdown(&mut self) -> Result<()> {
        self.inner.shutdown()
    }

    fn detach(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        if !self.ready.is_empty() || self.outstanding > 0 {
            bail!("sim: detach with uplinks still in flight");
        }
        self.inner.detach(want_state)
    }

    fn try_rejoin(&mut self) -> Result<Vec<usize>> {
        self.inner.try_rejoin()
    }

    fn link_stats(&self) -> Vec<LinkStats> {
        let mut v = self.links.clone();
        if v.len() < self.inner.n_workers() {
            v.resize(self.inner.n_workers(), LinkStats::default());
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;

    use super::*;
    use crate::compress::Payload;
    use crate::coordinator::transport::UplinkMsg;

    /// Inner transport double: downlinks are recorded, uplinks come off a
    /// scripted queue (in "physical" order the test chooses).
    struct Scripted {
        n: usize,
        queue: VecDeque<Event>,
        dispatched: Vec<(usize, u64)>,
    }

    impl Scripted {
        fn new(n: usize) -> Self {
            Scripted { n, queue: VecDeque::new(), dispatched: Vec::new() }
        }

        fn push_uplink(&mut self, wid: usize, round: u64, dim: usize) {
            let msg = UplinkMsg::from_payload(
                wid as u32,
                round,
                0.5,
                Payload::Dense(vec![0.25; dim]),
            );
            self.queue.push_back(Event::Uplink { wid, round, msg });
        }
    }

    impl Transport for Scripted {
        fn n_workers(&self) -> usize {
            self.n
        }

        fn send_downlink(
            &mut self,
            wid: usize,
            _theta: &Arc<Vec<f32>>,
            ctx: &RoundCtx,
        ) -> Result<bool> {
            self.dispatched.push((wid, ctx.round));
            Ok(true)
        }

        fn recv_event(&mut self) -> Result<Event> {
            match self.queue.pop_front() {
                Some(e) => Ok(e),
                None => bail!("scripted transport queue empty"),
            }
        }
    }

    fn dispatch_all(sim: &mut Sim<Scripted>, n: usize, round: u64) {
        let theta = Arc::new(vec![0.0f32; 4]);
        let ctx = RoundCtx::sync(round, 0.01);
        for wid in 0..n {
            assert!(sim.send_downlink(wid, &theta, &ctx).unwrap());
        }
    }

    fn delivered_wids(sim: &mut Sim<Scripted>, n: usize) -> Vec<usize> {
        (0..n)
            .map(|_| match sim.recv_event().unwrap() {
                Event::Uplink { wid, .. } => wid,
                Event::Exit { wid } => panic!("unexpected exit for {wid}"),
            })
            .collect()
    }

    #[test]
    fn ideal_profile_is_transparent_and_wid_ordered() {
        let n = 4;
        let mut inner = Scripted::new(n);
        // Physical arrival order deliberately scrambled.
        for wid in [2, 0, 3, 1] {
            inner.push_uplink(wid, 0, 4);
        }
        let mut sim = Sim::new(inner, 7, SimProfile::parse("ideal").unwrap());
        dispatch_all(&mut sim, n, 0);
        // Zero delay everywhere → canonical wid order, regardless of the
        // physical order threads would produce.
        assert_eq!(delivered_wids(&mut sim, n), vec![0, 1, 2, 3]);
        for l in sim.link_stats() {
            assert_eq!(l, LinkStats { delivered: 1, ..LinkStats::default() });
        }
    }

    #[test]
    fn same_seed_reproduces_schedule_and_stats_bitwise() {
        let run = |seed: u64| {
            let n = 4;
            let profile = SimProfile::parse("lossy-wan").unwrap();
            let mut order = Vec::new();
            let mut sim = {
                let mut inner = Scripted::new(n);
                for round in 0..6u64 {
                    for wid in 0..n {
                        inner.push_uplink(wid, round, 64);
                    }
                }
                Sim::new(inner, seed, profile)
            };
            for round in 0..6u64 {
                dispatch_all(&mut sim, n, round);
                order.extend(delivered_wids(&mut sim, n));
            }
            (order, sim.link_stats())
        };
        let (order_a, stats_a) = run(41);
        let (order_b, stats_b) = run(41);
        assert_eq!(order_a, order_b);
        assert_eq!(stats_a, stats_b);
        // A different seed draws a different schedule: 24 delay draws
        // agreeing by chance is ~impossible, and this is deterministic.
        let (_, stats_c) = run(42);
        let total = |s: &[LinkStats]| s.iter().map(|l| l.delay_us).sum::<u64>();
        assert_ne!(total(&stats_a), total(&stats_c));
    }

    #[test]
    fn drops_resurface_as_retransmit_delay_not_loss() {
        let n = 3;
        let mut profile = SimProfile::parse("lossy-wan").unwrap();
        profile.drop_prob = 1.0; // every uplink "drops" MAX_RETRANSMITS times
        let mut inner = Scripted::new(n);
        for wid in 0..n {
            inner.push_uplink(wid, 0, 8);
        }
        let mut sim = Sim::new(inner, 3, profile);
        dispatch_all(&mut sim, n, 0);
        let mut got = delivered_wids(&mut sim, n);
        got.sort_unstable();
        // Exactly-once delivery: nothing is ever truly lost.
        assert_eq!(got, vec![0, 1, 2]);
        for l in sim.link_stats() {
            assert_eq!(l.delivered, 1);
            assert_eq!(l.drops, MAX_RETRANSMITS);
            assert!(
                l.delay_us >= MAX_RETRANSMITS * profile.retransmit_us,
                "delay {} missing the retransmit penalty",
                l.delay_us
            );
        }
    }

    #[test]
    fn bandwidth_term_charges_frame_bits() {
        let profile = SimProfile {
            latency_us: 10,
            jitter_us: 0,
            bandwidth_bits_per_us: 2,
            drop_prob: 0.0,
            retransmit_us: 0,
        };
        let mut inner = Scripted::new(1);
        // Dense f32x16: (5 + 64)-byte payload + 16-byte header = 680 bits.
        inner.push_uplink(0, 0, 16);
        let mut sim = Sim::new(inner, 1, profile);
        dispatch_all(&mut sim, 1, 0);
        let _ = delivered_wids(&mut sim, 1);
        let stats = sim.link_stats();
        assert_eq!(stats[0].delay_us, 10 + 680 / 2);
    }

    #[test]
    fn stragglers_stay_buffered_until_consumed() {
        // Quorum-style consumption: take 2 of 4, leave 2 buffered, then
        // drain them next "round" — they come back with their old tag.
        let n = 4;
        let mut inner = Scripted::new(n);
        for wid in 0..n {
            inner.push_uplink(wid, 0, 4);
        }
        let mut sim = Sim::new(inner, 11, SimProfile::parse("lossy-wan").unwrap());
        dispatch_all(&mut sim, n, 0);
        let first_two = delivered_wids(&mut sim, 2);
        let rest: Vec<_> = (0..2)
            .map(|_| match sim.recv_event().unwrap() {
                Event::Uplink { wid, round, .. } => (wid, round),
                Event::Exit { .. } => panic!("unexpected exit"),
            })
            .collect();
        let mut all: Vec<_> =
            first_two.into_iter().chain(rest.iter().map(|&(w, _)| w)).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
        assert!(rest.iter().all(|&(_, r)| r == 0), "straggler kept round tag");
    }

    #[test]
    fn exits_are_delivered_and_forwarded_promptly() {
        let n = 2;
        let mut inner = Scripted::new(n);
        inner.push_uplink(0, 0, 4);
        inner.queue.push_back(Event::Exit { wid: 1 });
        let mut sim = Sim::new(inner, 5, SimProfile::parse("lossy-wan").unwrap());
        dispatch_all(&mut sim, n, 0);
        // The exit carries no gradient delay: it beats the delayed uplink.
        assert!(matches!(sim.recv_event().unwrap(), Event::Exit { wid: 1 }));
        assert!(matches!(sim.recv_event().unwrap(), Event::Uplink { wid: 0, .. }));
        // Exits are control-plane: no delivery/drop accounting (the
        // downlink that was dispatched to the dying worker still crossed
        // its link, so only the downlink leg is billed).
        let l = &sim.link_stats()[1];
        assert_eq!((l.delivered, l.drops, l.reordered, l.delay_us), (0, 0, 0, 0));
        assert!(l.downlink_delay_us > 0, "lossy-wan downlink must be delayed");
    }

    #[test]
    fn downlink_delay_shifts_arrivals_and_is_seeded() {
        // Same uplink schedule, downlink leg on vs off (ideal): the
        // impaired run's arrivals happen strictly later, by exactly the
        // per-link downlink delay, and the draw reproduces bitwise.
        let run = |profile: SimProfile| {
            let n = 3;
            let mut inner = Scripted::new(n);
            for wid in 0..n {
                inner.push_uplink(wid, 0, 4);
            }
            let mut sim = Sim::new(inner, 13, profile);
            dispatch_all(&mut sim, n, 0);
            let _ = delivered_wids(&mut sim, n);
            (sim.now_us, sim.link_stats())
        };
        let mut profile = SimProfile::parse("wan").unwrap();
        profile.drop_prob = 0.0; // isolate the delay terms
        let (clock_a, stats_a) = run(profile);
        let (clock_b, stats_b) = run(profile);
        assert_eq!(clock_a, clock_b);
        assert_eq!(stats_a, stats_b);
        for l in &stats_a {
            assert!(l.downlink_delay_us >= profile.latency_us);
        }
        // Ideal: downlink leg free, and the whole schedule collapses to
        // zero — the transparency the bitwise gate relies on.
        let (clock_ideal, stats_ideal) = run(SimProfile::parse("ideal").unwrap());
        assert_eq!(clock_ideal, 0);
        assert!(stats_ideal.iter().all(|l| l.downlink_delay_us == 0));
        assert!(clock_a > clock_ideal);
    }

    #[test]
    fn recv_without_dispatch_is_an_error() {
        let mut sim =
            Sim::new(Scripted::new(2), 1, SimProfile::parse("ideal").unwrap());
        let err = sim.recv_event().unwrap_err().to_string();
        assert!(err.contains("no uplinks in flight"), "{err}");
    }

    #[test]
    fn profile_parse_enumerates_choices() {
        assert!(SimProfile::parse("ideal").unwrap().is_ideal());
        for name in ["lan", "wan", "lossy-wan"] {
            assert!(!SimProfile::parse(name).unwrap().is_ideal(), "{name}");
        }
        let err = SimProfile::parse("carrier-pigeon").unwrap_err().to_string();
        assert!(err.contains(SIM_PROFILE_CHOICES), "{err}");
    }
}
