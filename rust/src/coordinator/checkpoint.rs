//! Checkpointing: persist and resume the leader's training state.
//!
//! Layout on disk (a directory):
//!   `state.json` — round counter, config echo, dims, RNG-free metadata
//!   `theta.bin`  — little-endian f32 parameters
//!   `opt.bin`    — concatenated optimizer state vectors (m | v | v̂)
//!
//! Worker error-feedback residuals are *not* persisted: Algorithm 2's
//! residuals are bounded (Lemma 2) and re-warm within ~1/(1-β1) rounds;
//! restarting with e=0 is the standard practical choice (documented so
//! resumed curves are reproducible given the same seeds).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    pub model: String,
    pub algo: String,
    pub theta: Vec<f32>,
    /// Optimizer state vectors, each theta-sized (AMSGrad: [m, v, vhat]).
    pub opt_state: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let meta = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("round", Json::num(self.round as f64)),
            ("model", Json::str(&self.model)),
            ("algo", Json::str(&self.algo)),
            ("p", Json::num(self.theta.len() as f64)),
            ("opt_vectors", Json::num(self.opt_state.len() as f64)),
        ]);
        std::fs::write(dir.join("state.json"), meta.to_string_pretty())?;
        std::fs::write(dir.join("theta.bin"), f32s_to_bytes(&self.theta))?;
        let mut opt = Vec::new();
        for v in &self.opt_state {
            ensure!(v.len() == self.theta.len(), "opt vector dim mismatch");
            opt.extend_from_slice(&f32s_to_bytes(v));
        }
        std::fs::write(dir.join("opt.bin"), opt)?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let meta = json::parse(
            &std::fs::read_to_string(dir.join("state.json"))
                .with_context(|| format!("reading {}", dir.join("state.json").display()))?,
        )?;
        ensure!(meta.req("version")?.as_usize()? == 1, "unsupported checkpoint version");
        let p = meta.req("p")?.as_usize()?;
        let nopt = meta.req("opt_vectors")?.as_usize()?;
        let theta = bytes_to_f32s(&std::fs::read(dir.join("theta.bin"))?)?;
        ensure!(theta.len() == p, "theta.bin length {} != p {p}", theta.len());
        let opt_raw = bytes_to_f32s(&std::fs::read(dir.join("opt.bin"))?)?;
        ensure!(opt_raw.len() == nopt * p, "opt.bin length mismatch");
        let opt_state = opt_raw.chunks(p).map(|c| c.to_vec()).collect();
        Ok(Checkpoint {
            round: meta.req("round")?.as_usize()? as u64,
            model: meta.req("model")?.as_str()?.to_string(),
            algo: meta.req("algo")?.as_str()?.to_string(),
            theta,
            opt_state,
        })
    }
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    ensure!(b.len() % 4 == 0, "binary length not a multiple of 4");
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "comp_ams_ckpt_{}",
            std::process::id() as u64 ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .subsec_nanos() as u64
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact() {
        let dir = tmp();
        let ck = Checkpoint {
            round: 42,
            model: "mnist_cnn".into(),
            algo: "comp-ams-topk:0.01".into(),
            theta: vec![1.5, -2.25, 0.0],
            opt_state: vec![vec![0.1, 0.2, 0.3], vec![1.0, 2.0, 3.0]],
        };
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_theta_rejected() {
        let dir = tmp();
        let ck = Checkpoint {
            round: 1,
            model: "m".into(),
            algo: "a".into(),
            theta: vec![1.0; 8],
            opt_state: vec![vec![0.0; 8]],
        };
        ck.save(&dir).unwrap();
        // Truncate theta.bin.
        let raw = std::fs::read(dir.join("theta.bin")).unwrap();
        std::fs::write(dir.join("theta.bin"), &raw[..raw.len() - 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent/ckpt")).is_err());
    }
}
