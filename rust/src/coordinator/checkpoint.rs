//! Checkpointing: persist and resume the leader's training state.
//!
//! Layout on disk (a directory):
//!   `state.json` — round counter, config echo, dims, RNG-free metadata
//!   `theta.bin`  — little-endian f32 parameters
//!   `opt.bin`    — concatenated optimizer state vectors (m | v | v̂)
//!
//! Worker error-feedback residuals are *not* persisted in the on-disk
//! [`Checkpoint`]: Algorithm 2's residuals are bounded (Lemma 2) and
//! re-warm within ~1/(1-β1) rounds; restarting with e=0 is the standard
//! practical choice (documented so resumed curves are reproducible given
//! the same seeds).
//!
//! The in-memory [`JobCheckpoint`] used by the scheduler
//! ([`crate::coordinator::scheduler`]) is stronger: it carries the full
//! per-worker state blobs (error-feedback residuals, compressor RNGs,
//! mini-batch streams) plus the server optimizer state and the job's
//! accounting so far, so a preempted job resumes **bitwise identically**
//! to an uninterrupted run — property-tested across every protocol.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::config::TrainConfig;
use crate::util::json::{self, Json};

use super::comm::CommLedger;
use super::metrics::RoundMetric;

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    pub model: String,
    pub algo: String,
    pub theta: Vec<f32>,
    /// Optimizer state vectors, each theta-sized (AMSGrad: [m, v, vhat]).
    pub opt_state: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let meta = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("round", Json::num(self.round as f64)),
            ("model", Json::str(&self.model)),
            ("algo", Json::str(&self.algo)),
            ("p", Json::num(self.theta.len() as f64)),
            ("opt_vectors", Json::num(self.opt_state.len() as f64)),
        ]);
        std::fs::write(dir.join("state.json"), meta.to_string_pretty())?;
        std::fs::write(dir.join("theta.bin"), f32s_to_bytes(&self.theta))?;
        let mut opt = Vec::new();
        for v in &self.opt_state {
            ensure!(v.len() == self.theta.len(), "opt vector dim mismatch");
            opt.extend_from_slice(&f32s_to_bytes(v));
        }
        std::fs::write(dir.join("opt.bin"), opt)?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let meta = json::parse(
            &std::fs::read_to_string(dir.join("state.json"))
                .with_context(|| format!("reading {}", dir.join("state.json").display()))?,
        )?;
        ensure!(meta.req("version")?.as_usize()? == 1, "unsupported checkpoint version");
        let p = meta.req("p")?.as_usize()?;
        let nopt = meta.req("opt_vectors")?.as_usize()?;
        let theta = bytes_to_f32s(&std::fs::read(dir.join("theta.bin"))?)?;
        ensure!(theta.len() == p, "theta.bin length {} != p {p}", theta.len());
        let opt_raw = bytes_to_f32s(&std::fs::read(dir.join("opt.bin"))?)?;
        ensure!(opt_raw.len() == nopt * p, "opt.bin length mismatch");
        let opt_state = opt_raw.chunks(p).map(|c| c.to_vec()).collect();
        Ok(Checkpoint {
            round: meta.req("round")?.as_usize()? as u64,
            model: meta.req("model")?.as_str()?.to_string(),
            algo: meta.req("algo")?.as_str()?.to_string(),
            theta,
            opt_state,
        })
    }
}

/// Full in-memory snapshot of a suspended training job.
///
/// Produced by [`Trainer::suspend`](super::trainer::Trainer::suspend) and
/// consumed by [`Trainer::resume`](super::trainer::Trainer::resume) (or
/// [`Trainer::with_transport`](super::trainer::Trainer::with_transport)
/// when the scheduler re-assigns a pooled fleet). Unlike the on-disk
/// [`Checkpoint`], this captures *everything* the trajectory depends on:
/// worker error-feedback residuals, compressor RNG streams, mini-batch
/// RNG streams, and the server optimizer moments — so resuming at round
/// `round` replays the exact bytes an uninterrupted run would have
/// produced. It also carries the job's ledger and metrics so far, so the
/// final [`RunResult`](super::metrics::RunResult) of a
/// preempted-then-resumed job accounts for the whole job, not just the
/// post-resume tail.
#[derive(Clone, Debug)]
pub struct JobCheckpoint {
    /// Next round to run (rounds `0..round` are already accounted in
    /// `metrics`).
    pub round: u64,
    pub cfg: TrainConfig,
    pub theta: Vec<f32>,
    /// Server optimizer blob ([`ServerAlgo::export_state`](crate::algo::ServerAlgo::export_state)).
    pub server: Vec<u8>,
    /// Per-worker state blobs, indexed by wid
    /// ([`export_worker_blob`](super::cluster::export_worker_blob)).
    pub workers: Vec<Vec<u8>>,
    /// Communication accounting up to the suspension point.
    pub ledger: CommLedger,
    /// Round metrics up to the suspension point.
    pub metrics: Vec<RoundMetric>,
    pub worker_ms_total: f64,
    pub round_ms_total: f64,
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    ensure!(b.len() % 4 == 0, "binary length not a multiple of 4");
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "comp_ams_ckpt_{}",
            std::process::id() as u64 ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .subsec_nanos() as u64
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact() {
        let dir = tmp();
        let ck = Checkpoint {
            round: 42,
            model: "mnist_cnn".into(),
            algo: "comp-ams-topk:0.01".into(),
            theta: vec![1.5, -2.25, 0.0],
            opt_state: vec![vec![0.1, 0.2, 0.3], vec![1.0, 2.0, 3.0]],
        };
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_theta_rejected() {
        let dir = tmp();
        let ck = Checkpoint {
            round: 1,
            model: "m".into(),
            algo: "a".into(),
            theta: vec![1.0; 8],
            opt_state: vec![vec![0.0; 8]],
        };
        ck.save(&dir).unwrap();
        // Truncate theta.bin.
        let raw = std::fs::read(dir.join("theta.bin")).unwrap();
        std::fs::write(dir.join("theta.bin"), &raw[..raw.len() - 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent/ckpt")).is_err());
    }

    /// A θ/optimizer state whose bytes exercise the awkward f32 corners:
    /// signed zeros, subnormals, extremes, and values that differ only in
    /// the sign bit.
    fn awkward_checkpoint() -> Checkpoint {
        let theta = vec![
            0.0f32,
            -0.0,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1.0e-40, // subnormal
            f32::MAX,
            -f32::MAX,
            1.5,
        ];
        let m: Vec<f32> = theta.iter().map(|x| x * 0.5).collect();
        let v: Vec<f32> = theta.iter().map(|x| x.abs()).collect();
        let vhat = v.clone();
        Checkpoint {
            round: 1_234_567,
            model: "quadratic".into(),
            algo: "comp-ams-blocksign:64".into(),
            theta,
            opt_state: vec![m, v, vhat],
        }
    }

    #[test]
    fn roundtrip_is_bitwise_on_theta_and_optimizer_state() {
        // PartialEq on f32 conflates 0.0 == -0.0; the resume guarantee is
        // stronger — every byte of θ and every optimizer vector survives.
        let dir = tmp();
        let ck = awkward_checkpoint();
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.round, ck.round);
        assert_eq!(back.model, "quadratic");
        assert_eq!(back.algo, "comp-ams-blocksign:64");
        for (i, (a, b)) in ck.theta.iter().zip(&back.theta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "θ[{i}]");
        }
        assert_eq!(back.opt_state.len(), ck.opt_state.len());
        for (k, (va, vb)) in ck.opt_state.iter().zip(&back.opt_state).enumerate() {
            for (i, (a, b)) in va.iter().zip(vb).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "opt[{k}][{i}]");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_and_padded_opt_state_rejected() {
        let dir = tmp();
        let ck = awkward_checkpoint();
        ck.save(&dir).unwrap();
        let raw = std::fs::read(dir.join("opt.bin")).unwrap();
        // Whole missing vector, non-multiple-of-4 tail, trailing garbage.
        std::fs::write(dir.join("opt.bin"), &raw[..raw.len() - 4 * ck.theta.len()]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        std::fs::write(dir.join("opt.bin"), &raw[..raw.len() - 3]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        let mut padded = raw.clone();
        padded.extend_from_slice(&[0u8; 4]);
        std::fs::write(dir.join("opt.bin"), &padded).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // Restoring the original bytes loads cleanly again.
        std::fs::write(dir.join("opt.bin"), &raw).unwrap();
        Checkpoint::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_metadata_rejected() {
        let dir = tmp();
        let ck = awkward_checkpoint();
        ck.save(&dir).unwrap();
        let meta = std::fs::read_to_string(dir.join("state.json")).unwrap();
        // Unparseable JSON.
        std::fs::write(dir.join("state.json"), &meta[..meta.len() / 2]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // Unsupported version.
        std::fs::write(dir.join("state.json"), meta.replace("\"version\": 1", "\"version\": 9"))
            .unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // p disagreeing with theta.bin.
        std::fs::write(
            dir.join("state.json"),
            meta.replace(
                &format!("\"p\": {}", ck.theta.len()),
                &format!("\"p\": {}", ck.theta.len() + 1),
            ),
        )
        .unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // opt_vectors disagreeing with opt.bin.
        std::fs::write(
            dir.join("state.json"),
            meta.replace("\"opt_vectors\": 3", "\"opt_vectors\": 2"),
        )
        .unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // Missing required key.
        std::fs::write(dir.join("state.json"), meta.replace("\"round\"", "\"wrong\"")).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // Original metadata still loads.
        std::fs::write(dir.join("state.json"), &meta).unwrap();
        Checkpoint::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_rejects_mismatched_opt_vector_dims() {
        let dir = tmp();
        let ck = Checkpoint {
            round: 0,
            model: "m".into(),
            algo: "a".into(),
            theta: vec![1.0; 4],
            opt_state: vec![vec![0.0; 3]],
        };
        assert!(ck.save(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
