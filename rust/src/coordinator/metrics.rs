//! Run metrics: what every experiment driver records and the CSV schema
//! all figures are regenerated from.

use crate::grad::EvalStats;

use super::sim::LinkStats;

#[derive(Clone, Debug)]
pub struct RoundMetric {
    pub round: u64,
    /// Fractional epoch (round / rounds_per_epoch).
    pub epoch: f32,
    /// Mean worker training loss this round.
    pub train_loss: f32,
    /// Held-out stats if this was an eval round.
    pub eval: Option<EvalStats>,
    /// Cumulative uplink bits so far.
    pub uplink_bits: u64,
    /// Cumulative downlink bits so far.
    pub downlink_bits: u64,
    pub lr: f32,
    pub wall_ms: f64,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub algo: String,
    pub model: String,
    pub workers: usize,
    pub metrics: Vec<RoundMetric>,
    pub final_eval: EvalStats,
    pub total_wall_ms: f64,
    /// Mean leader-side (non-worker-pipeline) share of round time,
    /// clamped to [0, 1] (timer jitter must not report a negative or
    /// super-unit leader share).
    pub coord_overhead: f64,
    /// Straggler uplinks applied as stale gradients across the run
    /// (nonzero only with `--quorum` K < n).
    pub stale_uplinks: u64,
    /// Straggler uplinks past `--max-staleness`, dropped unapplied —
    /// including a crashed worker's never-to-arrive uplinks.
    pub dropped_uplinks: u64,
    /// Transport framing overhead in bits (envelope + socket frame
    /// headers), billed separately so `uplink_bits` stays
    /// transport-invariant. Zero for `inproc`.
    pub framing_bits: u64,
    /// Dead workers re-admitted mid-run (replacement processes that
    /// HELLO'd back into a dead wid). Zero for a run without deaths.
    pub rejoins: u64,
    /// Worker deaths that zeroed a live error-feedback accumulator (the
    /// residual dies with the worker process; a rejoiner restarts from
    /// `e = 0`). Zero for EF-free protocols.
    pub ef_resets: u64,
    /// Bits of EF accumulator state lost to those deaths (32·d per
    /// reset) — dropped gradient mass the run reports instead of hiding.
    pub ef_residual_lost_bits: u64,
    /// Cumulative uplink bits per worker id — the Figure-2-style
    /// per-worker communication breakdown. Includes the end-of-run
    /// straggler uplinks drained after the last round (K < n only),
    /// which post-date the final round metric's `uplink_bits`.
    pub uplink_bits_by_worker: Vec<u64>,
    /// Cumulative uplink bits routed to each server shard after payload
    /// slicing (empty for an unsharded server).
    pub uplink_bits_by_shard: Vec<u64>,
    /// Cumulative uplink bits per topology level: index 0 is the hop
    /// into the leader (root), index 1 the worker ↔ sub-leader hop of a
    /// `--topology tree` run. Entries sum exactly to the headline
    /// `uplink_bits`; a flat run has only index 0.
    pub uplink_bits_by_level: Vec<u64>,
    /// Cumulative downlink bits per topology level (see
    /// `uplink_bits_by_level`).
    pub downlink_bits_by_level: Vec<u64>,
    /// Cumulative framing bits per topology level (see
    /// `uplink_bits_by_level`).
    pub framing_bits_by_level: Vec<u64>,
    /// Cumulative wall-clock ms spent inside each server shard's update
    /// (empty for an unsharded server).
    pub server_ms_by_shard: Vec<f64>,
    /// Per-link delivery statistics from the seeded network simulator,
    /// one entry per worker id (delivered / drops / reordered /
    /// cumulative virtual delay). Deterministic from `--sim-seed` +
    /// `--sim-profile`; empty for runs over real transports.
    pub sim_links: Vec<LinkStats>,
}

impl RunResult {
    /// First round whose train loss (smoothed over a window) drops below
    /// `target`. Used by the Fig. 3 speedup analysis.
    pub fn rounds_to_loss(&self, target: f32, window: usize) -> Option<u64> {
        if self.metrics.is_empty() {
            return None;
        }
        let w = window.max(1);
        let mut acc = 0.0f32;
        let mut buf = std::collections::VecDeque::new();
        for m in &self.metrics {
            buf.push_back(m.train_loss);
            acc += m.train_loss;
            if buf.len() > w {
                acc -= buf.pop_front().unwrap();
            }
            if buf.len() == w && acc / w as f32 <= target {
                return Some(m.round);
            }
        }
        None
    }

    /// Final train loss (smoothed over the last `window` rounds).
    pub fn final_train_loss(&self, window: usize) -> f32 {
        let n = self.metrics.len();
        if n == 0 {
            return f32::NAN;
        }
        let w = window.clamp(1, n);
        self.metrics[n - w..].iter().map(|m| m.train_loss).sum::<f32>() / w as f32
    }

    pub fn uplink_bits(&self) -> u64 {
        self.metrics.last().map(|m| m.uplink_bits).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(round: u64, loss: f32) -> RoundMetric {
        RoundMetric {
            round,
            epoch: 0.0,
            train_loss: loss,
            eval: None,
            uplink_bits: round * 100,
            downlink_bits: 0,
            lr: 0.1,
            wall_ms: 1.0,
        }
    }

    fn run(losses: &[f32]) -> RunResult {
        RunResult {
            algo: "x".into(),
            model: "m".into(),
            workers: 1,
            metrics: losses
                .iter()
                .enumerate()
                .map(|(i, &l)| metric(i as u64, l))
                .collect(),
            final_eval: EvalStats { loss: 0.0, accuracy: 0.0 },
            total_wall_ms: 0.0,
            coord_overhead: 0.0,
            stale_uplinks: 0,
            dropped_uplinks: 0,
            framing_bits: 0,
            rejoins: 0,
            ef_resets: 0,
            ef_residual_lost_bits: 0,
            uplink_bits_by_worker: Vec::new(),
            uplink_bits_by_shard: Vec::new(),
            uplink_bits_by_level: Vec::new(),
            downlink_bits_by_level: Vec::new(),
            framing_bits_by_level: Vec::new(),
            server_ms_by_shard: Vec::new(),
            sim_links: Vec::new(),
        }
    }

    #[test]
    fn rounds_to_loss_finds_crossing() {
        let r = run(&[5.0, 4.0, 3.0, 2.0, 1.0, 0.5]);
        assert_eq!(r.rounds_to_loss(2.0, 1), Some(3));
        assert_eq!(r.rounds_to_loss(0.1, 1), None);
    }

    #[test]
    fn smoothing_window_filters_spikes() {
        let r = run(&[5.0, 0.1, 5.0, 2.0, 2.0, 2.0]);
        // window 1 triggers on the spike; window 3 waits until the
        // 3-round mean crosses (round 3: mean(0.1, 5, 2) = 2.37 <= 3).
        assert_eq!(r.rounds_to_loss(1.0, 1), Some(1));
        assert_eq!(r.rounds_to_loss(3.0, 3), Some(3));
        assert_eq!(r.rounds_to_loss(2.1, 3), Some(5));
    }

    #[test]
    fn final_train_loss_windows() {
        let r = run(&[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(r.final_train_loss(2), 1.5);
        assert_eq!(r.final_train_loss(100), 2.5);
    }
}
