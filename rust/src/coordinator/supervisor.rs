//! Process supervisor: spawn, monitor, and reap worker daemons.
//!
//! `--spawn-workers` turns the leader into a one-command cluster: the
//! supervisor launches `cfg.workers` copies of this binary's `worker`
//! subcommand (`std::process::Command::new(current_exe)`), each of which
//! connects back to the leader's TCP listener, handshakes, and runs the
//! decode → `process` → encode loop ([`super::worker`]).
//!
//! Failure handling is deliberately thin, because the runtime already
//! has the right machinery: a dead child's socket closes, the TCP reader
//! surfaces [`Event::Exit`](super::transport::Event::Exit), and the
//! [`ClusterRuntime`](super::runtime::ClusterRuntime) turns the worker
//! into a *permanent straggler* — the quorum keeps stepping and the
//! absence is accounted in `dropped_uplinks`. The supervisor's jobs are
//! the process-table ones: spawn with the right argv, report exits
//! ([`Supervisor::poll_exits`]), kill on demand (fault injection /
//! abort), and reap everything at end of run so no zombies outlive the
//! leader.
//!
//! Tests (whose `current_exe` is the test harness, not `comp-ams`) point
//! the supervisor at the real launcher via the `COMP_AMS_WORKER_BIN`
//! environment variable.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

/// Environment variable overriding the spawned worker binary (defaults
/// to `current_exe`; needed by integration tests).
pub const WORKER_BIN_ENV: &str = "COMP_AMS_WORKER_BIN";

/// The program to spawn workers from.
fn worker_program() -> Result<PathBuf> {
    match std::env::var_os(WORKER_BIN_ENV) {
        Some(p) => Ok(PathBuf::from(p)),
        None => std::env::current_exe().context("resolving current_exe for worker spawn"),
    }
}

struct Slot {
    child: Child,
    /// Set once the exit has been observed (by poll/kill/reap).
    exited: bool,
}

/// Owns the worker child processes for one training run.
pub struct Supervisor {
    children: Vec<Slot>,
}

impl Supervisor {
    /// Spawn `n` workers pointed at `leader` (`HOST:PORT`).
    pub fn spawn(n: usize, leader: &str) -> Result<Supervisor> {
        Self::spawn_with(n, leader, |_| Vec::new())
    }

    /// Like [`Supervisor::spawn`], with per-child extra argv (fault
    /// injection in tests, e.g. `--exit-after R`). `extra(i)` is keyed by
    /// spawn index — note a child's `wid` is assigned by the leader in
    /// *accept* order, which need not match spawn order.
    pub fn spawn_with(
        n: usize,
        leader: &str,
        extra: impl Fn(usize) -> Vec<String>,
    ) -> Result<Supervisor> {
        ensure!(n > 0, "supervisor needs at least one worker to spawn");
        let program = worker_program()?;
        let mut children = Vec::with_capacity(n);
        for i in 0..n {
            let child = Command::new(&program)
                .arg("worker")
                .arg("--leader")
                .arg(leader)
                .args(extra(i))
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                // stderr is inherited: worker panics/errors stay visible.
                .spawn()
                .with_context(|| {
                    format!("spawning worker {i} from {}", program.display())
                })?;
            children.push(Slot { child, exited: false });
        }
        Ok(Supervisor { children })
    }

    pub fn len(&self) -> usize {
        self.children.len()
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Spawn indexes of children newly observed to have exited since the
    /// last poll (crashed or finished).
    pub fn poll_exits(&mut self) -> Result<Vec<usize>> {
        let mut out = Vec::new();
        for (i, slot) in self.children.iter_mut().enumerate() {
            if slot.exited {
                continue;
            }
            if slot.child.try_wait()?.is_some() {
                slot.exited = true;
                out.push(i);
            }
        }
        Ok(out)
    }

    /// Children not yet observed to have exited.
    pub fn alive(&mut self) -> Result<usize> {
        self.poll_exits()?;
        Ok(self.children.iter().filter(|s| !s.exited).count())
    }

    /// Kill child `i` (fault injection, or aborting a hung worker).
    pub fn kill(&mut self, i: usize) -> Result<()> {
        let slot = self
            .children
            .get_mut(i)
            .with_context(|| format!("no child {i} to kill"))?;
        if !slot.exited {
            slot.child.kill().ok(); // already-dead is fine
            slot.child.wait()?;
            slot.exited = true;
        }
        Ok(())
    }

    /// Wait up to `grace` for every child to exit on its own (they do,
    /// once the leader broadcasts SHUTDOWN), then kill and wait the
    /// stragglers. Returns how many exited with a non-zero status (a
    /// crashed-then-restarted-as-straggler worker is *expected* to be
    /// non-zero; the caller decides whether that matters).
    pub fn reap(&mut self, grace: Duration) -> Result<usize> {
        let deadline = Instant::now() + grace;
        loop {
            self.poll_exits()?;
            if self.children.iter().all(|s| s.exited) || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let mut nonzero = 0usize;
        for slot in self.children.iter_mut() {
            if !slot.exited {
                slot.child.kill().ok();
            }
            // wait() reaps; for already-exited children it returns the
            // recorded status without blocking.
            let status = slot.child.wait()?;
            slot.exited = true;
            if !status.success() {
                nonzero += 1;
            }
        }
        Ok(nonzero)
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Never leave orphaned worker processes behind, whatever path
        // dropped us (including a poisoned-runtime error unwind).
        for slot in self.children.iter_mut() {
            if !slot.exited {
                slot.child.kill().ok();
                let _ = slot.child.wait();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_rejected() {
        assert!(Supervisor::spawn(0, "127.0.0.1:1").is_err());
    }

    #[test]
    fn spawn_kill_and_reap_leave_no_zombies() {
        // `current_exe` here is the unit-test binary; give it an argv that
        // makes it exit quickly (the test harness treats "worker" as a
        // filter matching nothing). This only exercises the process
        // table, not the worker protocol — tests/multiprocess.rs does that
        // against the real launcher.
        let mut sup = Supervisor::spawn(2, "127.0.0.1:1").unwrap();
        assert_eq!(sup.len(), 2);
        sup.kill(0).unwrap();
        let nonzero = sup.reap(Duration::from_secs(10)).unwrap();
        assert!(nonzero <= 2);
        assert_eq!(sup.alive().unwrap(), 0);
    }
}
