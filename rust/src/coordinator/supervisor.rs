//! Process supervisor: spawn, monitor, restart, and reap worker daemons.
//!
//! `--spawn-workers` turns the leader into a one-command cluster: the
//! supervisor launches `cfg.workers` copies of this binary's `worker`
//! subcommand (`std::process::Command::new(current_exe)`), each of which
//! connects back to the leader's TCP listener, handshakes, and runs the
//! decode → `process` → encode loop ([`super::worker`]).
//!
//! Transport-level failure handling stays where it belongs: a dead
//! child's socket closes, the TCP reader surfaces
//! [`Event::Exit`](super::transport::Event::Exit), and the
//! [`ClusterRuntime`](super::runtime::ClusterRuntime) sidelines the
//! worker while the quorum keeps stepping. The supervisor owns the
//! *process-table* half of fault tolerance: when a child exits nonzero
//! and a [`RestartPolicy`] is armed, it respawns the child after an
//! exponentially backed-off, jittered delay (capped attempts, capped
//! delay) — the replacement connects back to the leader's listen socket
//! and rejoins its wid through the normal HELLO → ASSIGN handshake
//! ([`Transport::try_rejoin`](super::transport::Transport::try_rejoin)).
//! Restarting is **polled**, not threaded: drive [`Supervisor::tick`]
//! from the round loop. Clean (zero) exits are never restarted — that is
//! how workers leave after a SHUTDOWN. Nonzero exit codes are recorded
//! ([`Supervisor::nonzero_exits`]) and reported per child by
//! [`Supervisor::reap`], so a crash is attributable after the run.
//!
//! Tests (whose `current_exe` is the test harness, not `comp-ams`) point
//! the supervisor at the real launcher via the `COMP_AMS_WORKER_BIN`
//! environment variable.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::rng::Rng;

/// Environment variable overriding the spawned worker binary (defaults
/// to `current_exe`; needed by integration tests).
pub const WORKER_BIN_ENV: &str = "COMP_AMS_WORKER_BIN";

/// The program to spawn workers from.
fn worker_program() -> Result<PathBuf> {
    match std::env::var_os(WORKER_BIN_ENV) {
        Some(p) => Ok(PathBuf::from(p)),
        None => std::env::current_exe().context("resolving current_exe for worker spawn"),
    }
}

/// Restart-with-backoff policy for crashed (nonzero-exit) children.
/// Attempt k (0-based) is delayed `min(base_delay · 2^k, max_delay)`
/// plus up to 25% deterministic jitter, so a crash-looping fleet does
/// not hammer the leader's listen socket in lockstep.
#[derive(Clone, Copy, Debug)]
pub struct RestartPolicy {
    /// Restart attempts per child slot before giving up on it.
    pub max_restarts: u32,
    /// Delay before the first restart attempt.
    pub base_delay: Duration,
    /// Ceiling on the exponential delay.
    pub max_delay: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 3,
            base_delay: Duration::from_millis(250),
            max_delay: Duration::from_secs(10),
        }
    }
}

impl RestartPolicy {
    /// Backoff before restart attempt `prior_restarts` (0-based), before
    /// jitter: `min(base · 2^k, max)`.
    pub fn delay_for(&self, prior_restarts: u32) -> Duration {
        let factor = 1u32.checked_shl(prior_restarts.min(31)).unwrap_or(u32::MAX);
        self.base_delay
            .checked_mul(factor)
            .unwrap_or(self.max_delay)
            .min(self.max_delay)
    }
}

/// One child's final status, as returned by [`Supervisor::reap`]: the
/// exit code travels with the slot index so a crash (e.g. a fault
/// injection's status 17) is attributable after the run.
#[derive(Debug)]
pub struct ExitReport {
    /// Spawn index (not necessarily the leader-assigned wid).
    pub slot: usize,
    pub status: ExitStatus,
}

struct Slot {
    child: Child,
    /// Set once the exit has been observed (by poll/kill/reap).
    exited: bool,
    /// Extra argv this slot was originally spawned with.
    extra: Vec<String>,
    /// Replacement extra argv for restarts (lets tests drop a
    /// fault-injection flag like `--exit-after` so the replacement does
    /// not immediately re-crash). `None` = reuse `extra`.
    restart_extra: Option<Vec<String>>,
    /// Restart attempts consumed so far.
    restarts: u32,
    /// When the next restart attempt is due (`None` = none scheduled).
    next_attempt: Option<Instant>,
}

/// Owns the worker child processes for one training run.
pub struct Supervisor {
    program: PathBuf,
    leader: String,
    children: Vec<Slot>,
    /// Armed restart policy; `None` (the default) = one-shot children,
    /// exactly the pre-restart behaviour.
    policy: Option<RestartPolicy>,
    /// Every nonzero exit observed, as `(slot, exit code)` — kept across
    /// restarts, so the history survives even when a slot's current
    /// child later exits cleanly.
    failures: Vec<(usize, Option<i32>)>,
    /// Deterministic jitter source for restart delays.
    rng: Rng,
}

impl Supervisor {
    /// Spawn `n` workers pointed at `leader` (`HOST:PORT`).
    pub fn spawn(n: usize, leader: &str) -> Result<Supervisor> {
        Self::spawn_with(n, leader, |_| Vec::new())
    }

    /// Like [`Supervisor::spawn`], with per-child extra argv (fault
    /// injection in tests, e.g. `--exit-after R`). `extra(i)` is keyed by
    /// spawn index — note a child's `wid` is assigned by the leader in
    /// *accept* order, which need not match spawn order.
    pub fn spawn_with(
        n: usize,
        leader: &str,
        extra: impl Fn(usize) -> Vec<String>,
    ) -> Result<Supervisor> {
        Self::spawn_inner(worker_program()?, n, leader, extra)
    }

    fn spawn_inner(
        program: PathBuf,
        n: usize,
        leader: &str,
        extra: impl Fn(usize) -> Vec<String>,
    ) -> Result<Supervisor> {
        ensure!(n > 0, "supervisor needs at least one worker to spawn");
        let mut children = Vec::with_capacity(n);
        for i in 0..n {
            let argv = extra(i);
            let child = spawn_child(&program, leader, &argv)
                .with_context(|| format!("spawning worker {i} from {}", program.display()))?;
            children.push(Slot {
                child,
                exited: false,
                extra: argv,
                restart_extra: None,
                restarts: 0,
                next_attempt: None,
            });
        }
        Ok(Supervisor {
            program,
            leader: leader.to_string(),
            children,
            policy: None,
            failures: Vec::new(),
            rng: Rng::seed(0x5EED_0F_5EED),
        })
    }

    /// Arm restart-with-backoff for children that exit nonzero. Without
    /// a policy the supervisor is one-shot: a crashed child stays down.
    pub fn set_restart_policy(&mut self, policy: RestartPolicy) {
        self.policy = Some(policy);
    }

    /// Override the extra argv used when restarting slot `i` (e.g. drop
    /// a `--exit-after` fault flag so the replacement runs clean).
    pub fn set_restart_argv(&mut self, i: usize, extra: Vec<String>) -> Result<()> {
        let slot = self
            .children
            .get_mut(i)
            .with_context(|| format!("no child {i} to set restart argv for"))?;
        slot.restart_extra = Some(extra);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.children.len()
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Every nonzero child exit observed so far, as `(slot, exit code)`
    /// (`None` = killed by signal). History — not reset by restarts.
    pub fn nonzero_exits(&self) -> &[(usize, Option<i32>)] {
        &self.failures
    }

    /// Spawn indexes of children newly observed to have exited since the
    /// last poll (crashed or finished). Nonzero exits are recorded in
    /// [`Supervisor::nonzero_exits`] and — when a [`RestartPolicy`] is
    /// armed — schedule a backed-off restart attempt (executed by
    /// [`Supervisor::tick`]).
    pub fn poll_exits(&mut self) -> Result<Vec<usize>> {
        let mut out = Vec::new();
        for i in 0..self.children.len() {
            if self.children[i].exited {
                continue;
            }
            let Some(status) = self.children[i].child.try_wait()? else {
                continue;
            };
            self.children[i].exited = true;
            out.push(i);
            if !status.success() {
                self.failures.push((i, status.code()));
                eprintln!(
                    "[supervisor] worker slot {i} exited with {status}{}",
                    if self.policy.is_some() { "" } else { " (no restart policy)" }
                );
                self.schedule_restart(i);
            }
        }
        Ok(out)
    }

    /// Schedule slot `i`'s next restart attempt under the armed policy
    /// (no-op without one, or once the slot's attempts are exhausted).
    fn schedule_restart(&mut self, i: usize) {
        let Some(policy) = self.policy else { return };
        let slot = &mut self.children[i];
        if slot.restarts >= policy.max_restarts {
            eprintln!(
                "[supervisor] worker slot {i}: giving up after {} restart attempts",
                slot.restarts
            );
            return;
        }
        let base = policy.delay_for(slot.restarts);
        let jitter = base.mul_f64(0.25 * self.rng.next_f64());
        slot.restarts += 1;
        slot.next_attempt = Some(Instant::now() + base + jitter);
    }

    /// Drive the restart machinery one step: observe exits, then respawn
    /// every slot whose backoff delay has elapsed. Call this from the
    /// round loop (it is cheap — one `try_wait` per child). Returns how
    /// many children were respawned. A failed respawn consumes the
    /// attempt and schedules the next one rather than erroring: one bad
    /// exec must not kill an otherwise healthy run.
    pub fn tick(&mut self) -> Result<usize> {
        self.poll_exits()?;
        let mut respawned = 0usize;
        for i in 0..self.children.len() {
            let due = self.children[i]
                .next_attempt
                .is_some_and(|t| Instant::now() >= t);
            if !due {
                continue;
            }
            self.children[i].next_attempt = None;
            let argv = self.children[i]
                .restart_extra
                .clone()
                .unwrap_or_else(|| self.children[i].extra.clone());
            match spawn_child(&self.program, &self.leader, &argv) {
                Ok(child) => {
                    let slot = &mut self.children[i];
                    slot.child = child;
                    slot.exited = false;
                    eprintln!(
                        "[supervisor] restarted worker slot {i} (attempt {})",
                        slot.restarts
                    );
                    respawned += 1;
                }
                Err(e) => {
                    eprintln!("[supervisor] restart of worker slot {i} failed: {e:#}");
                    self.schedule_restart(i);
                }
            }
        }
        Ok(respawned)
    }

    /// Children not yet observed to have exited.
    pub fn alive(&mut self) -> Result<usize> {
        self.poll_exits()?;
        Ok(self.children.iter().filter(|s| !s.exited).count())
    }

    /// Kill child `i` (fault injection, or aborting a hung worker). A
    /// deliberate kill is not a crash: no restart is scheduled, and any
    /// pending restart attempt for the slot is cancelled.
    pub fn kill(&mut self, i: usize) -> Result<()> {
        let slot = self
            .children
            .get_mut(i)
            .with_context(|| format!("no child {i} to kill"))?;
        slot.next_attempt = None;
        if !slot.exited {
            slot.child.kill().ok(); // already-dead is fine
            slot.child.wait()?;
            slot.exited = true;
        }
        Ok(())
    }

    /// Wait up to `grace` for every child to exit on its own (they do,
    /// once the leader broadcasts SHUTDOWN), then kill and wait the
    /// stragglers. Restarts are disarmed first — end of run means no
    /// more respawns. Returns one [`ExitReport`] per slot with the final
    /// child's exit status, so callers can see exactly which workers
    /// crashed and with what code (a fault-injected worker's status 17,
    /// say) rather than a bare count.
    pub fn reap(&mut self, grace: Duration) -> Result<Vec<ExitReport>> {
        self.policy = None;
        for slot in self.children.iter_mut() {
            slot.next_attempt = None;
        }
        let deadline = Instant::now() + grace;
        loop {
            self.poll_exits()?;
            if self.children.iter().all(|s| s.exited) || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let mut out = Vec::with_capacity(self.children.len());
        for (i, slot) in self.children.iter_mut().enumerate() {
            if !slot.exited {
                slot.child.kill().ok();
            }
            // wait() reaps; for already-exited children it returns the
            // recorded status without blocking.
            let status = slot.child.wait()?;
            slot.exited = true;
            out.push(ExitReport { slot: i, status });
        }
        Ok(out)
    }
}

/// Spawn one worker child: `<program> worker --leader <leader> <extra>`.
fn spawn_child(program: &Path, leader: &str, extra: &[String]) -> Result<Child> {
    Ok(Command::new(program)
        .arg("worker")
        .arg("--leader")
        .arg(leader)
        .args(extra)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        // stderr is inherited: worker panics/errors stay visible.
        .spawn()?)
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Never leave orphaned worker processes behind, whatever path
        // dropped us (including a poisoned-runtime error unwind).
        for slot in self.children.iter_mut() {
            if !slot.exited {
                slot.child.kill().ok();
                let _ = slot.child.wait();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_rejected() {
        assert!(Supervisor::spawn(0, "127.0.0.1:1").is_err());
    }

    #[test]
    fn spawn_kill_and_reap_leave_no_zombies() {
        // `current_exe` here is the unit-test binary; give it an argv that
        // makes it exit quickly (the test harness treats "worker" as a
        // filter matching nothing). This only exercises the process
        // table, not the worker protocol — tests/multiprocess.rs does that
        // against the real launcher.
        let mut sup = Supervisor::spawn(2, "127.0.0.1:1").unwrap();
        assert_eq!(sup.len(), 2);
        sup.kill(0).unwrap();
        let reports = sup.reap(Duration::from_secs(10)).unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().filter(|r| !r.status.success()).count() <= 2);
        assert_eq!(sup.alive().unwrap(), 0);
    }

    #[test]
    fn backoff_delays_double_and_cap() {
        let p = RestartPolicy {
            max_restarts: 10,
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_secs(1),
        };
        assert_eq!(p.delay_for(0), Duration::from_millis(100));
        assert_eq!(p.delay_for(1), Duration::from_millis(200));
        assert_eq!(p.delay_for(2), Duration::from_millis(400));
        // Capped at max_delay from attempt 4 on (1.6s → 1s)...
        assert_eq!(p.delay_for(4), Duration::from_secs(1));
        // ...including where 2^k itself would overflow.
        assert_eq!(p.delay_for(40), Duration::from_secs(1));
    }

    #[test]
    fn crashed_child_is_restarted_up_to_the_attempt_cap() {
        // /bin/false ignores the worker argv and exits 1 immediately —
        // a deterministic crash loop. With max_restarts = 2 the slot is
        // respawned exactly twice and then given up on.
        let mut sup = Supervisor::spawn_inner(
            PathBuf::from("/bin/false"),
            1,
            "127.0.0.1:1",
            |_| Vec::new(),
        )
        .unwrap();
        sup.set_restart_policy(RestartPolicy {
            max_restarts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(5),
        });
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut respawned = 0usize;
        while Instant::now() < deadline {
            respawned += sup.tick().unwrap();
            if respawned >= 2 && sup.alive().unwrap() == 0 {
                // Both restart attempts burned and the last child exited:
                // make sure no further attempt is ever scheduled.
                assert_eq!(sup.tick().unwrap(), 0);
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(respawned, 2, "expected exactly max_restarts respawns");
        // Original + 2 restarts, every exit nonzero with code 1.
        assert_eq!(sup.nonzero_exits().len(), 3);
        assert!(sup.nonzero_exits().iter().all(|&(slot, code)| {
            slot == 0 && code == Some(1)
        }));
        let reports = sup.reap(Duration::from_secs(5)).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].status.code(), Some(1));
    }

    #[test]
    fn clean_exit_is_not_restarted() {
        // /bin/true exits 0: a clean departure (post-SHUTDOWN behaviour)
        // must never trigger the restart path.
        let mut sup = Supervisor::spawn_inner(
            PathBuf::from("/bin/true"),
            1,
            "127.0.0.1:1",
            |_| Vec::new(),
        )
        .unwrap();
        sup.set_restart_policy(RestartPolicy::default());
        let deadline = Instant::now() + Duration::from_secs(10);
        while sup.alive().unwrap() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(sup.tick().unwrap(), 0);
        assert!(sup.nonzero_exits().is_empty());
        let reports = sup.reap(Duration::from_secs(5)).unwrap();
        assert!(reports[0].status.success());
    }
}
