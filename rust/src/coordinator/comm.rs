//! Communication ledger: the exact bit counts behind Figure 2.
//!
//! Uplink (worker → server) is charged per encoded payload — the byte
//! codec's real length ([`Payload::wire_bits`](crate::compress::Payload::wire_bits)
//! `== 8 × encode().len()`), not an estimate. The runtime charges each
//! message as the leader consumes its arrival (the same value the worker
//! computed at the production site, across both transports and both
//! backends), and straggler uplinks still in flight when the run ends
//! are drained and billed too, so no transmitted message escapes the
//! ledger. Bits are recorded per worker, so Figure-2-style reporting can
//! break the uplink bill down by worker. Downlink (server → worker) is the dense θ
//! broadcast, charged **per dispatched worker per round** — under partial
//! participation ([`crate::coordinator::runtime`]) a straggler that sits
//! a round out is not billed a broadcast it never received. The paper's
//! Figure 2 x-axis is uplink bits ("bits transmitted to the central
//! server"); both directions are recorded.
//!
//! Envelope framing ([`crate::coordinator::transport::Envelope`]) is
//! *not* part of the uplink bill: the ledger charges payload wire bits
//! only, so the accounting is identical across transports (the
//! per-message header is surfaced separately via `Envelope::wire_bits`).
//!
//! Partial participation adds two counters: `stale_uplinks` (straggler
//! gradients applied late) and `dropped_uplinks` (stragglers past the
//! staleness bound, transmitted — and charged — but never applied).

#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommLedger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    /// Cumulative uplink bits per worker id (grows on first charge).
    pub uplink_bits_by_worker: Vec<u64>,
    /// Cumulative uplink bits as routed to each server shard after
    /// payload slicing — what each shard's standalone process would
    /// receive once shards live behind real transport. Empty when the
    /// server is unsharded; kept in sync from
    /// [`ShardStats`](crate::algo::sharded::ShardStats) by the trainer.
    pub uplink_bits_by_shard: Vec<u64>,
    /// Straggler uplinks applied as stale gradients (staleness ≥ 1,
    /// within the `max_staleness` bound). Zero under full quorum.
    pub stale_uplinks: u64,
    /// Straggler uplinks past the staleness bound: transmitted and
    /// charged, but discarded by the runtime instead of applied. A
    /// crashed worker's never-to-arrive uplink is also counted here (but
    /// its bits are not, since nothing crossed the wire).
    pub dropped_uplinks: u64,
    /// Transport framing bits: per-message overhead on top of the
    /// payload bill (the 16-byte `Envelope` header, plus the socket
    /// frame header on TCP), billed per consumed uplink and per
    /// dispatched downlink. Kept out of `uplink_bits` so the gradient
    /// bit accounting stays identical across transports; zero for
    /// `InProc`.
    pub framing_bits: u64,
    /// Dead workers re-admitted into the run: a replacement process
    /// HELLO'd the leader's listen socket mid-run and was re-ASSIGNed the
    /// dead wid (elastic fleet). Each rejoin restores the quorum target
    /// on the next dispatch.
    pub rejoins: u64,
    /// Worker deaths that zeroed a *live* error-feedback accumulator:
    /// the EF residual `e ∈ R^d` lives in the worker process and dies
    /// with it, so a rejoined replacement restarts from `e = 0`. Zero
    /// for protocols without worker-side EF (dist-sgd, dist-ams,
    /// `:noef`), and for runs without deaths.
    pub ef_resets: u64,
    /// Size of the EF accumulator state lost to those deaths, in bits
    /// (32·d per reset — `e` is a dense f32 d-vector). This is dropped
    /// *gradient mass the ledger can still measure*: the residual's
    /// values are unknowable post-mortem, but its extent is not, so runs
    /// with deaths report the bias instead of hiding it.
    pub ef_residual_lost_bits: u64,
    /// Per-link delivery statistics from the seeded network simulator
    /// (`--transport sim:<inner>`), one entry per worker id: uplinks
    /// delivered, seeded drops (resurfaced as retransmit delay),
    /// reorderings, and cumulative virtual delay. Mirrored from
    /// [`Sim`](crate::coordinator::sim::Sim) after every round, the way
    /// `uplink_bits_by_shard` mirrors the sharded server; empty for real
    /// transports.
    pub sim_links: Vec<crate::coordinator::sim::LinkStats>,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one worker's uplink message of `bits` wire bits.
    pub fn charge_uplink(&mut self, wid: usize, bits: u64) {
        if wid >= self.uplink_bits_by_worker.len() {
            self.uplink_bits_by_worker.resize(wid + 1, 0);
        }
        self.uplink_bits_by_worker[wid] += bits;
        self.uplink_bits += bits;
        self.uplink_msgs += 1;
    }

    /// Overwrite the per-shard routing snapshot (`routed_bits` values are
    /// already cumulative — the sharded server accumulates them at the
    /// slicing site, the way uplink bits are counted at the production
    /// site).
    pub fn sync_shard_routing(&mut self, routed_bits: &[u64]) {
        self.uplink_bits_by_shard.clear();
        self.uplink_bits_by_shard.extend_from_slice(routed_bits);
    }

    /// Overwrite the per-link simulator snapshot (stats are cumulative
    /// at the source — [`Sim`](crate::coordinator::sim::Sim) accumulates
    /// them at the delivery site).
    pub fn sync_sim_links(&mut self, links: &[crate::coordinator::sim::LinkStats]) {
        self.sim_links.clear();
        self.sim_links.extend_from_slice(links);
    }

    /// Record per-message transport framing overhead (see
    /// [`CommLedger::framing_bits`]).
    pub fn charge_framing(&mut self, bits: u64) {
        self.framing_bits += bits;
    }

    /// Dense f32 broadcast of a d-vector to `n` workers.
    pub fn charge_downlink_dense(&mut self, d: usize, n: usize) {
        self.downlink_bits += (n as u64) * 8 * (5 + 4 * d as u64);
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Payload;

    #[test]
    fn uplink_matches_payload_bits() {
        let mut l = CommLedger::new();
        let p = Payload::Dense(vec![0.0; 10]);
        l.charge_uplink(0, p.wire_bits());
        l.charge_uplink(1, p.wire_bits());
        assert_eq!(l.uplink_bits, 2 * p.wire_bits());
        assert_eq!(l.uplink_msgs, 2);
    }

    #[test]
    fn per_worker_breakdown_sums_to_total() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 100);
        l.charge_uplink(2, 300);
        l.charge_uplink(0, 50);
        assert_eq!(l.uplink_bits_by_worker, vec![150, 0, 300]);
        assert_eq!(
            l.uplink_bits_by_worker.iter().sum::<u64>(),
            l.uplink_bits
        );
        assert_eq!(l.uplink_msgs, 3);
    }

    #[test]
    fn shard_routing_snapshot_is_overwritten() {
        let mut l = CommLedger::new();
        assert!(l.uplink_bits_by_shard.is_empty());
        l.sync_shard_routing(&[100, 200]);
        assert_eq!(l.uplink_bits_by_shard, vec![100, 200]);
        l.sync_shard_routing(&[150, 250]);
        assert_eq!(l.uplink_bits_by_shard, vec![150, 250]);
    }

    #[test]
    fn sim_link_snapshot_is_overwritten_and_stays_out_of_bit_totals() {
        use crate::coordinator::sim::LinkStats;
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        assert!(l.sim_links.is_empty());
        let snap = vec![
            LinkStats { delivered: 3, drops: 1, reordered: 0, delay_us: 900 },
            LinkStats { delivered: 2, drops: 0, reordered: 1, delay_us: 400 },
        ];
        l.sync_sim_links(&snap);
        assert_eq!(l.sim_links, snap);
        l.sync_sim_links(&snap[..1]);
        assert_eq!(l.sim_links.len(), 1);
        // Virtual-clock stats never leak into the wire-bit accounting.
        assert_eq!(l.total_bits(), 1000);
    }

    #[test]
    fn downlink_formula() {
        let mut l = CommLedger::new();
        l.charge_downlink_dense(100, 4);
        assert_eq!(l.downlink_bits, 4 * 8 * 405);
        assert_eq!(l.total_bits(), l.downlink_bits);
    }

    #[test]
    fn ef_loss_and_rejoin_counters_stay_out_of_the_bit_totals() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        l.ef_resets += 1;
        l.ef_residual_lost_bits += 32 * 256;
        l.rejoins += 1;
        // Lost EF state was never transmitted: it must not leak into the
        // uplink/downlink accounting the figures are drawn from.
        assert_eq!(l.total_bits(), 1000);
        assert_eq!(l.uplink_bits, 1000);
        assert_eq!(l.ef_residual_lost_bits, 8192);
    }

    #[test]
    fn framing_is_billed_separately_from_payload_bits() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        l.charge_framing(128);
        l.charge_framing(200);
        assert_eq!(l.framing_bits, 328);
        assert_eq!(l.uplink_bits, 1000);
        // Framing never leaks into the uplink/downlink totals.
        assert_eq!(l.total_bits(), 1000);
    }
}
