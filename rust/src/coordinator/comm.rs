//! Communication ledger: the exact bit counts behind Figure 2.
//!
//! Uplink (worker → server) is charged per encoded payload — the byte
//! codec's real length ([`Payload::wire_bits`](crate::compress::Payload::wire_bits)
//! `== 8 × encode().len()`), not an estimate. The runtime charges each
//! message as the leader consumes its arrival (the same value the worker
//! computed at the production site, across both transports and both
//! backends), and straggler uplinks still in flight when the run ends
//! are drained and billed too, so no transmitted message escapes the
//! ledger. Bits are recorded per worker, so Figure-2-style reporting can
//! break the uplink bill down by worker. Downlink (server → worker) is the dense θ
//! broadcast, charged **per dispatched worker per round** — under partial
//! participation ([`crate::coordinator::runtime`]) a straggler that sits
//! a round out is not billed a broadcast it never received. The paper's
//! Figure 2 x-axis is uplink bits ("bits transmitted to the central
//! server"); both directions are recorded.
//!
//! Envelope framing ([`crate::coordinator::transport::Envelope`]) is
//! *not* part of the uplink bill: the ledger charges payload wire bits
//! only, so the accounting is identical across transports (the
//! per-message header is surfaced separately via `Envelope::wire_bits`).
//!
//! Partial participation adds two counters: `stale_uplinks` (straggler
//! gradients applied late) and `dropped_uplinks` (stragglers past the
//! staleness bound, transmitted — and charged — but never applied).
//!
//! The tree topology ([`crate::coordinator::tree`]) adds a **level**
//! dimension: level 0 is the hop into the root (sub-leader → root, or
//! worker → leader in the flat star), level 1 the worker → sub-leader
//! hops inside the groups. The root runtime charges level 0 directly;
//! each group runtime charges its own private ledger, which the trainer
//! absorbs via [`CommLedger::absorb_child`] — so
//! `Σ uplink_bits_by_level == uplink_bits` holds exactly (same for
//! downlink and framing), and "root-ingress bits" is just
//! `uplink_bits_by_level[0]`.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommLedger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    /// Uplink bits by tree level: `[0]` is the hop into the root (the
    /// only level in the flat star), `[1]` the worker → sub-leader hops.
    /// Always sums to `uplink_bits`; at most one entry for flat runs.
    pub uplink_bits_by_level: Vec<u64>,
    /// Downlink bits by tree level (root → sub-leader, sub-leader →
    /// worker). Always sums to `downlink_bits`.
    pub downlink_bits_by_level: Vec<u64>,
    /// Framing bits by tree level. Always sums to `framing_bits`.
    pub framing_bits_by_level: Vec<u64>,
    /// Cumulative uplink bits per worker id (grows on first charge).
    pub uplink_bits_by_worker: Vec<u64>,
    /// Cumulative uplink bits as routed to each server shard after
    /// payload slicing — what each shard's standalone process would
    /// receive once shards live behind real transport. Empty when the
    /// server is unsharded; kept in sync from
    /// [`ShardStats`](crate::algo::sharded::ShardStats) by the trainer.
    pub uplink_bits_by_shard: Vec<u64>,
    /// Straggler uplinks applied as stale gradients (staleness ≥ 1,
    /// within the `max_staleness` bound). Zero under full quorum.
    pub stale_uplinks: u64,
    /// Straggler uplinks past the staleness bound: transmitted and
    /// charged, but discarded by the runtime instead of applied. A
    /// crashed worker's never-to-arrive uplink is also counted here (but
    /// its bits are not, since nothing crossed the wire).
    pub dropped_uplinks: u64,
    /// Transport framing bits: per-message overhead on top of the
    /// payload bill (the 16-byte `Envelope` header, plus the socket
    /// frame header on TCP), billed per consumed uplink and per
    /// dispatched downlink. Kept out of `uplink_bits` so the gradient
    /// bit accounting stays identical across transports; zero for
    /// `InProc`.
    pub framing_bits: u64,
    /// Dead workers re-admitted into the run: a replacement process
    /// HELLO'd the leader's listen socket mid-run and was re-ASSIGNed the
    /// dead wid (elastic fleet). Each rejoin restores the quorum target
    /// on the next dispatch.
    pub rejoins: u64,
    /// Worker deaths that zeroed a *live* error-feedback accumulator:
    /// the EF residual `e ∈ R^d` lives in the worker process and dies
    /// with it, so a rejoined replacement restarts from `e = 0`. Zero
    /// for protocols without worker-side EF (dist-sgd, dist-ams,
    /// `:noef`), and for runs without deaths.
    pub ef_resets: u64,
    /// Size of the EF accumulator state lost to those deaths, in bits
    /// (32·d per reset — `e` is a dense f32 d-vector). This is dropped
    /// *gradient mass the ledger can still measure*: the residual's
    /// values are unknowable post-mortem, but its extent is not, so runs
    /// with deaths report the bias instead of hiding it.
    pub ef_residual_lost_bits: u64,
    /// Per-link delivery statistics from the seeded network simulator
    /// (`--transport sim:<inner>`), one entry per worker id: uplinks
    /// delivered, seeded drops (resurfaced as retransmit delay),
    /// reorderings, and cumulative virtual delay. Mirrored from
    /// [`Sim`](crate::coordinator::sim::Sim) after every round, the way
    /// `uplink_bits_by_shard` mirrors the sharded server; empty for real
    /// transports.
    pub sim_links: Vec<crate::coordinator::sim::LinkStats>,
}

/// Add `bits` to a grow-on-demand per-level counter (zero charges do not
/// materialize a level entry).
fn charge_level(levels: &mut Vec<u64>, level: usize, bits: u64) {
    if bits == 0 {
        return;
    }
    if level >= levels.len() {
        levels.resize(level + 1, 0);
    }
    levels[level] += bits;
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one worker's uplink message of `bits` wire bits.
    pub fn charge_uplink(&mut self, wid: usize, bits: u64) {
        if wid >= self.uplink_bits_by_worker.len() {
            self.uplink_bits_by_worker.resize(wid + 1, 0);
        }
        self.uplink_bits_by_worker[wid] += bits;
        self.uplink_bits += bits;
        charge_level(&mut self.uplink_bits_by_level, 0, bits);
        self.uplink_msgs += 1;
    }

    /// Overwrite the per-shard routing snapshot (`routed_bits` values are
    /// already cumulative — the sharded server accumulates them at the
    /// slicing site, the way uplink bits are counted at the production
    /// site).
    pub fn sync_shard_routing(&mut self, routed_bits: &[u64]) {
        self.uplink_bits_by_shard.clear();
        self.uplink_bits_by_shard.extend_from_slice(routed_bits);
    }

    /// Overwrite the per-link simulator snapshot (stats are cumulative
    /// at the source — [`Sim`](crate::coordinator::sim::Sim) accumulates
    /// them at the delivery site).
    pub fn sync_sim_links(&mut self, links: &[crate::coordinator::sim::LinkStats]) {
        self.sim_links.clear();
        self.sim_links.extend_from_slice(links);
    }

    /// Record per-message transport framing overhead (see
    /// [`CommLedger::framing_bits`]).
    pub fn charge_framing(&mut self, bits: u64) {
        self.framing_bits += bits;
        charge_level(&mut self.framing_bits_by_level, 0, bits);
    }

    /// Downlink broadcast of `bits_per_msg` wire bits to each of `n`
    /// dispatched workers. The per-message bill comes from
    /// [`Transport::downlink_wire_bits`](crate::coordinator::transport::Transport::downlink_wire_bits)
    /// — the dense-θ payload on the flat star, the compressed θ-delta
    /// payload under `--downlink-compress`.
    pub fn charge_downlink(&mut self, bits_per_msg: u64, n: usize) {
        let bits = (n as u64) * bits_per_msg;
        self.downlink_bits += bits;
        charge_level(&mut self.downlink_bits_by_level, 0, bits);
    }

    /// Dense f32 broadcast of a d-vector to `n` workers.
    pub fn charge_downlink_dense(&mut self, d: usize, n: usize) {
        self.charge_downlink(8 * (5 + 4 * d as u64), n);
    }

    /// Fold a child (sub-leader group) ledger into this one at tree
    /// `level`: bit totals land in both the headline fields and the
    /// per-level breakdowns, event counters (messages, staleness,
    /// rejoins, EF losses) are added directly. Per-worker/per-shard/
    /// sim-link snapshots are *not* merged — at the root those are keyed
    /// by group id and stay level-0-only. The caller passes each child's
    /// *delta* since the last absorb (the trainer `mem::take`s the group
    /// ledger), so the invariant `Σ by_level == headline` holds after
    /// every call.
    pub fn absorb_child(&mut self, level: usize, child: &CommLedger) {
        self.uplink_bits += child.uplink_bits;
        charge_level(&mut self.uplink_bits_by_level, level, child.uplink_bits);
        self.downlink_bits += child.downlink_bits;
        charge_level(&mut self.downlink_bits_by_level, level, child.downlink_bits);
        self.framing_bits += child.framing_bits;
        charge_level(&mut self.framing_bits_by_level, level, child.framing_bits);
        self.uplink_msgs += child.uplink_msgs;
        self.stale_uplinks += child.stale_uplinks;
        self.dropped_uplinks += child.dropped_uplinks;
        self.rejoins += child.rejoins;
        self.ef_resets += child.ef_resets;
        self.ef_residual_lost_bits += child.ef_residual_lost_bits;
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Payload;

    #[test]
    fn uplink_matches_payload_bits() {
        let mut l = CommLedger::new();
        let p = Payload::Dense(vec![0.0; 10]);
        l.charge_uplink(0, p.wire_bits());
        l.charge_uplink(1, p.wire_bits());
        assert_eq!(l.uplink_bits, 2 * p.wire_bits());
        assert_eq!(l.uplink_msgs, 2);
    }

    #[test]
    fn per_worker_breakdown_sums_to_total() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 100);
        l.charge_uplink(2, 300);
        l.charge_uplink(0, 50);
        assert_eq!(l.uplink_bits_by_worker, vec![150, 0, 300]);
        assert_eq!(
            l.uplink_bits_by_worker.iter().sum::<u64>(),
            l.uplink_bits
        );
        assert_eq!(l.uplink_msgs, 3);
    }

    #[test]
    fn shard_routing_snapshot_is_overwritten() {
        let mut l = CommLedger::new();
        assert!(l.uplink_bits_by_shard.is_empty());
        l.sync_shard_routing(&[100, 200]);
        assert_eq!(l.uplink_bits_by_shard, vec![100, 200]);
        l.sync_shard_routing(&[150, 250]);
        assert_eq!(l.uplink_bits_by_shard, vec![150, 250]);
    }

    #[test]
    fn sim_link_snapshot_is_overwritten_and_stays_out_of_bit_totals() {
        use crate::coordinator::sim::LinkStats;
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        assert!(l.sim_links.is_empty());
        let snap = vec![
            LinkStats {
                delivered: 3,
                drops: 1,
                reordered: 0,
                delay_us: 900,
                downlink_delay_us: 300,
            },
            LinkStats {
                delivered: 2,
                drops: 0,
                reordered: 1,
                delay_us: 400,
                downlink_delay_us: 100,
            },
        ];
        l.sync_sim_links(&snap);
        assert_eq!(l.sim_links, snap);
        l.sync_sim_links(&snap[..1]);
        assert_eq!(l.sim_links.len(), 1);
        // Virtual-clock stats never leak into the wire-bit accounting.
        assert_eq!(l.total_bits(), 1000);
    }

    #[test]
    fn downlink_formula() {
        let mut l = CommLedger::new();
        l.charge_downlink_dense(100, 4);
        assert_eq!(l.downlink_bits, 4 * 8 * 405);
        assert_eq!(l.total_bits(), l.downlink_bits);
    }

    #[test]
    fn ef_loss_and_rejoin_counters_stay_out_of_the_bit_totals() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        l.ef_resets += 1;
        l.ef_residual_lost_bits += 32 * 256;
        l.rejoins += 1;
        // Lost EF state was never transmitted: it must not leak into the
        // uplink/downlink accounting the figures are drawn from.
        assert_eq!(l.total_bits(), 1000);
        assert_eq!(l.uplink_bits, 1000);
        assert_eq!(l.ef_residual_lost_bits, 8192);
    }

    #[test]
    fn per_level_breakdowns_sum_to_headline_totals() {
        let mut root = CommLedger::new();
        root.charge_uplink(0, 1000);
        root.charge_downlink(600, 2);
        root.charge_framing(128);
        assert_eq!(root.uplink_bits_by_level, vec![1000]);
        assert_eq!(root.downlink_bits_by_level, vec![1200]);
        assert_eq!(root.framing_bits_by_level, vec![128]);

        let mut group = CommLedger::new();
        group.charge_uplink(0, 400);
        group.charge_uplink(1, 400);
        group.charge_downlink_dense(10, 2);
        group.stale_uplinks = 1;
        group.ef_resets = 2;
        group.ef_residual_lost_bits = 64;
        root.absorb_child(1, &group);

        assert_eq!(root.uplink_bits_by_level, vec![1000, 800]);
        assert_eq!(
            root.uplink_bits_by_level.iter().sum::<u64>(),
            root.uplink_bits
        );
        assert_eq!(
            root.downlink_bits_by_level.iter().sum::<u64>(),
            root.downlink_bits
        );
        assert_eq!(
            root.framing_bits_by_level.iter().sum::<u64>(),
            root.framing_bits
        );
        assert_eq!(root.uplink_msgs, 3);
        assert_eq!(root.stale_uplinks, 1);
        assert_eq!(root.ef_resets, 2);
        assert_eq!(root.ef_residual_lost_bits, 64);
        // Child per-worker breakdowns are keyed by group-local wids and
        // deliberately not merged into the root's level-0 snapshot.
        assert_eq!(root.uplink_bits_by_worker, vec![1000]);

        // Absorbing a drained (default) child is a no-op.
        let before = root.clone();
        root.absorb_child(1, &CommLedger::new());
        assert_eq!(root, before);
    }

    #[test]
    fn framing_is_billed_separately_from_payload_bits() {
        let mut l = CommLedger::new();
        l.charge_uplink(0, 1000);
        l.charge_framing(128);
        l.charge_framing(200);
        assert_eq!(l.framing_bits, 328);
        assert_eq!(l.uplink_bits, 1000);
        // Framing never leaks into the uplink/downlink totals.
        assert_eq!(l.total_bits(), 1000);
    }
}
