//! Communication ledger: the exact bit counts behind Figure 2.
//!
//! Uplink (worker → server) is charged per encoded payload — the byte
//! codec's real length, not an estimate. Downlink (server → worker) is
//! the dense θ broadcast, charged per worker per round. The paper's
//! Figure 2 x-axis is uplink bits ("bits transmitted to the central
//! server"); both directions are recorded.

use crate::compress::Payload;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommLedger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn charge_uplink(&mut self, p: &Payload) {
        self.uplink_bits += p.wire_bits();
        self.uplink_msgs += 1;
    }

    /// Dense f32 broadcast of a d-vector to `n` workers.
    pub fn charge_downlink_dense(&mut self, d: usize, n: usize) {
        self.downlink_bits += (n as u64) * 8 * (5 + 4 * d as u64);
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_matches_payload_bits() {
        let mut l = CommLedger::new();
        let p = Payload::Dense(vec![0.0; 10]);
        l.charge_uplink(&p);
        l.charge_uplink(&p);
        assert_eq!(l.uplink_bits, 2 * p.wire_bits());
        assert_eq!(l.uplink_msgs, 2);
    }

    #[test]
    fn downlink_formula() {
        let mut l = CommLedger::new();
        l.charge_downlink_dense(100, 4);
        assert_eq!(l.downlink_bits, 4 * 8 * 405);
        assert_eq!(l.total_bits(), l.downlink_bits);
    }
}
