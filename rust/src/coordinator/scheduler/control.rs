//! The control protocol: line-delimited JSON over a second TCP listener.
//!
//! Each request is one JSON object on one line; the daemon answers with
//! one JSON object on one line and keeps the connection open for the
//! next request. Verbs:
//!
//! ```text
//! {"cmd":"submit","config":{...TrainConfig...},"priority":2,"name":"sweep-a"}
//!     → {"ok":true,"id":1}
//! {"cmd":"status"}
//!     → {"ok":true,"draining":false,"fleet_workers":4,"jobs":[{...},...]}
//! {"cmd":"cancel","id":1}
//!     → {"ok":true}
//! {"cmd":"drain"}              (finish queued work, then exit)
//!     → {"ok":true}
//! ```
//!
//! Every error is `{"ok":false,"error":"..."}` — the connection stays
//! usable. Submitted configs are normalized for fleet execution
//! ([`parse_submit`]): `transport` is forced to `tcp`, leader-side
//! threading and worker spawning are disabled (the fleet already runs),
//! and only the analytic substrates are accepted (remote daemons rebuild
//! their data shard from the config).
//!
//! Two representation choices keep the protocol lossless over JSON:
//! non-finite floats (the quadratic substrate has no accuracy, so it
//! reports NaN) map to `null` ([`finite`]), and a finished job's θ is
//! shipped as `theta_hex` — eight lowercase hex digits per `f32` bit
//! pattern ([`theta_to_hex`]) — so clients can verify *bitwise* equality
//! of resumed trajectories, which a decimal float print could not
//! guarantee.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::util::json::{parse, Json};

use super::queue::Job;

/// Map a float into JSON, turning non-finite values (NaN accuracy on
/// substrates without one, ±Inf) into `null` — the parser on the other
/// end rejects bare `NaN`/`Infinity` tokens, as JSON requires.
pub fn finite(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Render θ as a hex string, 8 lowercase hex digits per coordinate
/// (the `f32`'s bit pattern, big-endian digit order). Bit-exact by
/// construction — the reason this exists instead of a JSON number array.
pub fn theta_to_hex(theta: &[f32]) -> String {
    let mut s = String::with_capacity(theta.len() * 8);
    for x in theta {
        s.push_str(&format!("{:08x}", x.to_bits()));
    }
    s
}

/// Invert [`theta_to_hex`].
pub fn theta_from_hex(s: &str) -> Result<Vec<f32>> {
    ensure!(
        s.len() % 8 == 0 && s.is_ascii(),
        "theta hex length {} is not a multiple of 8 ascii chars",
        s.len()
    );
    s.as_bytes()
        .chunks(8)
        .map(|c| {
            let chunk = std::str::from_utf8(c).expect("ascii checked above");
            let bits = u32::from_str_radix(chunk, 16)
                .with_context(|| format!("bad theta hex chunk '{chunk}'"))?;
            Ok(f32::from_bits(bits))
        })
        .collect()
}

/// Parse and normalize a `submit` request into `(name, priority, cfg)`.
/// `fleet_size` bounds the job's worker count — a job can use a prefix
/// of the fleet, never more than it.
pub fn parse_submit(req: &Json, fleet_size: usize) -> Result<(String, i64, TrainConfig)> {
    let mut cfg = TrainConfig::from_json(req.req("config")?)
        .context("parsing submit config")?;
    // Normalize for fleet execution: jobs always run over the resident
    // TCP fleet, whatever the submitted config said.
    cfg.transport = "tcp".into();
    cfg.spawn_workers = false;
    cfg.threaded = false;
    ensure!(
        cfg.is_analytic(),
        "scheduled jobs run on remote workers, which rebuild their data \
         shard from the config: analytic substrates only (quadratic | \
         logistic), not '{}'",
        cfg.model
    );
    ensure!(
        cfg.workers <= fleet_size,
        "job wants {} workers but the fleet has {}",
        cfg.workers,
        fleet_size
    );
    cfg.validate()?;
    let priority = match req.get("priority") {
        Some(v) => {
            let p = v.as_f64()?;
            ensure!(p.fract() == 0.0, "priority must be an integer, got {p}");
            p as i64
        }
        None => 0,
    };
    let name = match req.get("name") {
        Some(v) => v.as_str()?.to_string(),
        None => String::new(),
    };
    Ok((name, priority, cfg))
}

/// One job's row in a `status` response.
pub fn job_to_json(job: &Job) -> Json {
    let mut pairs = vec![
        ("id", Json::num(job.id as f64)),
        ("name", Json::str(&job.name)),
        ("state", Json::str(job.state.as_str())),
        ("priority", Json::num(job.priority as f64)),
        ("model", Json::str(&job.cfg.model)),
        ("algo", Json::str(&job.cfg.algo)),
        ("workers", Json::num(job.cfg.workers as f64)),
        ("rounds_total", Json::num(job.cfg.rounds as f64)),
        ("rounds_done", Json::num(job.rounds_done as f64)),
        ("preemptions", Json::num(job.preemptions as f64)),
    ];
    if let Some(e) = &job.error {
        pairs.push(("error", Json::str(e)));
    }
    if let Some(r) = &job.result {
        pairs.push((
            "result",
            Json::obj(vec![
                ("final_train_loss", finite(f64::from(r.final_train_loss(10)))),
                ("final_eval_loss", finite(f64::from(r.final_eval.loss))),
                ("final_eval_acc", finite(f64::from(r.final_eval.accuracy))),
                ("rounds", Json::num(r.metrics.len() as f64)),
                ("uplink_bits", Json::num(r.uplink_bits() as f64)),
                ("framing_bits", Json::num(r.framing_bits as f64)),
                ("stale_uplinks", Json::num(r.stale_uplinks as f64)),
                ("dropped_uplinks", Json::num(r.dropped_uplinks as f64)),
                ("rejoins", Json::num(r.rejoins as f64)),
                ("ef_resets", Json::num(r.ef_resets as f64)),
                (
                    "ef_residual_lost_bits",
                    Json::num(r.ef_residual_lost_bits as f64),
                ),
                (
                    "uplink_bits_by_worker",
                    Json::Arr(
                        r.uplink_bits_by_worker
                            .iter()
                            .map(|&b| Json::num(b as f64))
                            .collect(),
                    ),
                ),
                ("total_wall_ms", finite(r.total_wall_ms)),
            ]),
        ));
    }
    if let Some(t) = &job.final_theta {
        pairs.push(("theta_hex", Json::Str(theta_to_hex(t))));
    }
    Json::obj(pairs)
}

/// Client half: send one request line to the daemon's control address,
/// read one response line, fail on `{"ok":false}`.
pub fn request(addr: &str, req: &Json) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to the control socket at {addr}"))?;
    stream.set_nodelay(true)?;
    let mut line = req.to_string_compact();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()?;
    let mut resp = String::new();
    BufReader::new(stream)
        .read_line(&mut resp)
        .context("reading the control response")?;
    ensure!(!resp.is_empty(), "control connection closed without a response");
    let json = parse(resp.trim_end()).context("parsing the control response")?;
    if !json.req("ok")?.as_bool()? {
        let err = json
            .get("error")
            .and_then(|e| e.as_str().ok().map(str::to_string))
            .unwrap_or_else(|| "unknown control error".into());
        bail!("control request failed: {err}");
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::super::queue::{JobQueue, JobState};
    use super::*;

    #[test]
    fn theta_hex_is_bit_exact_even_for_nonfinite() {
        let theta =
            vec![0.0f32, -0.0, 1.5e-38, f32::NAN, f32::INFINITY, -123.456, f32::MIN];
        let hex = theta_to_hex(&theta);
        assert_eq!(hex.len(), theta.len() * 8);
        let back = theta_from_hex(&hex).unwrap();
        let a: Vec<u32> = theta.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
        assert!(theta_from_hex("0123456").is_err()); // not %8
        assert!(theta_from_hex("zzzzzzzz").is_err()); // not hex
    }

    #[test]
    fn finite_maps_nan_to_null() {
        assert_eq!(finite(1.25), Json::num(1.25));
        assert_eq!(finite(f64::NAN), Json::Null);
        assert_eq!(finite(f64::INFINITY), Json::Null);
    }

    #[test]
    fn submit_normalizes_and_validates() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        // Whatever the client claims about transport/threading, the
        // scheduler runs the job over its fleet.
        cfg.transport = "inproc".into();
        cfg.threaded = true;
        cfg.spawn_workers = false;
        let req = Json::obj(vec![
            ("cmd", Json::str("submit")),
            ("config", cfg.to_json()),
            ("priority", Json::num(2.0)),
            ("name", Json::str("sweep")),
        ]);
        let (name, priority, parsed) = parse_submit(&req, 4).unwrap();
        assert_eq!(name, "sweep");
        assert_eq!(priority, 2);
        assert_eq!(parsed.transport, "tcp");
        assert!(!parsed.threaded);
        assert!(!parsed.spawn_workers);
        assert_eq!(parsed.workers, 3);
        // Defaults: no name, priority 0.
        let req = Json::obj(vec![("config", cfg.to_json())]);
        let (name, priority, _) = parse_submit(&req, 4).unwrap();
        assert!(name.is_empty());
        assert_eq!(priority, 0);
    }

    #[test]
    fn submit_rejects_bad_jobs() {
        let cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        let ok = Json::obj(vec![("config", cfg.to_json())]);
        // More workers than the fleet has.
        assert!(parse_submit(&ok, 2).is_err());
        assert!(parse_submit(&ok, cfg.workers).is_ok());
        // Non-analytic model.
        let mut bad = cfg.clone();
        bad.model = "mnist_cnn".into();
        let req = Json::obj(vec![("config", bad.to_json())]);
        assert!(parse_submit(&req, 64).is_err());
        // Bogus algo caught by validate().
        let mut bad = cfg.clone();
        bad.algo = "carrier-pigeon".into();
        let req = Json::obj(vec![("config", bad.to_json())]);
        assert!(parse_submit(&req, 64).is_err());
        // Missing config key entirely.
        assert!(parse_submit(&Json::obj(vec![("cmd", Json::str("submit"))]), 4).is_err());
        // Fractional priority.
        let req = Json::obj(vec![
            ("config", cfg.to_json()),
            ("priority", Json::num(1.5)),
        ]);
        assert!(parse_submit(&req, 64).is_err());
    }

    #[test]
    fn job_json_reports_state_and_omits_missing_fields() {
        let mut q = JobQueue::new();
        let id = q.submit("probe", 1, TrainConfig::preset("quadratic", "dist-sgd"));
        let j = job_to_json(q.job(id).unwrap());
        assert_eq!(j.req("state").unwrap().as_str().unwrap(), "queued");
        assert_eq!(j.req("id").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("name").unwrap().as_str().unwrap(), "probe");
        assert!(j.get("result").is_none());
        assert!(j.get("error").is_none());
        assert!(j.get("theta_hex").is_none());
        q.job_mut(id).unwrap().state = JobState::Failed;
        q.job_mut(id).unwrap().error = Some("boom".into());
        let j = job_to_json(q.job(id).unwrap());
        assert_eq!(j.req("state").unwrap().as_str().unwrap(), "failed");
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "boom");
        // The whole row must survive a compact-print → parse round trip
        // (that is how it travels on the wire).
        assert_eq!(parse(&j.to_string_compact()).unwrap(), j);
    }
}
