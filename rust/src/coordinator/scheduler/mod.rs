//! The multi-job scheduler: a resident leader serving many training
//! jobs over one persistent worker fleet.
//!
//! `comp-ams serve` turns the leader into a daemon. Worker daemons
//! HELLO once and become a pooled resource; each submitted job is
//! re-ASSIGNed onto the fleet, driven round by round through a per-job
//! [`Trainer`](super::trainer::Trainer), and DETACHed back to the pool
//! when it finishes — or is suspended into a
//! [`JobCheckpoint`](super::checkpoint::JobCheckpoint) when a strictly
//! higher-priority job arrives, to be resumed bitwise-identically later.
//!
//! Three layers, one file each:
//!
//! | module     | role |
//! |------------|------|
//! | [`queue`]  | plain-data [`JobQueue`]: priorities, FIFO tie-break, lifecycle states |
//! | [`daemon`] | the [`Scheduler`]: fleet ownership, job driving, preemption, SIGINT/drain |
//! | [`control`]| line-delimited JSON protocol (`submit`/`status`/`cancel`/`drain`), client helper |
//!
//! Because every job runs through its own `Trainer` value over a fresh
//! pooled transport, per-job [`RunResult`](super::metrics::RunResult)s
//! and bit ledgers are disjoint by construction — the daemon holds no
//! cross-job accounting state.

pub mod control;
pub mod daemon;
pub mod queue;

pub use control::{job_to_json, parse_submit, request, theta_from_hex, theta_to_hex};
pub use daemon::{serve, Scheduler, ServeOpts};
pub use queue::{Job, JobId, JobQueue, JobState};
