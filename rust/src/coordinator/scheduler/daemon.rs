//! The resident leader daemon: one worker fleet, many jobs.
//!
//! `comp-ams serve` promotes the leader from a single-run driver to a
//! long-lived scheduler:
//!
//! ```text
//!   fleet listener (tcp)      control listener (tcp, line-JSON)
//!        │ HELLO ×N                 │ submit / status / cancel / drain
//!        ▼                          ▼
//!   Fleet{streams} ◀──────── Scheduler loop:
//!        │   ASSIGN(cfg, resume)    pick highest-priority runnable job
//!        │   rounds…                step it round by round
//!        │   DETACH(want_state)     (checking cancel / preempt / SIGINT
//!        ▼                           between rounds)
//!   workers back to idle, next job re-ASSIGNs the same sockets
//! ```
//!
//! Worker daemons HELLO once and become a pooled resource: each job gets
//! a fresh pooled [`Tcp`](super::super::net::Tcp) transport over
//! `try_clone`s of the fleet's sockets ([`assign_streams`]), wrapped in
//! a per-job [`Trainer`] ([`Trainer::with_transport`]), so per-job
//! [`RunResult`](super::super::metrics::RunResult)s and
//! [`CommLedger`](super::super::comm::CommLedger)s can never bleed into
//! each other — the accounting lives in the per-job value, not the
//! resident daemon.
//!
//! One job runs at a time (the fleet is one resource). A submission with
//! *strictly* higher priority preempts the running job at the next round
//! boundary: the job is [`Trainer::suspend`]ed into a
//! [`JobCheckpoint`] (θ + server optimizer + every worker's compressor/
//! EF/data-stream state) and later resumed bitwise-identically — the
//! workers re-enter their state from the ASSIGN frame's resume blob.
//! SIGINT takes the same path: checkpoint the active job, mark it
//! suspended, SHUTDOWN the fleet, reap any spawned children, exit.
//! `drain` finishes everything already queued, then exits.
//!
//! The daemon prints `fleet-addr HOST:PORT` / `control-addr HOST:PORT`
//! lines on stdout (flushed) as each listener binds — with ephemeral
//! ports (`tcp:0`, the default) this is how tests and CI find it.
//!
//! The fleet heals between jobs. Every `assign` (and each idle tick of
//! the scheduler loop) runs a liveness pass: dead sockets are probed
//! out and evicted with their slot named on stderr, the supervisor —
//! when the daemon spawned its own fleet — restarts crashed children
//! under [`RestartPolicy`](super::super::supervisor::RestartPolicy)'s
//! exponential backoff, and replacement `comp-ams worker` daemons that
//! HELLO on the (still open) fleet listener are re-admitted up to the
//! original fleet size. A job that wants more workers than are
//! currently live fails fast with an error naming the evicted slots —
//! it is never silently assigned onto a dead socket. (Mid-job deaths
//! are the per-job runtime's domain: the pooled transport reports the
//! worker dead and the round quorum shrinks; healing happens at the
//! next job boundary.)

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::util::json::{parse, Json};

use super::super::checkpoint::JobCheckpoint;
use super::super::net::{assign_streams, write_frame, FrameKind, Tcp, TcpLeader};
use super::super::supervisor::{RestartPolicy, Supervisor};
use super::super::trainer::Trainer;
use super::control::{job_to_json, parse_submit};
use super::queue::{JobId, JobQueue, JobState};

/// How the daemon is launched (`comp-ams serve` flags).
pub struct ServeOpts {
    /// Fleet size: how many worker daemons to wait for (or spawn).
    pub workers: usize,
    /// Spawn the fleet as child processes instead of waiting for
    /// externally launched `comp-ams worker`s.
    pub spawn_workers: bool,
    /// Fleet listener port (0 = ephemeral, announced on stdout).
    pub fleet_port: u16,
    /// Control listener port (0 = ephemeral, announced on stdout).
    pub control_port: u16,
}

/// Entry point for `comp-ams serve`: install the SIGINT handler, form
/// the fleet, start the control listener, and run jobs until drained or
/// interrupted.
pub fn serve(opts: &ServeOpts) -> Result<()> {
    install_sigint();
    Scheduler::start(opts)?.run()
}

// ---------------------------------------------------------------------------
// SIGINT: a flag the serve loop polls between rounds (and while idle).

static SIGINT: AtomicBool = AtomicBool::new(false);

fn sigint_received() -> bool {
    SIGINT.load(Ordering::Relaxed)
}

/// Install a handler that flips [`SIGINT`]. Pure std: libc's `signal`
/// is already linked; storing to an `AtomicBool` is async-signal-safe.
#[cfg(unix)]
fn install_sigint() {
    extern "C" fn on_sigint(_sig: i32) {
        SIGINT.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(2 /* SIGINT */, on_sigint as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_sigint() {}

/// Print one machine-parseable `key value` line on stdout and flush it
/// (stdout is block-buffered under a pipe — tests and CI read these).
fn announce(key: &str, value: impl std::fmt::Display) -> Result<()> {
    let mut out = std::io::stdout();
    writeln!(out, "{key} {value}")?;
    out.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// The fleet: HELLO'd sockets, pooled across jobs.

/// The resident worker fleet: one connected, idle socket per worker
/// daemon (plus the supervisor when the daemon spawned them itself).
/// The fleet listener stays open for the daemon's whole life so
/// replacement workers can HELLO back in after a death.
struct Fleet {
    leader: TcpLeader,
    streams: Vec<TcpStream>,
    /// The fleet size the daemon was asked for — the re-admission
    /// ceiling (a late HELLO beyond it stays queued in the backlog).
    target: usize,
    /// Cumulative human-readable eviction log ("slot 1 (addr)"), so a
    /// failed assign can always name who died even rounds later.
    evicted: Vec<String>,
    supervisor: Option<Supervisor>,
}

/// Probe an **idle** fleet socket for liveness without consuming bytes.
/// A worker daemon idle between jobs sends nothing, so: EOF (`Ok(0)`)
/// or a hard error means the peer is gone; pending bytes or
/// `WouldBlock` mean it is alive.
fn stream_is_dead(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let dead = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    dead
}

impl Fleet {
    /// Bind the fleet listener, announce its address, and collect the
    /// fleet's HELLOs (spawning the workers first if asked to). A
    /// spawned fleet is armed with the default restart-backoff policy
    /// so a crashed child is relaunched automatically.
    fn form(opts: &ServeOpts) -> Result<Fleet> {
        ensure!(opts.workers >= 1, "serve needs a fleet of at least one worker");
        let leader = TcpLeader::bind(opts.fleet_port)?;
        let addr = leader.local_addr()?;
        announce("fleet-addr", addr)?;
        let supervisor = if opts.spawn_workers {
            let mut sup = Supervisor::spawn(opts.workers, &addr.to_string())?;
            sup.set_restart_policy(RestartPolicy::default());
            Some(sup)
        } else {
            eprintln!(
                "[serve] waiting for {} worker(s): comp-ams worker --leader {addr}",
                opts.workers
            );
            None
        };
        let streams = leader.accept_hellos(opts.workers)?;
        eprintln!("[serve] fleet of {} worker(s) connected", streams.len());
        Ok(Fleet {
            leader,
            streams,
            target: opts.workers,
            evicted: Vec::new(),
            supervisor,
        })
    }

    /// One healing pass: restart crashed spawned children (backoff
    /// permitting), evict fleet sockets whose peer died, and re-admit
    /// pending HELLOs up to the original fleet size. Never fails — a
    /// sick fleet keeps serving whatever is still alive.
    fn heal(&mut self) {
        if let Some(sup) = self.supervisor.as_mut() {
            match sup.tick() {
                Ok(0) => {}
                Ok(n) => eprintln!("[serve] supervisor respawned {n} worker process(es)"),
                Err(e) => eprintln!("[serve] supervisor tick failed: {e:#}"),
            }
        }
        let mut slot = 0;
        while slot < self.streams.len() {
            if stream_is_dead(&self.streams[slot]) {
                let peer = self.streams[slot]
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "unknown peer".into());
                eprintln!("[serve] evicting dead fleet worker slot {slot} ({peer})");
                self.evicted.push(format!("slot {slot} ({peer})"));
                let dead = self.streams.remove(slot);
                let _ = dead.shutdown(Shutdown::Both);
            } else {
                slot += 1;
            }
        }
        while self.streams.len() < self.target {
            match self.leader.try_accept_hello() {
                Ok(Some(stream)) => {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "unknown peer".into());
                    self.streams.push(stream);
                    eprintln!(
                        "[serve] fleet worker rejoined ({peer}); {}/{} live",
                        self.streams.len(),
                        self.target
                    );
                }
                Ok(None) => break,
                Err(e) => {
                    eprintln!("[serve] fleet rejoin accept failed: {e:#}");
                    break;
                }
            }
        }
    }

    /// ASSIGN a job onto the first `cfg.workers` fleet members (pooled:
    /// end-of-job DETACHes them back to idle instead of closing them).
    /// Heals first, and fails fast — naming the evicted slots — rather
    /// than assigning a job onto a socket whose worker is dead.
    fn assign(&mut self, cfg: &TrainConfig, resume: Option<&[Vec<u8>]>) -> Result<Tcp> {
        self.heal();
        if cfg.workers > self.streams.len() {
            let who = if self.evicted.is_empty() {
                "none evicted".to_string()
            } else {
                self.evicted.join(", ")
            };
            bail!(
                "job wants {} workers but the fleet has {} live (dead workers evicted: \
                 {who}); launch replacement `comp-ams worker --leader <fleet-addr>` \
                 daemons to heal the fleet",
                cfg.workers,
                self.streams.len()
            );
        }
        assign_streams(&self.streams[..cfg.workers], cfg, resume, true)
    }

    /// End of service: SHUTDOWN every (idle) worker daemon, close the
    /// sockets, and reap any children we spawned.
    fn shutdown(mut self) -> Result<()> {
        for stream in &mut self.streams {
            // Best effort per worker — one that died mid-service must not
            // keep the rest from shutting down cleanly.
            let _ = write_frame(stream, FrameKind::Shutdown, &[]);
            let _ = stream.shutdown(Shutdown::Both);
        }
        if let Some(sup) = self.supervisor.as_mut() {
            let reports = sup.reap(Duration::from_secs(10))?;
            let nonzero = reports.iter().filter(|r| !r.status.success()).count();
            if nonzero > 0 {
                eprintln!(
                    "[serve] warning: {nonzero} worker process(es) exited non-zero"
                );
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared state between the scheduler loop and control handler threads.

struct SchedState {
    queue: JobQueue,
    draining: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<SchedState>,
    /// Wakes the scheduler loop on submit/cancel/drain.
    cvar: Condvar,
    fleet_size: usize,
}

/// How one job's drive ended.
enum Outcome {
    Done(Vec<f32>, crate::coordinator::metrics::RunResult),
    Suspended { ckpt: JobCheckpoint, preempted: bool },
    Cancelled,
}

// ---------------------------------------------------------------------------
// The scheduler.

/// The resident multi-job scheduler: owns the fleet and the shared job
/// queue; [`Scheduler::run`] drives jobs until drained or interrupted.
pub struct Scheduler {
    fleet: Fleet,
    shared: Arc<Shared>,
    control: TcpListener,
}

impl Scheduler {
    /// Form the fleet, bind + announce the control listener, and start
    /// the control accept thread. Does not run any job yet.
    pub fn start(opts: &ServeOpts) -> Result<Scheduler> {
        let fleet = Fleet::form(opts)?;
        let shared = Arc::new(Shared {
            state: Mutex::new(SchedState {
                queue: JobQueue::new(),
                draining: false,
                shutdown: false,
            }),
            cvar: Condvar::new(),
            fleet_size: fleet.streams.len(),
        });
        let control = TcpListener::bind(("127.0.0.1", opts.control_port))
            .with_context(|| {
                format!("binding the control listener on 127.0.0.1:{}", opts.control_port)
            })?;
        announce("control-addr", control.local_addr()?)?;
        let acceptor = control.try_clone()?;
        let accept_shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("control-accept".into())
            .spawn(move || {
                for conn in acceptor.incoming() {
                    let Ok(stream) = conn else { continue };
                    let conn_shared = Arc::clone(&accept_shared);
                    let _ = std::thread::Builder::new()
                        .name("control-conn".into())
                        .spawn(move || handle_conn(stream, conn_shared));
                }
            })
            .context("spawning the control accept thread")?;
        Ok(Scheduler { fleet, shared, control })
    }

    pub fn control_addr(&self) -> Result<SocketAddr> {
        Ok(self.control.local_addr()?)
    }

    /// Serve jobs until the queue is drained (after a `drain` request)
    /// or SIGINT arrives, then release the fleet.
    pub fn run(mut self) -> Result<()> {
        loop {
            let next = loop {
                let mut st = self.shared.state.lock().unwrap();
                if st.shutdown || sigint_received() {
                    st.shutdown = true;
                    break None;
                }
                if let Some(id) = st.queue.next_runnable() {
                    break Some(id);
                }
                if st.draining {
                    break None;
                }
                // Timed wait so an idle daemon still notices SIGINT.
                let (guard, _) = self
                    .shared
                    .cvar
                    .wait_timeout(st, Duration::from_millis(200))
                    .unwrap();
                drop(guard);
                // Heal between waits, outside the state lock: admitting
                // a slow rejoiner must not stall control connections.
                self.fleet.heal();
            };
            match next {
                Some(id) => self.run_one(id),
                None => break,
            }
        }
        eprintln!("[serve] releasing the fleet");
        self.fleet.shutdown()
    }

    /// Run one scheduled job to completion, suspension, cancellation, or
    /// failure, recording the outcome on the job.
    fn run_one(&mut self, id: JobId) {
        let (name, cfg, ckpt, priority) = {
            let mut st = self.shared.state.lock().unwrap();
            let job = st.queue.job_mut(id).expect("scheduled job exists");
            job.state = JobState::Running;
            (job.name.clone(), job.cfg.clone(), job.checkpoint.take(), job.priority)
        };
        eprintln!(
            "[serve] job {id} ({name}): {} {} on {} worker(s), rounds {}..{}",
            cfg.model,
            cfg.algo,
            cfg.workers,
            ckpt.as_ref().map_or(0, |c| c.round),
            cfg.rounds
        );
        let outcome = self.drive(id, priority, &cfg, ckpt);
        let mut st = self.shared.state.lock().unwrap();
        let job = st.queue.job_mut(id).expect("scheduled job exists");
        match outcome {
            Ok(Outcome::Done(theta, result)) => {
                job.rounds_done = cfg.rounds;
                job.final_theta = Some(theta);
                job.result = Some(result);
                job.state = JobState::Done;
                eprintln!("[serve] job {id} ({name}): done");
            }
            Ok(Outcome::Suspended { ckpt, preempted }) => {
                job.rounds_done = ckpt.round;
                if preempted {
                    job.preemptions += 1;
                }
                job.checkpoint = Some(ckpt);
                job.state = JobState::Suspended;
                eprintln!(
                    "[serve] job {id} ({name}): suspended at round {} ({})",
                    job.rounds_done,
                    if preempted { "preempted" } else { "shutdown" }
                );
            }
            Ok(Outcome::Cancelled) => {
                job.state = JobState::Cancelled;
                job.checkpoint = None;
                eprintln!("[serve] job {id} ({name}): cancelled");
            }
            Err(e) => {
                job.error = Some(format!("{e:#}"));
                job.state = JobState::Failed;
                eprintln!("[serve] job {id} ({name}): failed: {e:#}");
            }
        }
    }

    /// The per-job round loop: a fresh pooled transport + trainer, with
    /// cancel / preemption / shutdown checks at every round boundary.
    fn drive(
        &mut self,
        id: JobId,
        priority: i64,
        cfg: &TrainConfig,
        ckpt: Option<JobCheckpoint>,
    ) -> Result<Outcome> {
        let tcp = self.fleet.assign(cfg, ckpt.as_ref().map(|c| c.workers.as_slice()))?;
        let mut trainer = Trainer::with_transport(cfg, Box::new(tcp), ckpt.as_ref())?;
        while trainer.next_round() < cfg.rounds {
            enum Act {
                Continue,
                Cancel,
                Suspend { preempted: bool },
            }
            let act = {
                let st = self.shared.state.lock().unwrap();
                let job = st.queue.job(id).expect("running job exists");
                if job.cancel_requested {
                    Act::Cancel
                } else if st.shutdown || sigint_received() {
                    Act::Suspend { preempted: false }
                } else if st.queue.best_waiting_priority().is_some_and(|p| p > priority)
                {
                    Act::Suspend { preempted: true }
                } else {
                    Act::Continue
                }
            };
            match act {
                Act::Continue => {}
                Act::Cancel => {
                    // Dropping the trainer detaches the fleet back to
                    // idle (pooled transport) without collecting state.
                    drop(trainer);
                    return Ok(Outcome::Cancelled);
                }
                Act::Suspend { preempted } => {
                    let ckpt = trainer.suspend().context("suspending the job")?;
                    return Ok(Outcome::Suspended { ckpt, preempted });
                }
            }
            let round = trainer.next_round();
            trainer.step(round)?;
            self.shared
                .state
                .lock()
                .unwrap()
                .queue
                .job_mut(id)
                .expect("running job exists")
                .rounds_done = trainer.next_round();
        }
        // Grab θ before finalize consumes the trainer: it travels to
        // clients as theta_hex for bitwise trajectory verification.
        let theta = trainer.theta.clone();
        let result = trainer.finalize()?;
        Ok(Outcome::Done(theta, result))
    }
}

// ---------------------------------------------------------------------------
// Control protocol server half.

/// Serve one control connection: one JSON request per line, one JSON
/// response per line, until the client hangs up.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let reader = BufReader::new(read_half);
    let mut writer = stream;
    let _ = writer.set_nodelay(true);
    for line in reader.lines() {
        let Ok(line) = line else { return };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match handle_request(&shared, &line) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(&format!("{e:#}"))),
            ]),
        };
        let mut out = resp.to_string_compact();
        out.push('\n');
        if writer.write_all(out.as_bytes()).and_then(|_| writer.flush()).is_err() {
            return;
        }
    }
}

fn ok_true() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

fn handle_request(shared: &Shared, line: &str) -> Result<Json> {
    let req = parse(line).context("parsing control request")?;
    let cmd = req.req("cmd")?.as_str()?;
    match cmd {
        "submit" => {
            let (name, priority, cfg) = parse_submit(&req, shared.fleet_size)?;
            let mut st = shared.state.lock().unwrap();
            ensure!(!st.draining, "scheduler is draining; not accepting new jobs");
            ensure!(!st.shutdown, "scheduler is shutting down");
            let id = st.queue.submit(&name, priority, cfg);
            shared.cvar.notify_all();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::num(id as f64)),
            ]))
        }
        "status" => {
            let st = shared.state.lock().unwrap();
            let jobs: Vec<Json> = st.queue.jobs().iter().map(job_to_json).collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(st.draining)),
                ("fleet_workers", Json::num(shared.fleet_size as f64)),
                ("jobs", Json::Arr(jobs)),
            ]))
        }
        "cancel" => {
            let id = req.req("id")?.as_usize()? as JobId;
            let mut st = shared.state.lock().unwrap();
            let job = st
                .queue
                .job_mut(id)
                .with_context(|| format!("no job {id}"))?;
            match job.state {
                JobState::Queued | JobState::Suspended => {
                    job.state = JobState::Cancelled;
                    job.checkpoint = None;
                }
                JobState::Running => job.cancel_requested = true,
                s => bail!("job {id} is already {}", s.as_str()),
            }
            shared.cvar.notify_all();
            Ok(ok_true())
        }
        "drain" => {
            let mut st = shared.state.lock().unwrap();
            st.draining = true;
            shared.cvar.notify_all();
            Ok(ok_true())
        }
        other => bail!("unknown command '{other}' (submit | status | cancel | drain)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared(fleet_size: usize) -> Shared {
        Shared {
            state: Mutex::new(SchedState {
                queue: JobQueue::new(),
                draining: false,
                shutdown: false,
            }),
            cvar: Condvar::new(),
            fleet_size,
        }
    }

    fn submit_req(workers: usize, priority: f64) -> String {
        let mut cfg = TrainConfig::preset("quadratic", "dist-sgd");
        cfg.workers = workers;
        Json::obj(vec![
            ("cmd", Json::str("submit")),
            ("config", cfg.to_json()),
            ("priority", Json::num(priority)),
        ])
        .to_string_compact()
    }

    #[test]
    fn submit_status_cancel_lifecycle() {
        let sh = shared(4);
        let resp = handle_request(&sh, &submit_req(2, 0.0)).unwrap();
        assert_eq!(resp.req("id").unwrap().as_usize().unwrap(), 1);
        handle_request(&sh, &submit_req(4, 5.0)).unwrap();
        let status = handle_request(&sh, r#"{"cmd":"status"}"#).unwrap();
        let jobs = status.req("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(status.req("fleet_workers").unwrap().as_usize().unwrap(), 4);
        // The queue scheduling sees priority 5 first.
        assert_eq!(sh.state.lock().unwrap().queue.next_runnable(), Some(2));
        handle_request(&sh, r#"{"cmd":"cancel","id":2}"#).unwrap();
        assert_eq!(sh.state.lock().unwrap().queue.next_runnable(), Some(1));
        // Cancelling a cancelled job is an error.
        assert!(handle_request(&sh, r#"{"cmd":"cancel","id":2}"#).is_err());
        assert!(handle_request(&sh, r#"{"cmd":"cancel","id":99}"#).is_err());
    }

    #[test]
    fn drain_refuses_new_submissions() {
        let sh = shared(4);
        handle_request(&sh, r#"{"cmd":"drain"}"#).unwrap();
        assert!(sh.state.lock().unwrap().draining);
        let err = handle_request(&sh, &submit_req(2, 0.0)).unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");
        // status still answers.
        let status = handle_request(&sh, r#"{"cmd":"status"}"#).unwrap();
        assert!(status.req("draining").unwrap().as_bool().unwrap());
    }

    #[test]
    fn oversubscribed_and_unknown_commands_rejected() {
        let sh = shared(2);
        assert!(handle_request(&sh, &submit_req(3, 0.0)).is_err());
        assert!(handle_request(&sh, r#"{"cmd":"gibberish"}"#).is_err());
        assert!(handle_request(&sh, "not json").is_err());
        // A running job is cancelled via the flag, not a state flip.
        handle_request(&sh, &submit_req(2, 0.0)).unwrap();
        sh.state.lock().unwrap().queue.job_mut(1).unwrap().state = JobState::Running;
        handle_request(&sh, r#"{"cmd":"cancel","id":1}"#).unwrap();
        let st = sh.state.lock().unwrap();
        let job = st.queue.job(1).unwrap();
        assert_eq!(job.state, JobState::Running);
        assert!(job.cancel_requested);
    }
}
