//! The job queue: priorities, FIFO tie-break, and job lifecycle state.
//!
//! The queue is plain data (configs, checkpoints, results — no live
//! transports or trainers), so it sits behind the scheduler's mutex and
//! is safely shared between the job-driving thread and the control
//! protocol handlers. The scheduling policy is deliberately simple and
//! fully deterministic: among the runnable jobs (queued or suspended),
//! the highest `priority` wins, and the lowest `id` — submission order —
//! breaks ties.

use crate::config::TrainConfig;

use super::super::checkpoint::JobCheckpoint;
use super::super::metrics::RunResult;

/// Monotonic job identifier, assigned at submit time starting from 1.
pub type JobId = u64;

/// Lifecycle of a scheduled job.
///
/// ```text
///   Queued ──▶ Running ──▶ Done | Failed | Cancelled
///                 │ ▲
///                 ▼ │  (preemption / graceful shutdown)
///              Suspended ──▶ Cancelled
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Submitted, never run.
    Queued,
    /// Currently owning the fleet.
    Running,
    /// Preempted (or interrupted by shutdown) with a checkpoint; eligible
    /// to run again.
    Suspended,
    /// Ran to completion; `result` holds its [`RunResult`].
    Done,
    /// Aborted with an error; `error` holds the rendered cause.
    Failed,
    /// Cancelled before completion (checkpoint, if any, discarded).
    Cancelled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Suspended => "suspended",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Terminal states never run (again).
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// One submitted training job and everything the scheduler knows about
/// it. `checkpoint` is present exactly while the job is [`Suspended`]
/// (`JobState::Suspended`); `result` and `final_theta` exactly once it
/// is [`Done`](JobState::Done).
pub struct Job {
    pub id: JobId,
    pub name: String,
    /// Higher runs first; a strictly higher-priority submission preempts
    /// the running job between rounds.
    pub priority: i64,
    pub cfg: TrainConfig,
    pub state: JobState,
    pub checkpoint: Option<JobCheckpoint>,
    pub result: Option<RunResult>,
    /// Final θ (bit-exact), surfaced over the control protocol so
    /// clients can verify resumed trajectories.
    pub final_theta: Option<Vec<f32>>,
    pub error: Option<String>,
    /// Rounds completed so far (across suspensions).
    pub rounds_done: u64,
    /// How many times this job was preempted by a higher-priority one.
    pub preemptions: u64,
    /// Set by the control protocol to cancel a *running* job; the
    /// scheduler honours it at the next round boundary.
    pub cancel_requested: bool,
}

/// All jobs ever submitted to this daemon (terminal jobs stay, so
/// `status` can report them), plus the id counter.
pub struct JobQueue {
    jobs: Vec<Job>,
    next_id: JobId,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue { jobs: Vec::new(), next_id: 1 }
    }

    /// Enqueue a job; an empty `name` gets the default `job-<id>`.
    pub fn submit(&mut self, name: &str, priority: i64, cfg: TrainConfig) -> JobId {
        let id = self.next_id;
        self.next_id += 1;
        let name =
            if name.is_empty() { format!("job-{id}") } else { name.to_string() };
        self.jobs.push(Job {
            id,
            name,
            priority,
            cfg,
            state: JobState::Queued,
            checkpoint: None,
            result: None,
            final_theta: None,
            error: None,
            rounds_done: 0,
            preemptions: 0,
            cancel_requested: false,
        });
        id
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }

    pub fn job_mut(&mut self, id: JobId) -> Option<&mut Job> {
        self.jobs.iter_mut().find(|j| j.id == id)
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    fn runnable(&self) -> impl Iterator<Item = &Job> {
        self.jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Queued | JobState::Suspended))
    }

    /// The job the scheduler should run next: highest priority, FIFO
    /// (lowest id) among equals. Suspended jobs compete on the same
    /// terms as queued ones.
    pub fn next_runnable(&self) -> Option<JobId> {
        self.runnable()
            .max_by(|a, b| a.priority.cmp(&b.priority).then(b.id.cmp(&a.id)))
            .map(|j| j.id)
    }

    /// Highest priority waiting to run — the preemption check: a running
    /// job yields when this is *strictly* above its own priority.
    pub fn best_waiting_priority(&self) -> Option<i64> {
        self.runnable().map(|j| j.priority).max()
    }

    /// Any job still queued, suspended, or running?
    pub fn has_unfinished(&self) -> bool {
        self.jobs.iter().any(|j| !j.state.is_terminal())
    }
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrainConfig {
        TrainConfig::preset("quadratic", "dist-sgd")
    }

    #[test]
    fn fifo_within_equal_priority() {
        let mut q = JobQueue::new();
        let a = q.submit("a", 0, cfg());
        let b = q.submit("b", 0, cfg());
        assert_eq!(q.next_runnable(), Some(a));
        q.job_mut(a).unwrap().state = JobState::Done;
        assert_eq!(q.next_runnable(), Some(b));
        q.job_mut(b).unwrap().state = JobState::Cancelled;
        assert_eq!(q.next_runnable(), None);
        assert!(!q.has_unfinished());
    }

    #[test]
    fn priority_beats_submission_order() {
        let mut q = JobQueue::new();
        let low = q.submit("low", -1, cfg());
        let mid = q.submit("", 0, cfg());
        let high = q.submit("high", 3, cfg());
        assert_eq!(q.next_runnable(), Some(high));
        assert_eq!(q.best_waiting_priority(), Some(3));
        q.job_mut(high).unwrap().state = JobState::Running;
        // Running jobs are not "waiting": only queued/suspended compete.
        assert_eq!(q.next_runnable(), Some(mid));
        assert_eq!(q.best_waiting_priority(), Some(0));
        assert_eq!(q.job(mid).unwrap().name, "job-2");
        q.job_mut(mid).unwrap().state = JobState::Failed;
        assert_eq!(q.next_runnable(), Some(low));
    }

    #[test]
    fn suspended_jobs_compete_again() {
        let mut q = JobQueue::new();
        let a = q.submit("a", 5, cfg());
        let b = q.submit("b", 1, cfg());
        q.job_mut(a).unwrap().state = JobState::Suspended;
        // Suspended-but-higher-priority beats queued-but-lower.
        assert_eq!(q.next_runnable(), Some(a));
        q.job_mut(a).unwrap().state = JobState::Cancelled;
        assert_eq!(q.next_runnable(), Some(b));
    }

    #[test]
    fn ids_are_monotonic_from_one()  {
        let mut q = JobQueue::new();
        assert_eq!(q.submit("", 0, cfg()), 1);
        assert_eq!(q.submit("", 9, cfg()), 2);
        assert_eq!(q.submit("", -9, cfg()), 3);
        assert!(q.job(4).is_none());
    }
}
