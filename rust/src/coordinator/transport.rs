//! Leader↔worker message plumbing behind the event-driven runtime.
//!
//! The [`Transport`] trait is the runtime's only view of the cluster: it
//! pushes a θ downlink at one worker ([`Transport::send_downlink`]) and
//! pulls the next uplink arrival ([`Transport::recv_event`]) — nothing in
//! the runtime or the protocols knows whether workers live on the leader
//! thread, on OS threads, or (eventually) in other processes.
//!
//! Three implementations ship:
//!
//! - [`InProc`] — the in-process channels of [`WorkerPool`], exactly the
//!   plumbing the lockstep trainer used: payloads move as Rust values,
//!   nothing is serialized.
//! - [`Loopback`] — the same worker pool, but **every** message (the θ
//!   downlink and each uplink) is round-tripped through the byte-level
//!   [`Envelope`] framing: `encode` on one side of the notional wire,
//!   `decode` on the other. This proves process-boundary readiness
//!   without sockets: a run over `Loopback` is bitwise identical to one
//!   over `InProc` (asserted by the transport property test), so moving a
//!   worker behind a real socket is a transport swap, not a protocol
//!   change.
//! - [`Tcp`](super::net::Tcp) — real worker **processes** over localhost
//!   sockets, speaking the same `Envelope` frames wrapped in the
//!   length-prefixed wire framing of [`super::net`]. Workers are separate
//!   OS processes (spawned by the [`supervisor`](super::supervisor) or
//!   launched by hand with `comp-ams worker --leader ADDR`); a worker
//!   whose connection drops surfaces as [`Event::Exit`] and becomes a
//!   permanent straggler under partial participation.
//!
//! A fourth, composite spelling — `sim:inproc` / `sim:loopback` — wraps
//! either in-process transport in the seeded network simulator
//! ([`super::sim::Sim`]): per-link latency, jitter, bandwidth, and
//! retransmit delay on a virtual clock, deterministic from `--sim-seed`.
//!
//! ## Envelope wire format
//!
//! An [`Envelope`] frames one message with a fixed 16-byte little-endian
//! header followed by the payload's own self-describing byte layout
//! ([`Payload::encode`]):
//!
//! ```text
//! | wid u32 | round u64 | loss f32 | payload bytes ... |
//! ```
//!
//! `wid` is the sender (receiver for a downlink), `round` is the round
//! the message belongs to — the tag partial participation uses to detect
//! staleness — and the f32 slot is the per-direction scalar: the
//! worker's batch loss on an uplink, the round's learning rate on a
//! downlink. That makes each direction self-contained: a remote worker
//! reconstructs its whole `RoundCtx` from the frame (round + lr, with
//! `observed_round = round` since a dispatch is always synchronous), and
//! the leader gets everything it consumes from the uplink frame —
//! which `Loopback` proves by rebuilding both from decoded bytes alone.
//! [`Envelope::wire_bits`] counts the full frame, header included; the
//! communication ledger keeps charging [`Payload::wire_bits`] so that
//! uplink accounting is identical across transports (the 128-bit header
//! is framing, not gradient payload).

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::algo::RoundCtx;
use crate::compress::{Payload, PayloadView, Scalars};

use super::cluster::WorkerPool;
use super::sim::{LinkStats, Sim, SimProfile};

/// Fixed frame header: `wid u32 | round u64 | loss f32`.
pub const ENVELOPE_HEADER_BYTES: usize = 16;

/// Serialize one envelope frame — header plus payload body — straight
/// into `out`, appending (the zero-copy fast path; see the scratch-buffer
/// contract in [`crate::compress::wire`]). Byte-identical to
/// [`Envelope::encode`] for the same fields, but takes a borrowed
/// [`PayloadView`] so the caller never has to own the payload: the TCP
/// leader encodes its θ downlink directly from the live `&[f32]` slice.
pub fn encode_envelope_into(
    wid: u32,
    round: u64,
    loss: f32,
    payload: &PayloadView<'_>,
    out: &mut Vec<u8>,
) {
    out.reserve(ENVELOPE_HEADER_BYTES + (payload.wire_bits() / 8) as usize);
    out.extend_from_slice(&wid.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&loss.to_le_bytes());
    payload.encode_into(out);
}

/// A borrowed decode of one envelope frame: header fields by value,
/// payload as a [`PayloadView`] into the frame bytes. Validates exactly
/// what [`Envelope::decode`] validates (which is now a thin
/// `parse().to_owned()` over this), but materializes nothing.
#[derive(Clone, Copy, Debug)]
pub struct EnvelopeView<'a> {
    pub wid: u32,
    pub round: u64,
    pub loss: f32,
    pub payload: PayloadView<'a>,
}

impl<'a> EnvelopeView<'a> {
    /// Borrowed decode of a wire frame; rejects exactly the byte strings
    /// [`Envelope::decode`] rejects.
    pub fn parse(buf: &'a [u8]) -> Result<EnvelopeView<'a>> {
        if buf.len() < ENVELOPE_HEADER_BYTES {
            bail!("envelope truncated: {} bytes", buf.len());
        }
        let wid = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        let round = u64::from_le_bytes(buf[4..12].try_into().unwrap());
        let loss = f32::from_le_bytes(buf[12..16].try_into().unwrap());
        let payload = PayloadView::parse(&buf[ENVELOPE_HEADER_BYTES..])?;
        Ok(EnvelopeView { wid, round, loss, payload })
    }

    /// Materialize an owned [`Envelope`] (copies the payload fields out of
    /// the frame bytes).
    pub fn to_owned(self) -> Envelope {
        Envelope {
            wid: self.wid,
            round: self.round,
            loss: self.loss,
            payload: self.payload.to_owned(),
        }
    }

    /// Exact frame size in bits, header included.
    pub fn wire_bits(&self) -> u64 {
        (ENVELOPE_HEADER_BYTES as u64) * 8 + self.payload.wire_bits()
    }
}

/// One framed leader↔worker message (see the module docs for the byte
/// layout).
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    /// Sending worker id (receiving worker id for a downlink).
    pub wid: u32,
    /// The round this message belongs to. For an uplink this is the round
    /// the gradient was computed at — the staleness tag.
    pub round: u64,
    /// Per-direction scalar: the worker's batch loss on an uplink, the
    /// round's learning rate on a downlink (so the receiving side can
    /// rebuild its `RoundCtx` from the frame alone).
    pub loss: f32,
    pub payload: Payload,
}

impl Envelope {
    /// Serialize to the wire frame: 16-byte header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity((self.wire_bits() / 8) as usize);
        self.encode_into(&mut out);
        out
    }

    /// Append the wire frame to `out` — byte-identical to
    /// [`Envelope::encode`], but reusing the caller's buffer.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        encode_envelope_into(self.wid, self.round, self.loss, &self.payload.view(), out);
    }

    /// Decode a wire frame; exact inverse of [`Envelope::encode`]
    /// (bitwise, including the loss and every payload kind). A thin
    /// `.to_owned()` over [`EnvelopeView::parse`].
    pub fn decode(buf: &[u8]) -> Result<Envelope> {
        Ok(EnvelopeView::parse(buf)?.to_owned())
    }

    /// Exact frame size in bits: the 16-byte header plus the payload's
    /// own `wire_bits` (`== 8 * encode().len()`).
    pub fn wire_bits(&self) -> u64 {
        (ENVELOPE_HEADER_BYTES as u64) * 8 + self.payload.wire_bits()
    }
}

/// One received uplink, holding either the worker's payload as a Rust
/// value (in-process transports) or the raw envelope frame bytes exactly
/// as they crossed the wire (byte transports). Either way the server
/// consumes it through [`UplinkMsg::payload`] as a borrowed
/// [`PayloadView`] — the frame case never materializes owned index/value
/// vectors, which is the zero-copy uplink path.
#[derive(Clone, Debug)]
pub struct UplinkMsg {
    wid: u32,
    round: u64,
    loss: f32,
    body: UplinkBody,
}

#[derive(Clone, Debug)]
enum UplinkBody {
    /// In-process: the payload as a value, no serialization happened.
    Value(Payload),
    /// Byte transports: the full envelope frame (16-byte header +
    /// payload body), validated once at construction.
    Frame(Vec<u8>),
}

impl UplinkMsg {
    /// Wrap an in-process payload (no bytes involved).
    pub fn from_payload(wid: u32, round: u64, loss: f32, payload: Payload) -> UplinkMsg {
        UplinkMsg { wid, round, loss, body: UplinkBody::Value(payload) }
    }

    /// Take ownership of a received envelope frame. Parses (and so
    /// validates) the frame exactly once; every later
    /// [`payload`](UplinkMsg::payload) re-borrows the already-validated
    /// bytes.
    pub fn from_frame(frame: Vec<u8>) -> Result<UplinkMsg> {
        let v = EnvelopeView::parse(&frame)?;
        let (wid, round, loss) = (v.wid, v.round, v.loss);
        Ok(UplinkMsg { wid, round, loss, body: UplinkBody::Frame(frame) })
    }

    pub fn wid(&self) -> u32 {
        self.wid
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn loss(&self) -> f32 {
        self.loss
    }

    /// Borrow the gradient payload. For a frame-backed uplink this is a
    /// view straight into the received bytes — no owned vectors.
    pub fn payload(&self) -> PayloadView<'_> {
        match &self.body {
            UplinkBody::Value(p) => p.view(),
            UplinkBody::Frame(f) => PayloadView::parse(&f[ENVELOPE_HEADER_BYTES..])
                .expect("uplink frame validated at construction"),
        }
    }

    /// The payload's wire size in bits (what the comm ledger charges —
    /// framing is billed separately).
    pub fn payload_wire_bits(&self) -> u64 {
        match &self.body {
            UplinkBody::Value(p) => p.wire_bits(),
            UplinkBody::Frame(f) => ((f.len() - ENVELOPE_HEADER_BYTES) as u64) * 8,
        }
    }

    /// Full frame size in bits, envelope header included.
    pub fn wire_bits(&self) -> u64 {
        (ENVELOPE_HEADER_BYTES as u64) * 8 + self.payload_wire_bits()
    }
}

/// One transport arrival, as the runtime's event loop consumes it.
#[derive(Debug)]
pub enum Event {
    Uplink {
        /// Sending worker.
        wid: usize,
        /// The round the worker computed at (== `msg.round()`).
        round: u64,
        msg: UplinkMsg,
    },
    /// Worker `wid`'s connection is gone (process crashed or socket
    /// dropped). Only process-boundary transports emit this; the runtime
    /// turns the worker into a *permanent straggler*: never re-dispatched,
    /// and any uplink it still owed is counted in `dropped_uplinks`.
    Exit {
        wid: usize,
    },
}

/// The leader's asynchronous view of the worker cluster.
///
/// A transport delivers every dispatched round eventually (in-process
/// transports never lose messages), but makes **no ordering promise**
/// across workers: `recv_event` yields genuine arrival order, which is
/// what lets the runtime take the first K uplinks of a round and treat
/// the rest as stragglers.
pub trait Transport {
    /// Number of workers behind this transport.
    fn n_workers(&self) -> usize;

    /// Send θ for round `ctx.round` to worker `wid` and start its round.
    /// Returns `Ok(false)` when the worker's connection is already gone
    /// (a crashed remote process) — the caller must treat the worker as
    /// dead rather than dispatched. In-process transports always return
    /// `Ok(true)`; a hard `Err` still means the transport itself broke.
    fn send_downlink(
        &mut self,
        wid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool>;

    /// Block until the next uplink (or worker exit) arrives.
    fn recv_event(&mut self) -> Result<Event>;

    /// Per-message framing overhead in bits, on top of
    /// [`Payload::wire_bits`]: what the ledger bills as `framing_bits`
    /// for every consumed uplink and dispatched downlink. Zero for
    /// [`InProc`] (no serialization), the 16-byte [`Envelope`] header for
    /// [`Loopback`], envelope + socket frame header for TCP.
    fn frame_overhead_bits(&self) -> u64 {
        0
    }

    /// Wire bits of one downlink message this round, billed per
    /// dispatched worker. The default is the dense θ payload codec
    /// (`8 × (5 + 4·dim)`, tag byte + dim word + f32s) every flat-star
    /// transport ships; the tree transport overrides this with the
    /// compressed θ-delta payload's real encoded length when
    /// `--downlink-compress` is active. The runtime reads this *after*
    /// the dispatch loop, so transports that encode the broadcast once
    /// per round can report the cached encoding's exact size.
    fn downlink_wire_bits(&self, dim: usize) -> u64 {
        8 * (5 + 4 * dim as u64)
    }

    /// Tell every live worker the run is over (a SHUTDOWN broadcast for
    /// socket transports; no-op in process). Called once after the final
    /// drain; must be idempotent.
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }

    /// Release the workers from this job without terminating them, and —
    /// when `want_state` — collect each worker's suspend blob
    /// ([`export_worker_blob`](super::cluster::export_worker_blob)) so
    /// the job can later resume bitwise-identically. One entry per
    /// worker: `Some(blob)` for a worker that answered, `None` for a dead
    /// worker (or when its state was not requested). Only callable with
    /// no uplinks in flight; after a detach the transport is spent. The
    /// scheduler uses this to hand a pooled fleet from one job to the
    /// next ([`crate::coordinator::scheduler`]).
    fn detach(&mut self, _want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        bail!("transport does not support detach")
    }

    /// Re-admit workers whose connection died: accept any late HELLOs
    /// pending on the transport's listen socket and re-ASSIGN each onto a
    /// dead worker id, fresh-state (a rejoiner's error-feedback
    /// accumulator died with the old process; the runtime accounts that
    /// loss — see [`CommLedger::ef_residual_lost_bits`]
    /// (super::comm::CommLedger)). Returns the revived worker ids.
    /// Never blocks: with no pending connection it returns immediately.
    /// In-process workers cannot die, so the default revives nothing.
    fn try_rejoin(&mut self) -> Result<Vec<usize>> {
        Ok(Vec::new())
    }

    /// Per-link delivery statistics (delivered / retransmitted /
    /// reordered / cumulative virtual delay), one entry per worker id.
    /// Only the seeded network simulator ([`super::sim::Sim`]) collects
    /// these; every real transport reports none.
    fn link_stats(&self) -> Vec<LinkStats> {
        Vec::new()
    }
}

/// In-process transport: messages move as Rust values over the pool's
/// mpsc channels (or the sequential queue) — today's plumbing, zero
/// serialization.
pub struct InProc {
    pool: WorkerPool,
}

impl InProc {
    pub fn new(pool: WorkerPool) -> Self {
        InProc { pool }
    }
}

impl Transport for InProc {
    fn n_workers(&self) -> usize {
        self.pool.len()
    }

    fn send_downlink(
        &mut self,
        wid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool> {
        self.pool.send(wid, theta, ctx)?;
        Ok(true)
    }

    fn recv_event(&mut self) -> Result<Event> {
        let (wid, round, wr) = self.pool.recv()?;
        let msg = UplinkMsg::from_payload(wid as u32, round, wr.loss, wr.payload);
        Ok(Event::Uplink { wid, round, msg })
    }

    fn detach(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        detach_pool(&mut self.pool, want_state)
    }
}

/// Shared detach path for the two pool-backed transports: in process
/// there is nothing to release, so a detach is just the optional state
/// export.
fn detach_pool(pool: &mut WorkerPool, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
    if !want_state {
        return Ok(vec![None; pool.len()]);
    }
    Ok(pool.export_states()?.into_iter().map(Some).collect())
}

/// Wire-framing transport: every downlink and uplink is encoded to bytes
/// and decoded back through [`Envelope`], so a run over `Loopback`
/// exercises exactly the serialization a socket transport would — while
/// staying bitwise identical to [`InProc`] (f32 values survive the
/// little-endian round trip exactly).
pub struct Loopback {
    pool: WorkerPool,
    /// Pooled downlink scratch: the θ envelope frame is encoded **once**
    /// per `(round, lr)` and reused for every worker — only the 4-byte
    /// wid header field is re-patched. Capacity is retained across
    /// rounds, so steady-state downlinks allocate nothing here.
    scratch: Vec<u8>,
    scratch_key: Option<(u64, u32)>,
}

impl Loopback {
    pub fn new(pool: WorkerPool) -> Self {
        Loopback { pool, scratch: Vec::new(), scratch_key: None }
    }
}

impl Transport for Loopback {
    fn n_workers(&self) -> usize {
        self.pool.len()
    }

    fn send_downlink(
        &mut self,
        wid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool> {
        // θ is serialized straight off the live slice (no owned Payload,
        // no body Vec); repeat sends within a round just re-patch the wid.
        let key = (ctx.round, ctx.lr.to_bits());
        if self.scratch_key == Some(key) {
            self.scratch[0..4].copy_from_slice(&(wid as u32).to_le_bytes());
        } else {
            self.scratch.clear();
            encode_envelope_into(
                wid as u32,
                ctx.round,
                ctx.lr,
                &PayloadView::Dense(Scalars::Slice(theta.as_slice())),
                &mut self.scratch,
            );
            self.scratch_key = Some(key);
        }
        let dec = EnvelopeView::parse(&self.scratch)?;
        ensure!(
            dec.wid as usize == wid && dec.round == ctx.round,
            "loopback downlink header corrupted"
        );
        let theta = match dec.payload {
            PayloadView::Dense(s) => Arc::new(s.to_vec()),
            other => bail!("loopback downlink decoded to {other:?}, expected dense θ"),
        };
        // The worker-side RoundCtx comes entirely off the wire: a
        // dispatch is always synchronous, so (round, lr) is the whole
        // context — exactly what a remote worker process would rebuild.
        let wire_ctx = RoundCtx::sync(dec.round, dec.loss);
        self.pool.send(wid, &theta, &wire_ctx)?;
        Ok(true)
    }

    fn recv_event(&mut self) -> Result<Event> {
        let (wid, round, wr) = self.pool.recv()?;
        let mut frame =
            Vec::with_capacity(ENVELOPE_HEADER_BYTES + (wr.payload.wire_bits() / 8) as usize);
        encode_envelope_into(wid as u32, round, wr.loss, &wr.payload.view(), &mut frame);
        let msg = UplinkMsg::from_frame(frame)?;
        ensure!(
            msg.wid() as usize == wid && msg.round() == round,
            "loopback uplink header corrupted"
        );
        Ok(Event::Uplink { wid, round, msg })
    }

    fn frame_overhead_bits(&self) -> u64 {
        (ENVELOPE_HEADER_BYTES as u64) * 8
    }

    fn detach(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        detach_pool(&mut self.pool, want_state)
    }
}

/// The valid `--transport` spellings, for every error message that has
/// to enumerate them.
pub const TRANSPORT_CHOICES: &str =
    "inproc | loopback | tcp[:port] | sim:inproc | sim:loopback";

/// The transports the seeded network simulator can wrap: in-process
/// only. `sim:tcp` is rejected at parse time — the simulator re-times
/// arrivals on a virtual clock, which real sockets (with their own
/// physical timing) would fight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimInner {
    InProc,
    Loopback,
}

impl SimInner {
    /// The plain spec of the wrapped transport.
    pub fn spec(self) -> TransportSpec {
        match self {
            SimInner::InProc => TransportSpec::InProc,
            SimInner::Loopback => TransportSpec::Loopback,
        }
    }
}

/// Parsed transport selector (`TrainConfig::transport` / `--transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportSpec {
    InProc,
    Loopback,
    /// Multi-process workers over localhost sockets
    /// ([`super::net::Tcp`]; the listener deliberately binds loopback
    /// only — cross-host clusters would need an authenticated bind
    /// address first). `port` 0 (the bare `tcp` spelling) binds an
    /// ephemeral port.
    Tcp { port: u16 },
    /// An in-process transport wrapped in the seeded network simulator
    /// ([`super::sim::Sim`], `--sim-seed` / `--sim-profile`).
    Sim { inner: SimInner },
}

impl TransportSpec {
    pub fn parse(s: &str) -> Result<TransportSpec> {
        match s {
            "inproc" => Ok(TransportSpec::InProc),
            "loopback" => Ok(TransportSpec::Loopback),
            "tcp" => Ok(TransportSpec::Tcp { port: 0 }),
            "sim:inproc" => Ok(TransportSpec::Sim { inner: SimInner::InProc }),
            "sim:loopback" => Ok(TransportSpec::Sim { inner: SimInner::Loopback }),
            other => {
                if let Some(port) = other.strip_prefix("tcp:") {
                    let port: u16 = port.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "bad tcp port '{port}' in transport '{other}' \
                             (valid transports: {TRANSPORT_CHOICES})"
                        )
                    })?;
                    return Ok(TransportSpec::Tcp { port });
                }
                if let Some(inner) = other.strip_prefix("sim:") {
                    if inner == "tcp" || inner.starts_with("tcp:") {
                        bail!(
                            "sim cannot wrap tcp: the simulator re-times arrivals \
                             on a virtual clock, which needs in-process workers \
                             (valid transports: {TRANSPORT_CHOICES})"
                        );
                    }
                    bail!(
                        "unknown sim inner transport '{inner}' \
                         (valid transports: {TRANSPORT_CHOICES})"
                    );
                }
                bail!("unknown transport '{other}' (valid transports: {TRANSPORT_CHOICES})")
            }
        }
    }

    /// True for transports whose workers live in other processes (and
    /// therefore need no leader-side worker pool).
    pub fn is_multiprocess(self) -> bool {
        matches!(self, TransportSpec::Tcp { .. })
    }

    /// Wrap a worker pool in this transport. Multi-process transports
    /// have no pool to wrap — the trainer assembles
    /// [`super::net::Tcp`] directly (listener + handshake + optional
    /// supervisor), so building them here is an error.
    pub fn build(self, pool: WorkerPool) -> Result<Box<dyn Transport>> {
        match self {
            TransportSpec::InProc => Ok(Box::new(InProc::new(pool))),
            TransportSpec::Loopback => Ok(Box::new(Loopback::new(pool))),
            TransportSpec::Tcp { .. } => {
                bail!("tcp transport is assembled by the trainer, not from a worker pool")
            }
            TransportSpec::Sim { .. } => {
                bail!(
                    "sim transport needs its seed and profile — use \
                     TransportSpec::build_sim (the trainer does)"
                )
            }
        }
    }

    /// Wrap a worker pool in the seeded network simulator around this
    /// spec's inner transport ([`Sim`]). Only valid for `sim:*` specs.
    pub fn build_sim(
        self,
        pool: WorkerPool,
        seed: u64,
        profile: SimProfile,
    ) -> Result<Box<dyn Transport>> {
        match self {
            TransportSpec::Sim { inner: SimInner::InProc } => {
                Ok(Box::new(Sim::new(InProc::new(pool), seed, profile)))
            }
            TransportSpec::Sim { inner: SimInner::Loopback } => {
                Ok(Box::new(Sim::new(Loopback::new(pool), seed, profile)))
            }
            other => bail!("build_sim on non-sim transport {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::{f32_to_f16, pack_signs};

    fn sample_payloads() -> Vec<Payload> {
        let x = vec![1.0f32, -2.5, 0.0, 3.25, -0.125];
        vec![
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 9, idx: vec![1, 7], val: vec![0.5, -3.0] },
            Payload::Signs { dim: 5, block: 2, scales: vec![1.0, 2.0, 0.5], bits: pack_signs(&x) },
            Payload::LayeredSigns {
                dim: 5,
                sizes: vec![2, 3],
                scales: vec![1.5, 0.25],
                bits: pack_signs(&x),
            },
            Payload::Quantized { dim: 4, norm: 8.0, levels: 4, q: vec![-4, 0, 2, 4] },
            Payload::SparseF16 {
                dim: 6,
                idx: vec![0, 5],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0)],
            },
        ]
    }

    #[test]
    fn envelope_roundtrips_every_payload_kind() {
        for (i, p) in sample_payloads().into_iter().enumerate() {
            let env = Envelope { wid: i as u32, round: 41 + i as u64, loss: -0.75, payload: p };
            let bytes = env.encode();
            assert_eq!(bytes.len() as u64 * 8, env.wire_bits(), "kind {i}");
            assert_eq!(
                env.wire_bits(),
                ENVELOPE_HEADER_BYTES as u64 * 8 + env.payload.wire_bits()
            );
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(back, env, "kind {i}");
            assert_eq!(back.loss.to_bits(), env.loss.to_bits());
            // encode_into appends byte-identically, and the borrowed
            // parse agrees with the owned decode.
            let mut buf = vec![0xEE];
            env.encode_into(&mut buf);
            assert_eq!(&buf[1..], &bytes[..]);
            let view = EnvelopeView::parse(&bytes).unwrap();
            assert_eq!(view.wire_bits(), env.wire_bits());
            assert_eq!(view.to_owned(), env);
        }
    }

    #[test]
    fn uplink_msg_frame_and_value_agree() {
        for (i, p) in sample_payloads().into_iter().enumerate() {
            let env =
                Envelope { wid: 7 + i as u32, round: 100 + i as u64, loss: 0.5, payload: p };
            let by_frame = UplinkMsg::from_frame(env.encode()).unwrap();
            let by_value =
                UplinkMsg::from_payload(env.wid, env.round, env.loss, env.payload.clone());
            assert_eq!(by_frame.wid(), by_value.wid());
            assert_eq!(by_frame.round(), by_value.round());
            assert_eq!(by_frame.loss().to_bits(), by_value.loss().to_bits());
            assert_eq!(by_frame.payload().to_owned(), env.payload);
            assert_eq!(by_value.payload().to_owned(), env.payload);
            assert_eq!(by_frame.payload_wire_bits(), env.payload.wire_bits());
            assert_eq!(by_value.payload_wire_bits(), env.payload.wire_bits());
            assert_eq!(by_frame.wire_bits(), env.wire_bits());
        }
        // A corrupt frame is rejected at construction, not at use.
        let mut bad = Envelope {
            wid: 0,
            round: 0,
            loss: 0.0,
            payload: Payload::Dense(vec![1.0]),
        }
        .encode();
        bad[ENVELOPE_HEADER_BYTES] = 99;
        assert!(UplinkMsg::from_frame(bad).is_err());
    }

    #[test]
    fn envelope_decode_rejects_corruption() {
        let env = Envelope {
            wid: 3,
            round: 9,
            loss: 1.5,
            payload: Payload::Dense(vec![1.0, 2.0]),
        };
        let bytes = env.encode();
        // Truncated header, truncated body, trailing garbage.
        assert!(Envelope::decode(&bytes[..8]).is_err());
        assert!(Envelope::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(Envelope::decode(&longer).is_err());
        // Bad payload tag inside an intact header.
        let mut bad = bytes;
        bad[ENVELOPE_HEADER_BYTES] = 99;
        assert!(Envelope::decode(&bad).is_err());
    }

    #[test]
    fn transport_spec_parses_and_rejects() {
        assert_eq!(TransportSpec::parse("inproc").unwrap(), TransportSpec::InProc);
        assert_eq!(TransportSpec::parse("loopback").unwrap(), TransportSpec::Loopback);
        assert_eq!(TransportSpec::parse("tcp").unwrap(), TransportSpec::Tcp { port: 0 });
        assert_eq!(
            TransportSpec::parse("tcp:7001").unwrap(),
            TransportSpec::Tcp { port: 7001 }
        );
        assert_eq!(
            TransportSpec::parse("sim:inproc").unwrap(),
            TransportSpec::Sim { inner: SimInner::InProc }
        );
        assert_eq!(
            TransportSpec::parse("sim:loopback").unwrap(),
            TransportSpec::Sim { inner: SimInner::Loopback }
        );
        assert_eq!(SimInner::Loopback.spec(), TransportSpec::Loopback);
        assert!(TransportSpec::Tcp { port: 0 }.is_multiprocess());
        assert!(!TransportSpec::InProc.is_multiprocess());
        // The simulator runs in the leader process over a worker pool.
        assert!(!TransportSpec::parse("sim:inproc").unwrap().is_multiprocess());
        // Unknown spellings and bad ports enumerate the valid choices.
        for bad in ["udp", "tcp:notaport", "tcp:70000", "sim:udp", "sim:"] {
            let err = TransportSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains("inproc | loopback | tcp[:port]"), "{bad}: {err}");
        }
        // Sim over real sockets is a parse-time contradiction.
        for bad in ["sim:tcp", "sim:tcp:7000"] {
            let err = TransportSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains("sim cannot wrap tcp"), "{bad}: {err}");
            assert!(err.contains(TRANSPORT_CHOICES), "{bad}: {err}");
        }
    }

    #[test]
    fn loopback_uplink_survives_framing_bitwise() {
        use crate::algo::AlgoSpec;
        use crate::grad::quadratic::QuadraticProblem;
        use crate::grad::GradSource;

        let n = 3;
        let problem = QuadraticProblem::new(1, 16, n, 4.0, 0.5, 1.0);
        let mk_pool = || {
            let sources: Vec<Box<dyn GradSource>> = (0..n)
                .map(|w| Box::new(problem.source_for(w, 7)) as Box<dyn GradSource>)
                .collect();
            let algos = AlgoSpec::parse("comp-ams-topk:0.3").unwrap().build(16, n, 100).0;
            WorkerPool::sequential(sources, algos).unwrap()
        };
        let mut inproc = InProc::new(mk_pool());
        let mut loopback = Loopback::new(mk_pool());
        let theta = Arc::new(vec![0.2f32; 16]);
        let ctx = RoundCtx::sync(0, 0.01);
        for wid in 0..n {
            inproc.send_downlink(wid, &theta, &ctx).unwrap();
            loopback.send_downlink(wid, &theta, &ctx).unwrap();
        }
        for _ in 0..n {
            let Event::Uplink { wid: wa, round: ra, msg: ma } = inproc.recv_event().unwrap()
            else {
                panic!("inproc emitted a non-uplink event")
            };
            let Event::Uplink { wid: wb, round: rb, msg: mb } = loopback.recv_event().unwrap()
            else {
                panic!("loopback emitted a non-uplink event")
            };
            assert_eq!((wa, ra), (wb, rb));
            assert_eq!((ma.wid(), ma.round()), (mb.wid(), mb.round()));
            assert_eq!(ma.loss().to_bits(), mb.loss().to_bits());
            assert_eq!(ma.payload().to_owned(), mb.payload().to_owned());
            assert_eq!(ma.payload_wire_bits(), mb.payload_wire_bits());
        }
        // Framing overhead: none in-process, the envelope header when
        // every message crosses the byte framing.
        assert_eq!(inproc.frame_overhead_bits(), 0);
        assert_eq!(
            loopback.frame_overhead_bits(),
            ENVELOPE_HEADER_BYTES as u64 * 8
        );
    }
}
