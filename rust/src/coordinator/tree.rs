//! Tree aggregation topology: sub-leaders between the workers and the
//! root (`--topology tree:<degree>[:<group-compressor>]`).
//!
//! The flat star dispatches θ to all n workers and collects n uplinks at
//! one leader — a fan-in that caps scale well before the paper's
//! "millions of users" regime. The tree splits the fleet into
//! ⌈n/degree⌉ contiguous **groups**, each owned by a sub-leader:
//!
//! ```text
//!                         root ClusterRuntime
//!                    θ̂ ↓ (compressed downlink)  ↑ C(ḡ_g + e_g)  (1 per group)
//!          ┌────────────────┬────────────────┐
//!     sub-leader 0     sub-leader 1     sub-leader 2        (TreeTransport)
//!      θ̂ ↓   ↑ ĝ_i      θ̂ ↓   ↑ ĝ_i      θ̂ ↓   ↑ ĝ_i
//!     w0 w1 w2 w3      w4 w5 w6 w7      w8 w9 ...           (group runtimes)
//! ```
//!
//! A sub-leader **is a [`ClusterRuntime`]** whose "server step" is the
//! aggregate-and-forward half
//! ([`GroupForwardServer`](crate::algo::group::GroupForwardServer)): it
//! runs its group at full participation, aggregates the group's uplinks
//! with the same estimator the root uses, re-compresses the aggregate
//! through its own error-feedback accumulator, and forwards exactly one
//! uplink to the root. The root additionally compresses **downlinks**
//! (`--downlink-compress <compressor>`): θ is sent as a compressed
//! θ-delta against the workers' reconstruction θ̂, whose un-transmitted
//! remainder `θ − θ̂` is next round's delta — the downlink direction's
//! error-feedback memory (Wang et al. 2111.00705's two-way compression).
//! Both directions ride the existing Envelope/frame protocol with no new
//! frame kinds: a forwarded group aggregate is an ordinary
//! [`UplinkMsg`], a compressed downlink an ordinary payload.
//!
//! ## Per-level bit accounting
//!
//! Every hop is billed exactly, by level:
//!
//! - **level 0** (sub-leader ↔ root): the root runtime charges each
//!   forwarded aggregate's payload bits as uplink, the (possibly
//!   compressed) θ-delta payload per dispatched group as downlink
//!   ([`Transport::downlink_wire_bits`]), and an envelope header per
//!   message as framing.
//! - **level 1** (worker ↔ sub-leader): each group runtime charges its
//!   own [`CommLedger`]; the trainer absorbs those deltas into the run
//!   ledger after every round ([`TreeHandle::absorb_level1`]), so
//!   `uplink_bits_by_level[0] + uplink_bits_by_level[1] == uplink_bits`
//!   holds exactly (same for downlink and framing).
//!
//! A killed sub-leader (`--tree-kill gid:round`, the fault-injection
//! hook) degrades the run to the surviving groups — the root's quorum
//! floor shrinks exactly like a dead worker in the flat star — and its
//! group's worker-side EF accumulators are charged to
//! `ef_resets`/`ef_residual_lost_bits` (they lived in the dead subtree),
//! on top of the sub-leader's own EF residual which the root runtime
//! charges via [`ClusterRuntime::set_ef_state_bits`].
//!
//! ## Bitwise contract
//!
//! The degenerate tree — `degree ≥ n` (one group spanning every worker),
//! identity group compressor, no downlink compression — reproduces the
//! flat star **bitwise in loss and θ**: the single group aggregates the
//! same payloads in the same wid order with the same estimator, the
//! identity forward is the exact dense mean, and the root's mean over
//! one message is the identity. (Transmitted *bits* differ by
//! construction: the forwarded hop is a real extra message.) The
//! property suite gates this across all six protocol strings ×
//! inproc/loopback, like every prior abstraction layer. Note the group
//! loss/gradient forward is the *group mean*, so with several groups the
//! root computes a mean of group means — identical to the flat mean when
//! `degree` divides n, the usual deployment shape.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::algo::group::GroupForwardServer;
use crate::algo::RoundCtx;
use crate::compress::{Compressor, CompressorSpec};

use super::comm::CommLedger;
use super::runtime::ClusterRuntime;
use super::sim::LinkStats;
use super::transport::{Event, Transport, UplinkMsg, ENVELOPE_HEADER_BYTES};

/// The accepted `--topology` spellings, enumerated in every parse and
/// validation error.
pub const TOPOLOGY_CHOICES: &str = "flat | tree:<degree>[:<group-compressor>]";

/// Parsed topology selector (`TrainConfig::topology` / `--topology`).
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// The single-leader star every prior layer ran.
    Flat,
    /// Two-level tree: ⌈n/degree⌉ sub-leaders over contiguous groups of
    /// `degree` workers, each re-compressing its group aggregate with
    /// `group_compressor` (identity = forward the exact mean).
    Tree { degree: usize, group_compressor: CompressorSpec },
}

impl Topology {
    /// Parse `flat` (or empty) and `tree:<degree>[:<group-compressor>]`,
    /// e.g. `tree:8`, `tree:8:topk:0.05`.
    pub fn parse(s: &str) -> Result<Topology> {
        if s.is_empty() || s == "flat" {
            return Ok(Topology::Flat);
        }
        if let Some(rest) = s.strip_prefix("tree:") {
            let (deg_str, comp_str) = match rest.split_once(':') {
                Some((d, c)) => (d, Some(c)),
                None => (rest, None),
            };
            let degree: usize = deg_str.parse().map_err(|_| {
                anyhow!(
                    "bad tree degree '{deg_str}' in topology '{s}' \
                     (accepted forms: {TOPOLOGY_CHOICES})"
                )
            })?;
            ensure!(
                degree >= 2,
                "tree degree must be >= 2 — a 1-ary sub-leader aggregates nothing \
                 (accepted forms: {TOPOLOGY_CHOICES})"
            );
            let group_compressor = match comp_str {
                Some(c) => CompressorSpec::parse(c)?,
                None => CompressorSpec::Identity,
            };
            return Ok(Topology::Tree { degree, group_compressor });
        }
        bail!("unknown topology '{s}' (accepted forms: {TOPOLOGY_CHOICES})")
    }

    /// Number of sub-leader groups a tree over `n` workers builds
    /// (`None` for the flat star).
    pub fn group_count(&self, n: usize) -> Option<usize> {
        match self {
            Topology::Flat => None,
            Topology::Tree { degree, .. } => Some(n.div_ceil(*degree)),
        }
    }
}

/// Parse the `--tree-kill gid:round` fault-injection spec: sub-leader
/// `gid`'s process "dies" right before its round-`round` dispatch (its
/// whole group drops out; the run degrades to the survivors). Empty =
/// no kill.
pub fn parse_tree_kill(s: &str) -> Result<Option<(usize, u64)>> {
    if s.is_empty() {
        return Ok(None);
    }
    let (gid, round) = s
        .split_once(':')
        .ok_or_else(|| anyhow!("bad tree-kill '{s}' (accepted form: <gid>:<round>)"))?;
    Ok(Some((
        gid.parse()
            .map_err(|_| anyhow!("bad tree-kill group id '{gid}' (accepted form: <gid>:<round>)"))?,
        round
            .parse()
            .map_err(|_| anyhow!("bad tree-kill round '{round}' (accepted form: <gid>:<round>)"))?,
    )))
}

/// Downlink compressor state: θ is shipped as `C(θ − θ̂)` where θ̂ is the
/// workers' reconstruction, advanced only by decoded payloads — the
/// un-transmitted remainder is automatically next round's delta, so no
/// separate EF accumulator is needed in this direction.
struct DownlinkCodec {
    comp: Box<dyn Compressor>,
    theta_hat: Vec<f32>,
    delta: Vec<f32>,
}

impl DownlinkCodec {
    fn new(spec: &CompressorSpec, dim: usize) -> Self {
        DownlinkCodec {
            comp: spec.build(),
            theta_hat: vec![0.0; dim],
            delta: vec![0.0; dim],
        }
    }

    /// Encode this round's broadcast: compress the delta, advance θ̂ by
    /// the decoded payload, return the payload's wire bits (what one
    /// downlink message costs this round).
    fn encode_round(&mut self, theta: &[f32]) -> Result<u64> {
        for ((d, &t), &h) in self.delta.iter_mut().zip(theta).zip(&self.theta_hat) {
            *d = t - h;
        }
        let payload = self.comp.compress(&self.delta);
        let bits = payload.wire_bits();
        payload.view().add_into(&mut self.theta_hat)?;
        Ok(bits)
    }
}

/// One sub-leader: its group's runtime, forward server, private ledger,
/// and θ̂ scratch.
struct Group {
    runtime: ClusterRuntime,
    server: GroupForwardServer,
    ledger: CommLedger,
    scratch: Vec<f32>,
    size: usize,
    dead: bool,
}

struct TreeInner {
    groups: Vec<Group>,
    queue: VecDeque<Event>,
    down: Option<DownlinkCodec>,
    /// `(round, lr bits)` of the cached downlink encode — the broadcast
    /// is encoded once per round and shared by every group, exactly like
    /// the loopback/TCP downlink scratch.
    round_key: Option<(u64, u32)>,
    /// Wire bits of one downlink message under the cached encode (the
    /// dense-θ formula when no downlink compressor is configured).
    downlink_bits: u64,
    dim: usize,
    kill: Option<(usize, u64)>,
    /// Per-worker EF accumulator bits inside the groups (charged for a
    /// whole group when its sub-leader is killed); 0 for EF-free
    /// protocols.
    worker_ef_bits: u64,
}

/// The root's [`Transport`] over the sub-leaders: "worker id" at this
/// level is a group id, a downlink dispatch drives one full group round
/// synchronously, and the uplink is the group's forwarded compressed
/// aggregate. Shares state with a [`TreeHandle`] via `Rc<RefCell<…>>`
/// (legal: [`Transport`] is deliberately not `Send`-bound).
pub struct TreeTransport {
    inner: Rc<RefCell<TreeInner>>,
}

/// The trainer's handle onto the tree's shared state: per-round level-1
/// ledger absorption and group introspection.
#[derive(Clone)]
pub struct TreeHandle {
    inner: Rc<RefCell<TreeInner>>,
}

impl TreeTransport {
    /// Assemble the tree from per-group `(runtime, forward server, group
    /// size)` triples. `downlink` enables compressed θ-delta broadcasts;
    /// `kill` is the `--tree-kill` fault-injection spec; `worker_ef_bits`
    /// sizes the per-worker EF residual charged when a sub-leader dies.
    pub fn new(
        groups: Vec<(ClusterRuntime, GroupForwardServer, usize)>,
        dim: usize,
        downlink: Option<&CompressorSpec>,
        kill: Option<(usize, u64)>,
        worker_ef_bits: u64,
    ) -> Result<(TreeTransport, TreeHandle)> {
        ensure!(!groups.is_empty(), "tree topology needs at least one group");
        if let Some((gid, _)) = kill {
            ensure!(
                gid < groups.len(),
                "tree-kill group id {gid} is out of range for {} groups (valid ids: 0..{})",
                groups.len(),
                groups.len()
            );
        }
        let inner = TreeInner {
            groups: groups
                .into_iter()
                .map(|(runtime, server, size)| Group {
                    runtime,
                    server,
                    ledger: CommLedger::new(),
                    scratch: Vec::with_capacity(dim),
                    size,
                    dead: false,
                })
                .collect(),
            queue: VecDeque::new(),
            down: downlink.map(|spec| DownlinkCodec::new(spec, dim)),
            round_key: None,
            downlink_bits: 0,
            dim,
            kill,
            worker_ef_bits,
        };
        let inner = Rc::new(RefCell::new(inner));
        Ok((TreeTransport { inner: inner.clone() }, TreeHandle { inner }))
    }
}

impl Transport for TreeTransport {
    fn n_workers(&self) -> usize {
        self.inner.borrow().groups.len()
    }

    fn send_downlink(
        &mut self,
        gid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool> {
        let mut borrow = self.inner.borrow_mut();
        let inner = &mut *borrow;
        ensure!(gid < inner.groups.len(), "downlink to unknown group {gid}");
        if inner.groups[gid].dead {
            return Ok(false);
        }
        if inner.kill.is_some_and(|(g, r)| g == gid && ctx.round >= r) {
            // Fault injection: the sub-leader process dies before this
            // dispatch. Its workers' EF residuals die with the subtree;
            // charge them to the group ledger (absorbed at level 1). The
            // sub-leader's *own* EF residual is charged by the root
            // runtime's mark_dead, like any dead worker's.
            let g = &mut inner.groups[gid];
            g.dead = true;
            if inner.worker_ef_bits > 0 {
                g.ledger.ef_resets += g.size as u64;
                g.ledger.ef_residual_lost_bits += inner.worker_ef_bits * g.size as u64;
            }
            return Ok(false);
        }
        // Once-per-round downlink encode, shared across groups: θ̂ (and
        // the per-message bill) depends only on (round, lr), not on gid.
        let key = (ctx.round, ctx.lr.to_bits());
        if inner.round_key != Some(key) {
            inner.downlink_bits = match &mut inner.down {
                Some(codec) => codec.encode_round(theta)?,
                None => 8 * (5 + 4 * inner.dim as u64),
            };
            inner.round_key = Some(key);
        }
        let g = &mut inner.groups[gid];
        g.scratch.clear();
        match &inner.down {
            Some(codec) => g.scratch.extend_from_slice(&codec.theta_hat),
            None => g.scratch.extend_from_slice(theta.as_slice()),
        }
        // Drive the whole group round synchronously: dispatch θ̂ to the
        // group, collect at full participation, aggregate-and-forward.
        let outcome = g.runtime.run_round(
            &mut g.scratch,
            &mut g.server,
            ctx.round,
            ctx.lr,
            &mut g.ledger,
        )?;
        let payload = g
            .server
            .take_forwarded()
            .context("group round stepped but parked no forward payload")?;
        let msg =
            UplinkMsg::from_payload(gid as u32, ctx.round, outcome.train_loss, payload);
        inner.queue.push_back(Event::Uplink { wid: gid, round: ctx.round, msg });
        Ok(true)
    }

    fn recv_event(&mut self) -> Result<Event> {
        self.inner
            .borrow_mut()
            .queue
            .pop_front()
            .ok_or_else(|| anyhow!("tree transport has no queued sub-leader uplink"))
    }

    fn frame_overhead_bits(&self) -> u64 {
        // The sub-leader ↔ root hop carries ordinary envelope frames.
        (ENVELOPE_HEADER_BYTES as u64) * 8
    }

    fn downlink_wire_bits(&self, dim: usize) -> u64 {
        let inner = self.inner.borrow();
        if inner.round_key.is_some() {
            inner.downlink_bits
        } else {
            8 * (5 + 4 * dim as u64)
        }
    }

    fn shutdown(&mut self) -> Result<()> {
        for g in self.inner.borrow_mut().groups.iter_mut() {
            g.runtime.shutdown()?;
        }
        Ok(())
    }

    fn link_stats(&self) -> Vec<LinkStats> {
        Vec::new()
    }
}

impl TreeHandle {
    pub fn group_count(&self) -> usize {
        self.inner.borrow().groups.len()
    }

    /// Group ids whose sub-leader has died (via `--tree-kill`).
    pub fn dead_groups(&self) -> Vec<usize> {
        let inner = self.inner.borrow();
        (0..inner.groups.len()).filter(|&g| inner.groups[g].dead).collect()
    }

    /// Fold each group's private ledger into the run ledger at level 1
    /// and reset it, so repeated calls absorb only new deltas. Called by
    /// the trainer after every root round; the invariant
    /// `Σ *_bits_by_level == *_bits` holds after each call.
    pub fn absorb_level1(&self, root: &mut CommLedger) {
        let mut inner = self.inner.borrow_mut();
        for g in inner.groups.iter_mut() {
            let child = std::mem::take(&mut g.ledger);
            root.absorb_child(1, &child);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_parses_and_rejects() {
        assert_eq!(Topology::parse("").unwrap(), Topology::Flat);
        assert_eq!(Topology::parse("flat").unwrap(), Topology::Flat);
        assert_eq!(
            Topology::parse("tree:8").unwrap(),
            Topology::Tree { degree: 8, group_compressor: CompressorSpec::Identity }
        );
        assert_eq!(
            Topology::parse("tree:4:topk:0.05").unwrap(),
            Topology::Tree {
                degree: 4,
                group_compressor: CompressorSpec::TopK { ratio: 0.05 }
            }
        );
        assert_eq!(
            Topology::parse("tree:2:blocksign:64").unwrap(),
            Topology::Tree {
                degree: 2,
                group_compressor: CompressorSpec::BlockSign { block: 64 }
            }
        );
        for bad in ["star", "tree", "tree:", "tree:x", "tree:1", "tree:0", "tree:4:bogus"] {
            let err = Topology::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains(TOPOLOGY_CHOICES) || err.contains("compressor"),
                "{bad}: {err}"
            );
        }
    }

    #[test]
    fn group_count_rounds_up() {
        let t = Topology::parse("tree:3").unwrap();
        assert_eq!(t.group_count(9), Some(3));
        assert_eq!(t.group_count(10), Some(4));
        assert_eq!(t.group_count(2), Some(1));
        assert_eq!(Topology::Flat.group_count(8), None);
    }

    #[test]
    fn tree_kill_parses_and_rejects() {
        assert_eq!(parse_tree_kill("").unwrap(), None);
        assert_eq!(parse_tree_kill("1:40").unwrap(), Some((1, 40)));
        for bad in ["1", "x:4", "1:y", ":4"] {
            assert!(parse_tree_kill(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn downlink_codec_theta_hat_converges_under_identity() {
        // Identity downlink "compression": θ̂ tracks θ exactly after one
        // round, and each broadcast costs the dense payload.
        let mut c = DownlinkCodec::new(&CompressorSpec::Identity, 4);
        let theta = vec![1.0f32, -2.0, 0.5, 3.0];
        let bits = c.encode_round(&theta).unwrap();
        assert_eq!(bits, 8 * (5 + 4 * 4));
        assert_eq!(c.theta_hat, theta);
        // Second round with unchanged θ: the delta is exactly zero.
        c.encode_round(&theta).unwrap();
        assert_eq!(c.theta_hat, theta);
    }

    #[test]
    fn downlink_codec_residual_carries_over() {
        // Top-k delta: whatever a round leaves untransmitted reappears in
        // the next delta (θ̂ only advances by decoded payloads).
        let dim = 32;
        let mut c = DownlinkCodec::new(&CompressorSpec::TopK { ratio: 0.25 }, dim);
        let mut rng = crate::util::rng::Rng::seed(3);
        let theta: Vec<f32> = rng.normal_vec(dim);
        c.encode_round(&theta).unwrap();
        let err1: f32 = theta
            .iter()
            .zip(&c.theta_hat)
            .map(|(t, h)| (t - h).abs())
            .sum();
        assert!(err1 > 0.0, "top-k must leave reconstruction error");
        // Re-broadcasting the same θ shrinks the reconstruction error.
        for _ in 0..8 {
            c.encode_round(&theta).unwrap();
        }
        let err2: f32 = theta
            .iter()
            .zip(&c.theta_hat)
            .map(|(t, h)| (t - h).abs())
            .sum();
        assert!(err2 < err1 * 0.1, "θ̂ must converge to θ: {err1} -> {err2}");
    }
}
