//! TCP transport: real worker processes behind the [`Transport`] trait.
//!
//! This is the socket step the ROADMAP promised after PR 4: the protocol
//! and runtime layers are untouched — the leader still dispatches
//! [`Envelope`](super::transport::Envelope) downlinks and consumes
//! [`Event::Uplink`] arrivals — but
//! the workers now live in **other OS processes** (spawned by the
//! [`supervisor`](super::supervisor), or launched by hand with
//! `comp-ams worker --leader ADDR`).
//!
//! ## Wire frame
//!
//! Every message on a leader↔worker socket is one length-prefixed frame
//! (little-endian):
//!
//! ```text
//! | magic u32 = "CAM1" | kind u8 | len u32 | body: len bytes |
//! ```
//!
//! The magic doubles as a protocol version (`CAM1` → bump the trailing
//! byte on an incompatible change). Kinds:
//!
//! | kind       | direction       | body                                        |
//! |------------|-----------------|---------------------------------------------|
//! | `HELLO`    | worker → leader | empty (the magic carries the version)       |
//! | `ASSIGN`   | leader → worker | `wid u32 \| resume_len u32 \| resume bytes \| TrainConfig JSON` |
//! | `DOWNLINK` | leader → worker | envelope bytes (dense θ, lr slot)           |
//! | `UPLINK`   | worker → leader | envelope bytes (payload, loss slot)         |
//! | `SHUTDOWN` | leader → worker | empty                                       |
//! | `DETACH`   | leader → worker | `want_state u8` (job over; daemon stays)    |
//! | `STATE`    | worker → leader | worker suspend blob (empty unless wanted)   |
//!
//! The handshake assigns worker ids in accept order: a connecting worker
//! sends `HELLO`, the leader replies `ASSIGN{wid, resume, config}`, and
//! the worker rebuilds its gradient shard and protocol half from exactly
//! the constructors the in-process pool uses
//! ([`build_worker_parts`](super::trainer::build_worker_parts)) — which
//! is why a TCP run with K = n is bitwise identical to `InProc`. A
//! non-empty `resume` blob restores the worker half's suspended state
//! ([`import_worker_blob`](super::cluster::import_worker_blob)) so a
//! resumed job continues bitwise-identically.
//!
//! ## Pooled fleets
//!
//! `DETACH`/`STATE` exist for the resident scheduler
//! ([`super::scheduler`]): a worker daemon serves **many jobs** over one
//! connection. The leader ends a job with `DETACH{want_state}`; the
//! worker always answers with one `STATE` frame (its suspend blob when
//! wanted, empty otherwise — the reply doubles as a quiesce fence) and
//! returns to idle, waiting for the next `ASSIGN` or a final `SHUTDOWN`.
//! A pooled [`Tcp`] (built by [`assign_streams`] with `pooled = true`)
//! therefore detaches instead of closing sockets on shutdown, leaving
//! the fleet connected for the next job. `HELLO`/`ASSIGN`/`DETACH`/
//! `STATE` frames are control-plane and — like the handshake before
//! them — are *not* billed to the framing ledger, which stays exactly
//! `(downlinks + uplinks) × (frame + envelope headers)`.
//!
//! ## Failure model
//!
//! Each accepted worker gets one leader-side reader thread that
//! multiplexes its uplinks into the shared event channel. Malformed
//! frames and short reads surface as `Err` from
//! [`Transport::recv_event`] — poisoning the runtime like any transport
//! error —
//! while a **clean disconnect** (worker process died) becomes
//! [`Event::Exit`], which the runtime maps onto the partial-participation
//! machinery: the worker is a straggler, the quorum keeps stepping, and
//! its unfulfilled uplink lands in `dropped_uplinks`.
//!
//! ## Rejoin
//!
//! Death is no longer permanent. A [`Tcp`] that kept its listen socket
//! ([`Tcp::adopt_listener`] — [`TcpLeader::accept_workers`] does this
//! automatically) re-admits replacements mid-run: a late `HELLO` is
//! matched to a dead wid and answered with a fresh `ASSIGN` (empty
//! resume blob — the dead incarnation's error-feedback accumulator died
//! with its process; the runtime accounts the loss), and the wid's link
//! is rebuilt around the new socket. Each link carries a **generation**
//! number so events still queued from the dead incarnation's reader
//! (its `Event::Exit`, a straggling uplink) are recognized as ghosts
//! and dropped instead of being charged to the replacement.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algo::RoundCtx;
use crate::compress::{PayloadView, Scalars};
use crate::config::TrainConfig;

use super::transport::{
    encode_envelope_into, Event, Transport, UplinkMsg, ENVELOPE_HEADER_BYTES,
};

/// Wire magic, doubling as the protocol version ("CAM1").
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"CAM1");

/// Frame header: `magic u32 | kind u8 | len u32`.
pub const FRAME_HEADER_BYTES: usize = 9;

/// Frames larger than this are rejected as garbage before allocating.
const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Handshake/connect patience (accepting workers, reading ASSIGN).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

/// Patience for a rejoiner's HELLO after its connect: short — the
/// connection is already up, only the first frame is outstanding, and a
/// rejoin probe must not stall a running round for long.
const REJOIN_HELLO_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    Hello = 1,
    Assign = 2,
    Downlink = 3,
    Uplink = 4,
    Shutdown = 5,
    /// Leader → worker: the current job is over, but the daemon should
    /// stay connected for the next ASSIGN. Body: `want_state u8` (1 =
    /// reply with the suspend blob, 0 = reply with an empty STATE).
    Detach = 6,
    /// Worker → leader: the detach acknowledgement carrying the worker's
    /// suspend blob (empty when not requested).
    State = 7,
}

impl FrameKind {
    fn from_u8(b: u8) -> Result<FrameKind> {
        Ok(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Assign,
            3 => FrameKind::Downlink,
            4 => FrameKind::Uplink,
            5 => FrameKind::Shutdown,
            6 => FrameKind::Detach,
            7 => FrameKind::State,
            other => bail!("bad frame kind {other}"),
        })
    }
}

/// Encode an ASSIGN body:
/// `wid u32 | resume_len u32 | resume bytes | TrainConfig JSON`.
/// An empty `resume` means a fresh start; non-empty restores the worker
/// half's suspended state before the first round.
pub fn encode_assign(wid: u32, resume: &[u8], cfg_json: &str) -> Vec<u8> {
    let mut body = Vec::with_capacity(8 + resume.len() + cfg_json.len());
    body.extend(wid.to_le_bytes());
    body.extend((resume.len() as u32).to_le_bytes());
    body.extend_from_slice(resume);
    body.extend_from_slice(cfg_json.as_bytes());
    body
}

/// Write one frame (header + body) and flush it onto the wire.
pub fn write_frame(w: &mut impl Write, kind: FrameKind, body: &[u8]) -> Result<()> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    hdr[4] = kind as u8;
    hdr[5..9].copy_from_slice(&(body.len() as u32).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Start a frame in a caller-owned scratch buffer: append the 9-byte
/// header with a zero length placeholder. The caller then appends the
/// body straight into the same buffer (e.g. via [`encode_envelope_into`])
/// and calls [`finish_frame`]; the result is one contiguous frame ready
/// for a single `write_all`. Appends — clear the buffer first to start a
/// fresh frame (capacity is retained, the zero-copy scratch contract).
pub fn begin_frame(buf: &mut Vec<u8>, kind: FrameKind) {
    buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    buf.push(kind as u8);
    buf.extend_from_slice(&0u32.to_le_bytes());
}

/// Patch the length field of a frame started with [`begin_frame`], after
/// the body has been appended. Byte-identical to what [`write_frame`]
/// would have produced for the same kind and body.
pub fn finish_frame(buf: &mut Vec<u8>) -> Result<()> {
    ensure!(
        buf.len() >= FRAME_HEADER_BYTES,
        "finish_frame on a buffer without a frame header"
    );
    let len = buf.len() - FRAME_HEADER_BYTES;
    ensure!(
        len as u64 <= MAX_FRAME_BYTES as u64,
        "frame length {len} exceeds the 1 GiB cap"
    );
    buf[5..9].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(())
}

/// Read one frame; `Ok(None)` on a clean EOF at a frame boundary, `Err`
/// on a short read mid-frame, a bad magic/version word, an unknown kind,
/// or an absurd length.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(FrameKind, Vec<u8>)>> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    // First byte decides EOF-at-boundary vs short read.
    let mut got = 0usize;
    while got < hdr.len() {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("short read: {got} of {FRAME_HEADER_BYTES} header bytes"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    ensure!(
        magic == FRAME_MAGIC,
        "bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x} \"CAM1\" — \
         peer speaks another protocol or version)"
    );
    let kind = FrameKind::from_u8(hdr[4])?;
    let len = u32::from_le_bytes(hdr[5..9].try_into().unwrap());
    ensure!(len <= MAX_FRAME_BYTES, "frame length {len} exceeds the 1 GiB cap");
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .with_context(|| format!("short read in a {len}-byte {kind:?} body"))?;
    Ok(Some((kind, body)))
}

/// A bound-but-not-yet-connected leader endpoint. Two-phase so the
/// caller can learn the ephemeral port (and spawn workers at it) before
/// blocking in [`TcpLeader::accept_workers`].
pub struct TcpLeader {
    listener: TcpListener,
}

impl TcpLeader {
    /// Bind `127.0.0.1:port` (`port` 0 = ephemeral). Loopback only, on
    /// purpose: the frame protocol is unauthenticated, so cross-host
    /// clusters need an explicit (future) bind-address knob rather than
    /// a silent 0.0.0.0 default.
    pub fn bind(port: u16) -> Result<TcpLeader> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding tcp leader on 127.0.0.1:{port}"))?;
        Ok(TcpLeader { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and handshake `cfg.workers` worker connections, assigning
    /// `wid` 0.. in accept order, then start one reader thread per
    /// worker. Fails if the cluster has not formed within the handshake
    /// timeout. One-job ownership: the resulting [`Tcp`] sends SHUTDOWN
    /// and closes the sockets when the run ends. The listen socket stays
    /// with the transport, so a crashed worker's replacement can HELLO
    /// back into its wid mid-run ([`Transport::try_rejoin`]).
    pub fn accept_workers(self, cfg: &TrainConfig) -> Result<Tcp> {
        let streams = self.accept_hellos(cfg.workers)?;
        let mut tcp = assign_streams(&streams, cfg, None, false)?;
        tcp.adopt_listener(self)?;
        Ok(tcp)
    }

    /// Accept `n` connections and consume each one's HELLO, in accept
    /// order, without assigning them to any job. The scheduler uses this
    /// to form a resident fleet once, then re-ASSIGNs the same streams
    /// job after job ([`assign_streams`]).
    pub fn accept_hellos(&self, n: usize) -> Result<Vec<TcpStream>> {
        let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
        self.listener.set_nonblocking(true)?;
        let mut streams = Vec::with_capacity(n);
        for wid in 0..n {
            let mut stream = loop {
                match self.listener.accept() {
                    Ok((s, _peer)) => break s,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        ensure!(
                            Instant::now() < deadline,
                            "timed out waiting for worker {wid}/{n} to connect"
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accepting worker connection"),
                }
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            match read_frame(&mut stream)? {
                Some((FrameKind::Hello, _)) => {}
                Some((kind, _)) => bail!("worker {wid} opened with {kind:?}, not HELLO"),
                None => bail!("worker {wid} disconnected before HELLO"),
            }
            stream.set_read_timeout(None)?;
            streams.push(stream);
        }
        Ok(streams)
    }

    /// Accept at most one pending connection and consume its HELLO,
    /// without blocking when nobody is waiting. The scheduler's fleet
    /// healing uses this to re-admit worker daemons between jobs.
    pub fn try_accept_hello(&self) -> Result<Option<TcpStream>> {
        self.listener.set_nonblocking(true)?;
        try_accept_hello(&self.listener, REJOIN_HELLO_TIMEOUT)
    }
}

/// Accept at most one pending connection on a **nonblocking** listener
/// and consume its HELLO. `Ok(None)` when nobody is waiting — or when
/// the connection flunks the handshake (a non-HELLO opener is dropped,
/// not fatal: mid-run the listen socket can receive strays, and an
/// optional rejoin must never poison a healthy run).
fn try_accept_hello(
    listener: &TcpListener,
    hello_timeout: Duration,
) -> Result<Option<TcpStream>> {
    let mut stream = match listener.accept() {
        Ok((s, _peer)) => s,
        Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(None),
        Err(e) => return Err(e).context("accepting a rejoining worker"),
    };
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(hello_timeout))?;
    if !matches!(read_frame(&mut stream), Ok(Some((FrameKind::Hello, _)))) {
        return Ok(None);
    }
    stream.set_read_timeout(None)?;
    Ok(Some(stream))
}

/// ASSIGN a job to already-HELLO'd worker connections and build the
/// [`Tcp`] transport that runs it. `streams[i]` becomes worker `wid = i`
/// for this job. `resume` (one blob per worker) restores a suspended
/// job's worker state; `None` starts fresh. With `pooled = true` the
/// transport belongs to a resident fleet: ending the job DETACHes the
/// workers (daemons stay connected, sockets stay open) instead of
/// shutting them down — the caller keeps the original `TcpStream`s and
/// can re-assign them to the next job.
pub fn assign_streams(
    streams: &[TcpStream],
    cfg: &TrainConfig,
    resume: Option<&[Vec<u8>]>,
    pooled: bool,
) -> Result<Tcp> {
    ensure!(
        streams.len() == cfg.workers,
        "assigning {} workers onto {} connections",
        cfg.workers,
        streams.len()
    );
    if let Some(blobs) = resume {
        ensure!(
            blobs.len() == streams.len(),
            "resume carries {} worker blobs for {} workers",
            blobs.len(),
            streams.len()
        );
    }
    let cfg_json = cfg.to_json().to_string_pretty();
    let (event_tx, events) = channel::<ReaderEvent>();
    let mut links = Vec::with_capacity(streams.len());
    for (wid, stream) in streams.iter().enumerate() {
        let mut writer = stream.try_clone()?;
        let blob = resume.map_or(&[][..], |b| b[wid].as_slice());
        write_frame(
            &mut writer,
            FrameKind::Assign,
            &encode_assign(wid as u32, blob, &cfg_json),
        )
        .with_context(|| format!("assigning job to worker {wid}"))?;
        let reader = spawn_reader(wid, 0, stream.try_clone()?, event_tx.clone());
        links.push(WorkerLink {
            stream: writer,
            alive: true,
            gen: 0,
            reader: Some(reader),
        });
    }
    Ok(Tcp {
        links,
        events,
        event_tx,
        cfg_json,
        listener: None,
        shut_down: false,
        pooled,
        detached: false,
        downlink_frame: Vec::new(),
        downlink_key: None,
    })
}

/// What a reader thread emits: the wid and link generation it was
/// spawned for, plus the event itself. The generation lets
/// [`Tcp::recv_event`] drop ghost events from a replaced (rejoined)
/// link's old reader.
type ReaderEvent = (usize, u64, Result<Event>);

/// One leader-side reader thread: multiplex worker `wid`'s uplinks into
/// the shared event channel; a clean EOF becomes [`Event::Exit`], a
/// protocol violation becomes an `Err` event (runtime poisoning path).
/// The thread's return value is the detach handshake: a STATE frame ends
/// the thread with `Some(blob)` (collected by [`Tcp::detach`] via join),
/// every other exit path returns `None`.
fn spawn_reader(
    wid: usize,
    gen: u64,
    mut stream: TcpStream,
    tx: Sender<ReaderEvent>,
) -> JoinHandle<Option<Vec<u8>>> {
    // A reset/abort is a worker-death signal like a clean EOF (the OS
    // closes a crashed process's sockets either way); short reads and
    // malformed frames stay hard errors.
    fn is_disconnect(e: &anyhow::Error) -> bool {
        e.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::BrokenPipe
            )
        })
    }
    std::thread::Builder::new()
        .name(format!("tcp-reader-{wid}"))
        .spawn(move || loop {
            match read_frame(&mut stream) {
                // The frame body is handed to UplinkMsg whole: validated
                // once here, then served to the server step as a borrowed
                // PayloadView — no owned index/value vectors.
                Ok(Some((FrameKind::Uplink, body))) => match UplinkMsg::from_frame(body) {
                    Ok(msg) => {
                        let ev = Event::Uplink { wid, round: msg.round(), msg };
                        if tx.send((wid, gen, Ok(ev))).is_err() {
                            return None; // leader gone
                        }
                    }
                    Err(e) => {
                        let ctx = format!("decoding worker {wid} uplink");
                        let _ = tx.send((wid, gen, Err(e.context(ctx))));
                        return None;
                    }
                },
                // The worker acknowledged a DETACH: end of this job's
                // stream. No event — the joining detach call consumes the
                // blob directly.
                Ok(Some((FrameKind::State, body))) => return Some(body),
                Ok(Some((kind, _))) => {
                    let _ = tx.send((
                        wid,
                        gen,
                        Err(anyhow::anyhow!(
                            "worker {wid} sent a {kind:?} frame on the uplink stream"
                        )),
                    ));
                    return None;
                }
                // Worker process is gone (crash, post-SHUTDOWN close), or
                // the leader shut the socket down itself.
                Ok(None) => {
                    let _ = tx.send((wid, gen, Ok(Event::Exit { wid })));
                    return None;
                }
                Err(e) if is_disconnect(&e) => {
                    let _ = tx.send((wid, gen, Ok(Event::Exit { wid })));
                    return None;
                }
                Err(e) => {
                    let ctx = format!("reading worker {wid} uplink stream");
                    let _ = tx.send((wid, gen, Err(e.context(ctx))));
                    return None;
                }
            }
        })
        .expect("spawn tcp reader thread")
}

struct WorkerLink {
    stream: TcpStream,
    alive: bool,
    /// Incarnation counter, bumped on every rejoin. Events stamped with
    /// an older generation belong to a dead predecessor on this wid and
    /// are dropped by [`Tcp::recv_event`].
    gen: u64,
    /// This incarnation's reader thread; taken at detach/shutdown (and
    /// when retiring a dead incarnation on rejoin) to join it.
    reader: Option<JoinHandle<Option<Vec<u8>>>>,
}

/// Multi-process transport: one socket per worker process, one reader
/// thread per socket, all uplinks multiplexed into a single event
/// channel (true arrival order — the property partial participation
/// exploits, now with real network scheduling).
pub struct Tcp {
    links: Vec<WorkerLink>,
    events: Receiver<ReaderEvent>,
    /// Kept so rejoin can arm replacement readers onto the same channel.
    event_tx: Sender<ReaderEvent>,
    /// The job's ASSIGN config, kept verbatim so a rejoiner's ASSIGN is
    /// byte-identical to the original cluster's.
    cfg_json: String,
    /// The leader's listen socket (nonblocking), when mid-run rejoin is
    /// armed ([`Tcp::adopt_listener`]). `None` on pooled fleets — there
    /// the scheduler owns the listener and heals between jobs instead.
    listener: Option<TcpListener>,
    shut_down: bool,
    /// Fleet mode ([`assign_streams`]): end-of-job releases the workers
    /// with DETACH instead of SHUTDOWN and leaves the sockets open for
    /// the next ASSIGN.
    pooled: bool,
    /// Set once the workers have been DETACHed (the transport is spent).
    detached: bool,
    /// Pooled downlink scratch: the **full** socket frame (9-byte frame
    /// header + 16-byte envelope header + θ body) for the current
    /// `(round, lr)`, encoded once per round straight off the live θ
    /// slice — no owned `Payload`, no intermediate body `Vec` — and
    /// reused across the dispatch fan-out. Per worker only the 4-byte
    /// wid field is re-patched and the send is a single `write_all`.
    /// Capacity is retained across rounds, so steady-state downlinks
    /// allocate nothing.
    downlink_frame: Vec<u8>,
    downlink_key: Option<(u64, u32)>,
}

impl Tcp {
    /// Release every worker from the current job: send DETACH
    /// (`want_state` selects blob vs empty acknowledgement), then join
    /// the reader threads, each of which ends on the worker's STATE
    /// reply. Returns one entry per worker — `Some(blob)` from a worker
    /// that acknowledged, `None` for one that died first. After a detach
    /// the transport is spent; on a pooled fleet the underlying sockets
    /// stay open for the next [`assign_streams`].
    fn detach_inner(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        ensure!(!self.detached, "tcp transport already detached");
        self.detached = true;
        let body = [want_state as u8];
        for link in &mut self.links {
            if link.alive {
                // A failed write means the worker died under us; its
                // reader exits on EOF and joins as None below.
                if write_frame(&mut link.stream, FrameKind::Detach, &body).is_err() {
                    link.alive = false;
                }
            }
        }
        let mut out = Vec::with_capacity(self.links.len());
        for (wid, link) in self.links.iter_mut().enumerate() {
            let blob = match link.reader.take() {
                Some(reader) => reader
                    .join()
                    .map_err(|_| anyhow::anyhow!("tcp reader {wid} panicked"))?,
                None => None,
            };
            if blob.is_none() {
                link.alive = false;
            }
            out.push(blob);
        }
        Ok(out)
    }

    /// Arm mid-run rejoin: keep the leader's listen socket so a
    /// replacement worker process can HELLO back into a dead wid
    /// ([`Transport::try_rejoin`]).
    pub fn adopt_listener(&mut self, leader: TcpLeader) -> Result<()> {
        leader.listener.set_nonblocking(true)?;
        self.listener = Some(leader.listener);
        Ok(())
    }
}

impl Transport for Tcp {
    fn n_workers(&self) -> usize {
        self.links.len()
    }

    fn send_downlink(
        &mut self,
        wid: usize,
        theta: &Arc<Vec<f32>>,
        ctx: &RoundCtx,
    ) -> Result<bool> {
        ensure!(wid < self.links.len(), "no worker {wid} behind tcp transport");
        if !self.links[wid].alive {
            return Ok(false);
        }
        let lr_bits = ctx.lr.to_bits();
        if self.downlink_key != Some((ctx.round, lr_bits)) {
            self.downlink_frame.clear();
            begin_frame(&mut self.downlink_frame, FrameKind::Downlink);
            encode_envelope_into(
                wid as u32,
                ctx.round,
                ctx.lr,
                &PayloadView::Dense(Scalars::Slice(theta.as_slice())),
                &mut self.downlink_frame,
            );
            finish_frame(&mut self.downlink_frame)?;
            self.downlink_key = Some((ctx.round, lr_bits));
        } else {
            // Per-worker patch: wid is the first envelope field, right
            // after the socket frame header.
            self.downlink_frame[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + 4]
                .copy_from_slice(&(wid as u32).to_le_bytes());
        }
        let link = &mut self.links[wid];
        let sent = link
            .stream
            .write_all(&self.downlink_frame)
            .and_then(|()| link.stream.flush());
        match sent {
            Ok(()) => Ok(true),
            // A write failure means the worker process died under us; its
            // Event::Exit is already in (or on its way into) the channel.
            // Report "not dispatched" instead of killing the run.
            Err(_) => {
                link.alive = false;
                Ok(false)
            }
        }
    }

    fn recv_event(&mut self) -> Result<Event> {
        loop {
            let (wid, gen, ev) = self
                .events
                .recv()
                .map_err(|_| anyhow::anyhow!("all tcp reader threads are gone"))?;
            // A stale generation is a ghost of a dead incarnation whose
            // wid has since been rejoined (its Exit, a straggling uplink,
            // or its reader's error): drop it rather than charge it to
            // the replacement.
            if self.links.get(wid).is_none_or(|l| l.gen != gen) {
                continue;
            }
            let ev = ev?;
            if let Event::Exit { wid } = ev {
                self.links[wid].alive = false;
            }
            return Ok(ev);
        }
    }

    fn frame_overhead_bits(&self) -> u64 {
        ((FRAME_HEADER_BYTES + ENVELOPE_HEADER_BYTES) as u64) * 8
    }

    fn shutdown(&mut self) -> Result<()> {
        if self.shut_down {
            return Ok(());
        }
        self.shut_down = true;
        if self.pooled {
            // The fleet outlives this job: release the workers back to
            // idle instead of terminating them, and leave the sockets
            // open for the next ASSIGN. The scheduler sends the real
            // SHUTDOWN when it drains the whole fleet.
            if !self.detached {
                let _ = self.detach_inner(false);
            }
            return Ok(());
        }
        for link in &mut self.links {
            if link.alive {
                // Best effort: the worker may have died since we checked.
                let _ = write_frame(&mut link.stream, FrameKind::Shutdown, &[]);
            }
            // Closing both directions unblocks this worker's reader
            // thread even if the worker never closes its end.
            let _ = link.stream.shutdown(Shutdown::Both);
            link.alive = false;
        }
        for link in &mut self.links {
            if let Some(j) = link.reader.take() {
                let _ = j.join();
            }
        }
        Ok(())
    }

    fn detach(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        self.detach_inner(want_state)
    }

    fn try_rejoin(&mut self) -> Result<Vec<usize>> {
        let Some(listener) = self.listener.as_ref() else {
            return Ok(Vec::new());
        };
        let mut revived = Vec::new();
        for wid in 0..self.links.len() {
            if self.links[wid].alive {
                continue;
            }
            let Some(stream) = try_accept_hello(listener, REJOIN_HELLO_TIMEOUT)?
            else {
                break; // nobody is knocking; retry on a later dispatch
            };
            let mut writer = stream.try_clone()?;
            // Fresh ASSIGN, empty resume: the dead incarnation's EF
            // accumulator is gone (the runtime has already charged the
            // loss when it marked the wid dead).
            if write_frame(
                &mut writer,
                FrameKind::Assign,
                &encode_assign(wid as u32, &[], &self.cfg_json),
            )
            .is_err()
            {
                continue; // rejoiner vanished mid-handshake
            }
            let link = &mut self.links[wid];
            // Retire the dead incarnation: force its reader (possibly
            // still blocked on a half-dead socket) off with a hard
            // close, then join it so the thread is gone before the
            // replacement takes the slot.
            let _ = link.stream.shutdown(Shutdown::Both);
            if let Some(old) = link.reader.take() {
                let _ = old.join();
            }
            let gen = link.gen + 1;
            let reader = spawn_reader(wid, gen, stream, self.event_tx.clone());
            *link = WorkerLink {
                stream: writer,
                alive: true,
                gen,
                reader: Some(reader),
            };
            revived.push(wid);
        }
        Ok(revived)
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips_and_reports_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Uplink, b"hello-bytes").unwrap();
        write_frame(&mut buf, FrameKind::Shutdown, &[]).unwrap();
        let mut r = &buf[..];
        let (k, body) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(k, FrameKind::Uplink);
        assert_eq!(body, b"hello-bytes");
        let (k, body) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(k, FrameKind::Shutdown);
        assert!(body.is_empty());
        // Clean EOF at a frame boundary is None, not an error.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn frame_rejects_bad_magic_kind_and_short_reads() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Downlink, &[1, 2, 3]).unwrap();
        // Corrupt the magic.
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        let err = read_frame(&mut &bad[..]).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // Unknown kind byte.
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(read_frame(&mut &bad[..]).is_err());
        // Short header and short body are errors, not EOF.
        assert!(read_frame(&mut &buf[..4]).is_err());
        assert!(read_frame(&mut &buf[..buf.len() - 1]).is_err());
        // Absurd length is rejected before allocation.
        let mut bad = buf;
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &bad[..]).is_err());
    }

    #[test]
    fn begin_finish_frame_matches_write_frame() {
        let mut whole = Vec::new();
        write_frame(&mut whole, FrameKind::Downlink, b"theta-bytes").unwrap();
        let mut scratch = Vec::new();
        for _ in 0..2 {
            // Twice: the second pass reuses the cleared buffer, proving
            // the scratch contract reproduces identical bytes.
            scratch.clear();
            begin_frame(&mut scratch, FrameKind::Downlink);
            scratch.extend_from_slice(b"theta-bytes");
            finish_frame(&mut scratch).unwrap();
            assert_eq!(scratch, whole);
        }
        // A header-less buffer is rejected.
        let mut empty = Vec::new();
        assert!(finish_frame(&mut empty).is_err());
    }

    #[test]
    fn leader_binds_ephemeral_port() {
        let leader = TcpLeader::bind(0).unwrap();
        let addr = leader.local_addr().unwrap();
        assert!(addr.port() != 0);
        assert!(addr.ip().is_loopback());
    }
}
