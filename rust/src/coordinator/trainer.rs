//! The training driver: config/workload assembly plus a thin loop over
//! the event-driven [`ClusterRuntime`].
//!
//! The protocol is split per Algorithm 2: each worker's
//! [`WorkerAlgo`](crate::algo::WorkerAlgo) half (compressor + EF + local
//! optimizer state) lives inside the [`WorkerPool`](super::cluster::WorkerPool)
//! next to its gradient source, behind a [`Transport`]; the
//! [`ServerAlgo`](crate::algo::ServerAlgo) half (aggregation + server
//! optimizer) is applied by the runtime's round state machine — either as
//! one full-θ server or, with `server_shards > 1`, as a
//! [`ShardedServer`](crate::algo::sharded::ShardedServer) that splits θ
//! across parallel per-shard optimizers (bitwise-identical trajectories).
//!
//! `Trainer` itself only assembles the pieces (datasets, gradient
//! sources, protocol halves, transport, runtime) and drives one
//! [`ClusterRuntime::run_round`] per scheduled round, folding each
//! [`RoundOutcome`](super::runtime::RoundOutcome) into the metrics
//! stream.

use std::path::Path;
use std::rc::Rc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::algo::{AlgoSpec, ServerAlgo, ShardedServer, WorkerAlgo};
use crate::config::TrainConfig;
use crate::data::{
    images::SyntheticImages, lm::ByteCorpus, shard::Sharding, text::SyntheticText,
    vectors::GaussianVectors, Dataset,
};
use crate::grad::{
    logistic::{LogisticEvaluator, LogisticProblem},
    pjrt_model::{PjrtEvaluator, PjrtSource, ShardStream},
    quadratic::{QuadraticEvaluator, QuadraticProblem},
    EvalStats, Evaluator, GradSource,
};
use crate::runtime::{ModelBundle, OptimizerExe, Runtime};
use crate::util::timer::Stopwatch;

use super::cluster::WorkerPool;
use super::comm::CommLedger;
use super::metrics::{RoundMetric, RunResult};
use super::net::TcpLeader;
use super::runtime::ClusterRuntime;
use super::supervisor::Supervisor;
use super::transport::{Transport, TransportSpec};

pub struct Trainer {
    cfg: TrainConfig,
    runtime: ClusterRuntime,
    server: Box<dyn ServerAlgo>,
    algo_name: String,
    evaluator: Box<dyn Evaluator>,
    pub theta: Vec<f32>,
    ledger: CommLedger,
    metrics: Vec<RoundMetric>,
    worker_ms_total: f64,
    round_ms_total: f64,
    /// Child worker processes when `--spawn-workers` assembled the
    /// cluster; reaped at end of run (and killed on any error unwind).
    supervisor: Option<Supervisor>,
}

impl Trainer {
    pub fn new(cfg: &TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let spec = AlgoSpec::parse(&cfg.algo)?;
        let tspec = TransportSpec::parse(&cfg.transport)?;
        // Remote (tcp) workers rebuild their own gradient sources and
        // protocol halves from the ASSIGN config (build_worker_parts),
        // so don't construct n unused local pipelines for them. Server
        // construction is independent of the worker count.
        let local_workers = if tspec.is_multiprocess() { 0 } else { cfg.workers };
        let (sources, evaluator, theta, fused) = build_workload(cfg, local_workers)?;
        let fused = if cfg.fused_update { fused } else { None };
        let (workers, mut server) =
            spec.build_fused(theta.len(), local_workers, cfg.rounds, fused);
        if cfg.server_shards > 1 {
            // Replace the full-θ server with S per-shard servers (the
            // validate() above already rejected the fused combination).
            server = Box::new(ShardedServer::new(
                &spec,
                theta.len(),
                cfg.rounds,
                cfg.server_shards,
                cfg.server_threaded,
            )?);
        }
        let (transport, supervisor): (Box<dyn Transport>, Option<Supervisor>) = match tspec {
            TransportSpec::Tcp { port } => {
                // Workers are remote processes (local_workers == 0: the
                // pool pieces above are empty).
                drop(workers);
                drop(sources);
                let leader = TcpLeader::bind(port)?;
                let addr = leader.local_addr()?;
                let sup = if cfg.spawn_workers {
                    Some(Supervisor::spawn(cfg.workers, &addr.to_string())?)
                } else {
                    eprintln!(
                        "waiting for {} worker(s): comp-ams worker --leader {addr}",
                        cfg.workers
                    );
                    None
                };
                (Box::new(leader.accept_workers(cfg)?), sup)
            }
            in_proc => {
                let pool = match sources {
                    Sources::Threadable(s) if cfg.threaded => {
                        WorkerPool::threaded(s, workers)?
                    }
                    Sources::Threadable(s) => WorkerPool::sequential(
                        s.into_iter().map(|b| b as Box<dyn GradSource>).collect(),
                        workers,
                    )?,
                    Sources::LeaderOnly(s) => WorkerPool::sequential(s, workers)?,
                };
                (in_proc.build(pool)?, None)
            }
        };
        let runtime = ClusterRuntime::new(transport, cfg.quorum, cfg.max_staleness)?;
        let algo_name = server.name();
        Ok(Trainer {
            cfg: cfg.clone(),
            runtime,
            server,
            algo_name,
            evaluator,
            theta,
            ledger: CommLedger::new(),
            metrics: Vec::new(),
            worker_ms_total: 0.0,
            round_ms_total: 0.0,
            supervisor,
        })
    }

    pub fn algo_name(&self) -> String {
        self.algo_name.clone()
    }

    /// Drive one runtime round; returns the mean train loss over the
    /// uplinks that arrived.
    pub fn step(&mut self, round: u64) -> Result<f32> {
        let sw = Stopwatch::start();
        let lr = self.cfg.schedule.lr_at(self.cfg.lr, round);

        // The runtime runs the whole round state machine: downlink
        // dispatch, quorum collection, staleness classification, and the
        // server step (per-shard when sharded).
        let out = self.runtime.run_round(
            &mut self.theta,
            self.server.as_mut(),
            round,
            lr,
            &mut self.ledger,
        )?;
        self.worker_ms_total += out.worker_ms;
        if let Some(stats) = self.server.shard_stats() {
            self.ledger.sync_shard_routing(&stats.routed_bits);
        }

        let wall = sw.ms();
        self.round_ms_total += wall;
        let train_loss = out.train_loss;
        let eval = if self.cfg.eval_every > 0 && (round + 1) % self.cfg.eval_every == 0 {
            Some(self.evaluator.eval(&self.theta)?)
        } else {
            None
        };
        self.metrics.push(RoundMetric {
            round,
            epoch: (round + 1) as f32 / self.cfg.rounds_per_epoch.max(1) as f32,
            train_loss,
            eval,
            uplink_bits: self.ledger.uplink_bits,
            downlink_bits: self.ledger.downlink_bits,
            lr,
            wall_ms: wall,
        });
        if self.cfg.log_every > 0 && (round + 1) % self.cfg.log_every == 0 {
            let e = self.metrics.last().unwrap();
            let acc = e
                .eval
                .map(|s| format!(" test_acc={:.4} test_loss={:.4}", s.accuracy, s.loss))
                .unwrap_or_default();
            let lag = if out.stale > 0 || out.dropped > 0 {
                format!(" stale {} dropped {}", out.stale, out.dropped)
            } else {
                String::new()
            };
            eprintln!(
                "[{}] round {:>6} epoch {:>6.2} loss {:.4}{} lr {:.2e} uplink {:.2} MB{}",
                self.algo_name,
                round + 1,
                e.epoch,
                train_loss,
                acc,
                lr,
                e.uplink_bits as f64 / 8e6,
                lag,
            );
        }
        Ok(train_loss)
    }

    /// End-of-run teardown: bill the straggler uplinks still in flight
    /// (K < n only — transmitted messages the ledger must not lose;
    /// these post-date the last round metric, so they appear in the
    /// ledger-derived `RunResult` fields but not in metrics'
    /// `uplink_bits`), broadcast SHUTDOWN to remote workers, and reap
    /// any supervisor-spawned child processes. [`Trainer::run`] calls
    /// this after its last round; drive it yourself when stepping rounds
    /// manually over a tcp cluster, or the children only go away on
    /// drop.
    pub fn finish(&mut self) -> Result<()> {
        self.runtime.drain_in_flight(&mut self.ledger)?;
        self.runtime.shutdown()?;
        if let Some(sup) = self.supervisor.as_mut() {
            let nonzero = sup.reap(Duration::from_secs(10))?;
            let dead = self.runtime.dead_workers();
            if nonzero > dead.len() {
                eprintln!(
                    "warning: {nonzero} worker process(es) exited non-zero \
                     ({} accounted as dead mid-run)",
                    dead.len()
                );
            }
        }
        Ok(())
    }

    pub fn run(mut self) -> Result<RunResult> {
        let total = Stopwatch::start();
        for round in 0..self.cfg.rounds {
            self.step(round)?;
        }
        self.finish()?;
        let final_eval = self.evaluator.eval(&self.theta)?;
        let server_ms_by_shard = self
            .server
            .shard_stats()
            .map(|st| st.step_ms.clone())
            .unwrap_or_default();
        Ok(RunResult {
            algo: self.algo_name.clone(),
            model: self.cfg.model.clone(),
            workers: self.cfg.workers,
            metrics: self.metrics,
            final_eval,
            total_wall_ms: total.ms(),
            coord_overhead: if self.round_ms_total > 0.0 {
                // Clamped: timer jitter (worker stopwatch vs round
                // stopwatch) must not report a negative leader share.
                (1.0 - self.worker_ms_total / self.round_ms_total).clamp(0.0, 1.0)
            } else {
                0.0
            },
            stale_uplinks: self.ledger.stale_uplinks,
            dropped_uplinks: self.ledger.dropped_uplinks,
            framing_bits: self.ledger.framing_bits,
            uplink_bits_by_worker: self.ledger.uplink_bits_by_worker.clone(),
            uplink_bits_by_shard: self.ledger.uplink_bits_by_shard.clone(),
            server_ms_by_shard,
        })
    }

    pub fn eval_now(&mut self) -> Result<EvalStats> {
        self.evaluator.eval(&self.theta)
    }

    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }
}

/// One-call convenience: build + run.
pub fn train(cfg: &TrainConfig) -> Result<RunResult> {
    Trainer::new(cfg)?.run()
}

// ---------------------------------------------------------------------------

/// Gradient sources for the pool. The analytic substrates produce `Send`
/// sources that can move into worker threads; the PJRT path is pinned to
/// the leader thread (`Rc` handles inside the executables).
enum Sources {
    Threadable(Vec<Box<dyn GradSource + Send>>),
    LeaderOnly(Vec<Box<dyn GradSource>>),
}

type Workload = (
    Sources,
    Box<dyn Evaluator>,
    Vec<f32>,
    Option<Rc<OptimizerExe>>,
);

/// The quadratic substrate for this config — one construction shared by
/// the leader's workload assembly and the remote worker daemon, so both
/// sides build bitwise-identical shards.
fn quadratic_problem(cfg: &TrainConfig) -> Result<QuadraticProblem> {
    // Dirichlet sharding has no labels here; non-iid is expressed
    // through σ_g > 0 instead.
    let sigma_g = match Sharding::parse(&cfg.sharding)? {
        Sharding::Iid => 0.0,
        Sharding::Dirichlet { alpha } => (1.0 / alpha).min(10.0),
    };
    Ok(QuadraticProblem::new(cfg.seed, 256, cfg.workers, 20.0, 1.0, sigma_g))
}

/// The logistic substrate for this config (see [`quadratic_problem`]).
fn logistic_problem(cfg: &TrainConfig) -> LogisticProblem {
    LogisticProblem::new(cfg.seed, 64, 10, 32, 0.5)
}

/// Build worker `wid`'s gradient source and protocol worker half from a
/// config — the remote half of the TCP handshake: a `comp-ams worker`
/// daemon calls this with the `(wid, TrainConfig)` the leader ASSIGNed,
/// and gets exactly the objects the leader's in-process pool would have
/// built for that worker (same constructors, same seeds, same per-worker
/// compressor salting), which is what makes a K = n TCP run bitwise
/// identical to `InProc`.
///
/// Only the analytic substrates are supported: PJRT sources need the
/// artifact bundle and are leader-pinned.
pub fn build_worker_parts(
    cfg: &TrainConfig,
    wid: usize,
) -> Result<(Box<dyn GradSource>, Box<dyn WorkerAlgo>)> {
    anyhow::ensure!(
        wid < cfg.workers,
        "wid {wid} out of range for {} workers",
        cfg.workers
    );
    let src: Box<dyn GradSource> = match cfg.model.as_str() {
        "quadratic" => Box::new(quadratic_problem(cfg)?.source_for(wid, cfg.seed)),
        "logistic" => Box::new(logistic_problem(cfg).source_for(wid, cfg.seed)),
        other => bail!(
            "multi-process workers support the analytic substrates \
             (quadratic | logistic), not '{other}'"
        ),
    };
    // Build the full worker-half set and keep ours: stochastic
    // compressors are salted by worker index, so construction must go
    // through the same path as the leader's.
    let spec = AlgoSpec::parse(&cfg.algo)?;
    let mut workers = spec.build(src.dim(), cfg.workers, cfg.rounds).0;
    Ok((src, workers.swap_remove(wid)))
}

/// `n_sources` is how many *leader-side* gradient sources to build:
/// `cfg.workers` for the in-process transports, 0 for tcp (remote worker
/// processes own their sources). θ and the evaluator never depend on it.
fn build_workload(cfg: &TrainConfig, n_sources: usize) -> Result<Workload> {
    match cfg.model.as_str() {
        "quadratic" => {
            let p = quadratic_problem(cfg)?;
            let sources: Vec<Box<dyn GradSource + Send>> = (0..n_sources)
                .map(|w| Box::new(p.source_for(w, cfg.seed)) as _)
                .collect();
            let theta = vec![0.0f32; p.dim()];
            let eval = Box::new(QuadraticEvaluator { problem: p });
            Ok((Sources::Threadable(sources), eval, theta, None))
        }
        "logistic" => {
            let p = logistic_problem(cfg);
            let sources: Vec<Box<dyn GradSource + Send>> = (0..n_sources)
                .map(|w| Box::new(p.source_for(w, cfg.seed)) as _)
                .collect();
            let theta = vec![0.0f32; p.p()];
            let eval =
                Box::new(LogisticEvaluator { problem: p, seed: cfg.seed ^ 0xE0, n: 2000 });
            Ok((Sources::Threadable(sources), eval, theta, None))
        }
        // PJRT models are never multi-process (validate() rejects tcp for
        // them), so n_sources == cfg.workers here.
        name => build_pjrt_workload(cfg, name),
    }
}

fn build_pjrt_workload(cfg: &TrainConfig, name: &str) -> Result<Workload> {
    let rt = Rc::new(Runtime::cpu()?);
    let bundle = Rc::new(
        ModelBundle::load(&rt, Path::new(&cfg.artifacts), name).with_context(|| {
            format!(
                "loading model '{name}' from {} (run `make artifacts`?)",
                cfg.artifacts.display()
            )
        })?,
    );
    let entry = &bundle.entry;
    let seq_len = entry.x_shape.first().copied().unwrap_or(0);

    // Dataset per workload (DESIGN.md §4 substitutions).
    let mk_classif = |ds: Rc<dyn Dataset>| -> Result<Vec<ShardStream>> {
        let mut rng = crate::util::rng::Rng::seed(cfg.seed ^ 0x5A4D);
        let weights = Sharding::parse(&cfg.sharding)?.worker_weights(
            &mut rng,
            cfg.workers,
            ds.classes(),
        );
        Ok(weights
            .into_iter()
            .map(|w| ShardStream::Classif { ds: Rc::clone(&ds), weights: w })
            .collect())
    };

    let streams: Vec<ShardStream> = match name {
        "mnist_cnn" => mk_classif(Rc::new(SyntheticImages::mnist_like(cfg.seed)))?,
        "cifar_lenet" | "cifar_resnet" => {
            mk_classif(Rc::new(SyntheticImages::cifar_like(cfg.seed)))?
        }
        "imdb_lstm" => mk_classif(Rc::new(SyntheticText::imdb_like(cfg.seed, seq_len)))?,
        "logreg" => mk_classif(Rc::new(GaussianVectors::new(cfg.seed, 64, 4, 0.5)))?,
        "lm_small" | "lm_large" => {
            let corpus = Rc::new(ByteCorpus::generate(cfg.seed, 262_144, seq_len));
            (0..cfg.workers)
                .map(|_| ShardStream::Lm { corpus: Rc::clone(&corpus) })
                .collect()
        }
        other => bail!("no data substrate wired for model '{other}'"),
    };

    // The evaluator draws its own iid test stream (never label-skewed).
    let eval_stream = match &streams[0] {
        ShardStream::Classif { ds, .. } => {
            ShardStream::Classif { ds: Rc::clone(ds), weights: None }
        }
        ShardStream::Lm { corpus } => ShardStream::Lm { corpus: Rc::clone(corpus) },
    };
    let evaluator = Box::new(PjrtEvaluator::new(
        Rc::clone(&bundle),
        &eval_stream,
        cfg.seed,
        cfg.eval_batches,
    ));

    let sources: Vec<Box<dyn GradSource>> = streams
        .into_iter()
        .enumerate()
        .map(|(w, stream)| {
            Box::new(PjrtSource::new(Rc::clone(&bundle), stream, cfg.seed, w)) as _
        })
        .collect();
    let theta = bundle.init_theta.clone();
    let fused = Some(Rc::clone(&bundle.amsgrad));
    Ok((Sources::LeaderOnly(sources), evaluator, theta, fused))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    #[test]
    fn quadratic_comp_ams_descends() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.05");
        cfg.workers = 4;
        cfg.rounds = 300;
        cfg.lr = 0.05;
        cfg.eval_every = 0;
        let run = train(&cfg).unwrap();
        let first = run.metrics[0].train_loss;
        let last = run.final_train_loss(20);
        assert!(last < first - 0.5, "no descent: {first} -> {last}");
    }

    #[test]
    fn threaded_matches_sequential_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-blocksign:64");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.threaded = true;
        let b = train(&cfg).unwrap();
        for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(ma.train_loss, mb.train_loss, "round {}", ma.round);
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        assert_eq!(a.uplink_bits_by_worker, b.uplink_bits_by_worker);
    }

    #[test]
    fn loopback_transport_matches_inproc_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.transport = "loopback".into();
        let b = train(&cfg).unwrap();
        for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        // Every uplink crossed the byte framing; no staleness under the
        // full-quorum default.
        assert_eq!(b.stale_uplinks, 0);
        assert_eq!(b.dropped_uplinks, 0);
    }

    #[test]
    fn sharded_server_matches_unsharded_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.server_shards = 4;
        let b = train(&cfg).unwrap();
        cfg.server_threaded = true;
        let c = train(&cfg).unwrap();
        for ((ma, mb), mc) in a.metrics.iter().zip(&b.metrics).zip(&c.metrics) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.train_loss.to_bits(), mc.train_loss.to_bits());
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        // Per-shard accounting surfaces only for sharded runs, and the
        // deterministic routing bills identical bits on both backends.
        assert!(a.uplink_bits_by_shard.is_empty());
        assert!(a.server_ms_by_shard.is_empty());
        assert_eq!(b.uplink_bits_by_shard.len(), 4);
        assert_eq!(b.server_ms_by_shard.len(), 4);
        assert!(b.uplink_bits_by_shard.iter().all(|&bits| bits > 0));
        assert_eq!(b.uplink_bits_by_shard, c.uplink_bits_by_shard);
    }

    #[test]
    fn uplink_accounting_topk_vs_dense() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 2;
        cfg.rounds = 10;
        cfg.eval_every = 0;
        let dense = train(&cfg).unwrap();
        cfg.algo = "comp-ams-topk:0.01".into();
        let sparse = train(&cfg).unwrap();
        assert!(sparse.uplink_bits() < dense.uplink_bits() / 10);
    }

    #[test]
    fn coord_overhead_is_clamped_to_unit_interval() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-sgd");
        cfg.workers = 2;
        cfg.rounds = 5;
        cfg.eval_every = 0;
        let run = train(&cfg).unwrap();
        assert!(
            (0.0..=1.0).contains(&run.coord_overhead),
            "{}",
            run.coord_overhead
        );
    }

    #[test]
    fn logistic_learns_with_all_protocols() {
        for algo in ["dist-ams", "comp-ams-topk:0.05", "comp-ams-blocksign:64", "qadam",
                     "1bitadam:20", "dist-sgd"] {
            let mut cfg = TrainConfig::preset("logistic", algo);
            cfg.workers = 4;
            cfg.rounds = 250;
            cfg.lr = if algo == "dist-sgd" { 0.1 } else { 0.05 };
            cfg.eval_every = 0;
            let run = train(&cfg).unwrap();
            assert!(
                run.final_eval.accuracy > 0.5,
                "{algo}: acc={}",
                run.final_eval.accuracy
            );
        }
    }
}
