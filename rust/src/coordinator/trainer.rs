//! The training driver: config/workload assembly plus a thin loop over
//! the event-driven [`ClusterRuntime`].
//!
//! The protocol is split per Algorithm 2: each worker's
//! [`WorkerAlgo`](crate::algo::WorkerAlgo) half (compressor + EF + local
//! optimizer state) lives inside the [`WorkerPool`](super::cluster::WorkerPool)
//! next to its gradient source, behind a [`Transport`]; the
//! [`ServerAlgo`](crate::algo::ServerAlgo) half (aggregation + server
//! optimizer) is applied by the runtime's round state machine — either as
//! one full-θ server or, with `server_shards > 1`, as a
//! [`ShardedServer`](crate::algo::sharded::ShardedServer) that splits θ
//! across parallel per-shard optimizers (bitwise-identical trajectories).
//!
//! `Trainer` itself only assembles the pieces (datasets, gradient
//! sources, protocol halves, transport, runtime) and drives one
//! [`ClusterRuntime::run_round`] per scheduled round, folding each
//! [`RoundOutcome`](super::runtime::RoundOutcome) into the metrics
//! stream.

use std::path::Path;
use std::rc::Rc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::algo::{
    parse_byzantine, AggMode, AlgoSpec, ByzantineWorker, GroupForwardServer, ServerAlgo,
    ShardedServer, WorkerAlgo,
};
use crate::compress::CompressorSpec;
use crate::config::TrainConfig;
use crate::data::{
    images::SyntheticImages, lm::ByteCorpus, shard::Sharding, text::SyntheticText,
    vectors::GaussianVectors, Dataset,
};
use crate::grad::{
    logistic::{LogisticEvaluator, LogisticProblem},
    pjrt_model::{PjrtEvaluator, PjrtSource, ShardStream},
    quadratic::{QuadraticEvaluator, QuadraticProblem},
    EvalStats, Evaluator, GradSource,
};
use crate::runtime::{ModelBundle, OptimizerExe, Runtime};
use crate::util::timer::Stopwatch;

use super::checkpoint::JobCheckpoint;
use super::cluster::{import_worker_blob, WorkerPool};
use super::comm::CommLedger;
use super::metrics::{RoundMetric, RunResult};
use super::net::{assign_streams, TcpLeader};
use super::runtime::ClusterRuntime;
use super::sim::{Sim, SimProfile};
use super::supervisor::{RestartPolicy, Supervisor};
use super::transport::{Transport, TransportSpec};
use super::tree::{parse_tree_kill, Topology, TreeHandle, TreeTransport};

pub struct Trainer {
    cfg: TrainConfig,
    runtime: ClusterRuntime,
    server: Box<dyn ServerAlgo>,
    algo_name: String,
    evaluator: Box<dyn Evaluator>,
    pub theta: Vec<f32>,
    ledger: CommLedger,
    metrics: Vec<RoundMetric>,
    worker_ms_total: f64,
    round_ms_total: f64,
    /// The next round [`Trainer::run`] (or a manual [`Trainer::step`]
    /// loop) will execute; restored from the checkpoint on resume.
    next_round: u64,
    /// Child worker processes when `--spawn-workers` assembled the
    /// cluster; reaped at end of run (and killed on any error unwind).
    supervisor: Option<Supervisor>,
    /// Shared handle onto the tree transport's sub-leader state when
    /// `--topology tree:<degree>` assembled a two-level cluster: the
    /// per-round level-1 ledger absorption and group introspection.
    tree: Option<TreeHandle>,
}

impl Trainer {
    pub fn new(cfg: &TrainConfig) -> Result<Trainer> {
        Self::build(cfg, None)
    }

    /// Rebuild a trainer from a [`JobCheckpoint`] and continue bitwise
    /// where [`Trainer::suspend`] left off. The checkpoint carries its
    /// own config; worker state re-enters through the same constructors
    /// the original run used — imported into the rebuilt in-process
    /// pool, or shipped to remote daemons in the ASSIGN frame's resume
    /// blob.
    pub fn resume(ckpt: &JobCheckpoint) -> Result<Trainer> {
        Self::build(&ckpt.cfg, Some(ckpt))
    }

    fn build(cfg: &TrainConfig, ckpt: Option<&JobCheckpoint>) -> Result<Trainer> {
        cfg.validate()?;
        if let Some(ck) = ckpt {
            ensure!(
                ck.workers.len() == cfg.workers,
                "checkpoint holds {} worker state blob(s) for a {}-worker config",
                ck.workers.len(),
                cfg.workers
            );
            ensure!(
                ck.round <= cfg.rounds,
                "checkpoint round {} past the configured {} rounds",
                ck.round,
                cfg.rounds
            );
        }
        let spec = AlgoSpec::parse(&cfg.algo)?;
        let tspec = TransportSpec::parse(&cfg.transport)?;
        let topo = Topology::parse(&cfg.topology)?;
        // Remote (tcp) workers rebuild their own gradient sources and
        // protocol halves from the ASSIGN config (build_worker_parts),
        // so don't construct n unused local pipelines for them. Server
        // construction is independent of the worker count.
        let local_workers = if tspec.is_multiprocess() { 0 } else { cfg.workers };
        let (sources, evaluator, mut theta, fused) = build_workload(cfg, local_workers)?;
        let fused = if cfg.fused_update { fused } else { None };
        let (workers, mut server) =
            spec.build_fused(theta.len(), local_workers, cfg.rounds, fused);
        let mut workers = apply_byzantine(&cfg.byzantine, workers)?;
        if cfg.server_shards > 1 {
            // Replace the full-θ server with S per-shard servers (the
            // validate() above already rejected the fused combination).
            server = Box::new(ShardedServer::new(
                &spec,
                theta.len(),
                cfg.rounds,
                cfg.server_shards,
                cfg.server_threaded,
            )?);
        }
        server.set_agg_mode(AggMode::parse(&cfg.robust_agg)?)?;
        if let Some(ck) = ckpt {
            ensure!(
                ck.theta.len() == theta.len(),
                "checkpoint θ has {} coordinates, model has {}",
                ck.theta.len(),
                theta.len()
            );
            theta = ck.theta.clone();
            server
                .import_state(&ck.server)
                .context("restoring the server optimizer state")?;
        }
        // In tree mode the root's "workers" are sub-leaders, whose EF
        // accumulator is the group compressor's (set inside the branch).
        let mut root_ef_bits = spec.ef_state_bits(theta.len());
        let (transport, supervisor, tree): (
            Box<dyn Transport>,
            Option<Supervisor>,
            Option<TreeHandle>,
        ) = match tspec {
            TransportSpec::Tcp { port } => {
                // Workers are remote processes (local_workers == 0: the
                // pool pieces above are empty). Any resume blobs ride
                // the ASSIGN frames.
                drop(workers);
                drop(sources);
                let leader = TcpLeader::bind(port)?;
                let addr = leader.local_addr()?;
                let sup = if cfg.spawn_workers {
                    let mut sup = Supervisor::spawn(cfg.workers, &addr.to_string())?;
                    // Spawned children are supervised: a crashed worker
                    // is restarted with backoff and rejoins its wid.
                    sup.set_restart_policy(RestartPolicy::default());
                    Some(sup)
                } else {
                    eprintln!(
                        "waiting for {} worker(s): comp-ams worker --leader {addr}",
                        cfg.workers
                    );
                    None
                };
                let streams = leader.accept_hellos(cfg.workers)?;
                let mut tcp =
                    assign_streams(&streams, cfg, ckpt.map(|c| c.workers.as_slice()), false)?;
                // Keep the listen socket: a replacement worker (restarted
                // by the supervisor, or launched by hand) can HELLO back
                // into a dead wid mid-run.
                tcp.adopt_listener(leader)?;
                (Box::new(tcp), sup, None)
            }
            in_proc if matches!(topo, Topology::Tree { .. }) => {
                let Topology::Tree { degree, ref group_compressor } = topo else {
                    unreachable!("guard matched Tree");
                };
                // Suspend would have to detach through two runtime
                // layers and reconcile the sub-leaders' EF state — the
                // tree transport rejects detach, so a tree checkpoint
                // cannot exist; refuse a hand-crafted one symmetrically.
                ensure!(
                    ckpt.is_none(),
                    "tree topology does not support checkpoint resume"
                );
                let dim = theta.len();
                let downlink = match cfg.downlink_compress.as_str() {
                    "" => None,
                    s => Some(CompressorSpec::parse(s)?),
                };
                let kill = parse_tree_kill(&cfg.tree_kill)?;
                let agg = AggMode::parse(&cfg.robust_agg)?;
                root_ef_bits = if *group_compressor == CompressorSpec::Identity {
                    0
                } else {
                    32 * dim as u64
                };
                // Split the flat worker list into contiguous
                // degree-sized groups. The (source, algo) pairs went
                // through the same per-wid constructors as the flat
                // star, so per-worker compressor salting (and byzantine
                // wrapping) is unchanged — only who collects differs.
                let sizes: Vec<usize> = (0..cfg.workers.div_ceil(degree))
                    .map(|g| degree.min(cfg.workers - g * degree))
                    .collect();
                let pools: Vec<WorkerPool> = match sources {
                    Sources::Threadable(s) => chunk(s, degree)
                        .into_iter()
                        .zip(chunk(workers, degree))
                        .map(|(src, alg)| {
                            if cfg.threaded {
                                WorkerPool::threaded(src, alg)
                            } else {
                                WorkerPool::sequential(
                                    src.into_iter()
                                        .map(|b| b as Box<dyn GradSource>)
                                        .collect(),
                                    alg,
                                )
                            }
                        })
                        .collect::<Result<_>>()?,
                    Sources::LeaderOnly(s) => chunk(s, degree)
                        .into_iter()
                        .zip(chunk(workers, degree))
                        .map(|(src, alg)| WorkerPool::sequential(src, alg))
                        .collect::<Result<_>>()?,
                };
                // Each group rides the bare in-process transport; the
                // simulator (if configured) wraps the whole tree so its
                // virtual clock times the sub-leader ↔ root links.
                let bare = match in_proc {
                    TransportSpec::Sim { inner } => inner.spec(),
                    other => other,
                };
                let mut groups = Vec::with_capacity(pools.len());
                for (pool, &size) in pools.into_iter().zip(&sizes) {
                    let mut rt = ClusterRuntime::new(bare.build(pool)?, 0, 0)?;
                    rt.set_ef_state_bits(spec.ef_state_bits(dim));
                    let mut srv = GroupForwardServer::new(dim, group_compressor);
                    srv.set_agg_mode(agg)?;
                    groups.push((rt, srv, size));
                }
                let (tree_t, handle) = TreeTransport::new(
                    groups,
                    dim,
                    downlink.as_ref(),
                    kill,
                    spec.ef_state_bits(dim),
                )?;
                let transport: Box<dyn Transport> = match in_proc {
                    TransportSpec::Sim { .. } => Box::new(Sim::new(
                        tree_t,
                        cfg.sim_seed,
                        SimProfile::parse(&cfg.sim_profile)?,
                    )),
                    _ => Box::new(tree_t),
                };
                (transport, None, Some(handle))
            }
            in_proc => {
                // On resume, worker state goes back into the freshly
                // built (source, algo) pairs *before* they move into the
                // pool — the two Sources variants hold different trait-
                // object types, so each arm restores its own.
                let pool = match sources {
                    Sources::Threadable(mut s) => {
                        if let Some(ck) = ckpt {
                            for (w, blob) in ck.workers.iter().enumerate() {
                                import_worker_blob(s[w].as_mut(), workers[w].as_mut(), blob)
                                    .with_context(|| format!("restoring worker {w} state"))?;
                            }
                        }
                        if cfg.threaded {
                            WorkerPool::threaded(s, workers)?
                        } else {
                            WorkerPool::sequential(
                                s.into_iter().map(|b| b as Box<dyn GradSource>).collect(),
                                workers,
                            )?
                        }
                    }
                    Sources::LeaderOnly(mut s) => {
                        if let Some(ck) = ckpt {
                            for (w, blob) in ck.workers.iter().enumerate() {
                                import_worker_blob(s[w].as_mut(), workers[w].as_mut(), blob)
                                    .with_context(|| format!("restoring worker {w} state"))?;
                            }
                        }
                        WorkerPool::sequential(s, workers)?
                    }
                };
                let transport = match in_proc {
                    sim @ TransportSpec::Sim { .. } => sim.build_sim(
                        pool,
                        cfg.sim_seed,
                        SimProfile::parse(&cfg.sim_profile)?,
                    )?,
                    bare => bare.build(pool)?,
                };
                (transport, None, None)
            }
        };
        let mut runtime = ClusterRuntime::new(transport, cfg.quorum, cfg.max_staleness)?;
        // Size the per-worker EF accumulator so a worker death charges
        // the lost residual to the ledger.
        runtime.set_ef_state_bits(root_ef_bits);
        if tree.is_some() {
            // Forwarded group aggregates arrive at the root as ordinary
            // Dense payloads; phase-filtering servers (1-bit Adam) must
            // treat them as pre-averaged means, not raw worker uplinks.
            server.set_pre_aggregated(true);
        }
        let algo_name = server.name();
        Ok(Trainer {
            cfg: cfg.clone(),
            runtime,
            server,
            algo_name,
            evaluator,
            theta,
            ledger: ckpt.map(|c| c.ledger.clone()).unwrap_or_default(),
            metrics: ckpt.map(|c| c.metrics.clone()).unwrap_or_default(),
            worker_ms_total: ckpt.map_or(0.0, |c| c.worker_ms_total),
            round_ms_total: ckpt.map_or(0.0, |c| c.round_ms_total),
            next_round: ckpt.map_or(0, |c| c.round),
            supervisor,
            tree,
        })
    }

    /// Assemble a trainer over a transport the caller already owns — the
    /// resident scheduler's path, where the fleet's sockets were
    /// ASSIGNed via [`assign_streams`](super::net::assign_streams) and
    /// the worker resume state rode those frames. Only the leader half —
    /// θ, the server optimizer, the ledger/metrics tail — is restored
    /// from `ckpt` here. Analytic substrates only (the evaluator is
    /// rebuilt leader-side from the config); no supervisor is attached —
    /// whoever owns the fleet owns its processes.
    pub fn with_transport(
        cfg: &TrainConfig,
        transport: Box<dyn Transport>,
        ckpt: Option<&JobCheckpoint>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        ensure!(
            cfg.is_analytic(),
            "with_transport serves the analytic substrates, not '{}'",
            cfg.model
        );
        ensure!(
            Topology::parse(&cfg.topology)? == Topology::Flat,
            "with_transport drives the flat star; tree topology assembles \
             its own transport"
        );
        let spec = AlgoSpec::parse(&cfg.algo)?;
        let (_sources, evaluator, mut theta, _fused) = build_workload(cfg, 0)?;
        let (_workers, mut server) = spec.build_fused(theta.len(), 0, cfg.rounds, None);
        if cfg.server_shards > 1 {
            server = Box::new(ShardedServer::new(
                &spec,
                theta.len(),
                cfg.rounds,
                cfg.server_shards,
                cfg.server_threaded,
            )?);
        }
        server.set_agg_mode(AggMode::parse(&cfg.robust_agg)?)?;
        if let Some(ck) = ckpt {
            ensure!(
                ck.round <= cfg.rounds,
                "checkpoint round {} past the configured {} rounds",
                ck.round,
                cfg.rounds
            );
            ensure!(
                ck.theta.len() == theta.len(),
                "checkpoint θ has {} coordinates, model has {}",
                ck.theta.len(),
                theta.len()
            );
            theta = ck.theta.clone();
            server
                .import_state(&ck.server)
                .context("restoring the server optimizer state")?;
        }
        let mut runtime = ClusterRuntime::new(transport, cfg.quorum, cfg.max_staleness)?;
        runtime.set_ef_state_bits(spec.ef_state_bits(theta.len()));
        let algo_name = server.name();
        Ok(Trainer {
            cfg: cfg.clone(),
            runtime,
            server,
            algo_name,
            evaluator,
            theta,
            ledger: ckpt.map(|c| c.ledger.clone()).unwrap_or_default(),
            metrics: ckpt.map(|c| c.metrics.clone()).unwrap_or_default(),
            worker_ms_total: ckpt.map_or(0.0, |c| c.worker_ms_total),
            round_ms_total: ckpt.map_or(0.0, |c| c.round_ms_total),
            next_round: ckpt.map_or(0, |c| c.round),
            supervisor: None,
            tree: None,
        })
    }

    pub fn algo_name(&self) -> String {
        self.algo_name.clone()
    }

    /// Drive one runtime round; returns the mean train loss over the
    /// uplinks that arrived.
    pub fn step(&mut self, round: u64) -> Result<f32> {
        let sw = Stopwatch::start();
        let lr = self.cfg.schedule.lr_at(self.cfg.lr, round);

        // Supervised children first: a crashed worker whose backoff has
        // elapsed is respawned here, and its HELLO is picked up by the
        // runtime's rejoin probe at dispatch.
        if let Some(sup) = self.supervisor.as_mut() {
            sup.tick()?;
        }

        // The runtime runs the whole round state machine: downlink
        // dispatch, quorum collection, staleness classification, and the
        // server step (per-shard when sharded).
        let out = self.runtime.run_round(
            &mut self.theta,
            self.server.as_mut(),
            round,
            lr,
            &mut self.ledger,
        )?;
        self.worker_ms_total += out.worker_ms;
        // Fold the sub-leaders' private ledgers into the run ledger at
        // level 1 before the round metric snapshots the cumulative bits.
        if let Some(h) = &self.tree {
            h.absorb_level1(&mut self.ledger);
        }
        if let Some(stats) = self.server.shard_stats() {
            self.ledger.sync_shard_routing(&stats.routed_bits);
        }
        let links = self.runtime.link_stats();
        if !links.is_empty() {
            self.ledger.sync_sim_links(&links);
        }

        let wall = sw.ms();
        self.round_ms_total += wall;
        let train_loss = out.train_loss;
        let eval = if self.cfg.eval_every > 0 && (round + 1) % self.cfg.eval_every == 0 {
            Some(self.evaluator.eval(&self.theta)?)
        } else {
            None
        };
        self.metrics.push(RoundMetric {
            round,
            epoch: (round + 1) as f32 / self.cfg.rounds_per_epoch.max(1) as f32,
            train_loss,
            eval,
            uplink_bits: self.ledger.uplink_bits,
            downlink_bits: self.ledger.downlink_bits,
            lr,
            wall_ms: wall,
        });
        if self.cfg.log_every > 0 && (round + 1) % self.cfg.log_every == 0 {
            let e = self.metrics.last().unwrap();
            let acc = e
                .eval
                .map(|s| format!(" test_acc={:.4} test_loss={:.4}", s.accuracy, s.loss))
                .unwrap_or_default();
            let lag = if out.stale > 0 || out.dropped > 0 {
                format!(" stale {} dropped {}", out.stale, out.dropped)
            } else {
                String::new()
            };
            eprintln!(
                "[{}] round {:>6} epoch {:>6.2} loss {:.4}{} lr {:.2e} uplink {:.2} MB{}",
                self.algo_name,
                round + 1,
                e.epoch,
                train_loss,
                acc,
                lr,
                e.uplink_bits as f64 / 8e6,
                lag,
            );
        }
        self.next_round = round + 1;
        Ok(train_loss)
    }

    /// The next round [`Trainer::run`] would execute — equal to the
    /// number of rounds completed so far (suspension included).
    pub fn next_round(&self) -> u64 {
        self.next_round
    }

    /// Quiesce the run and capture everything needed to continue it
    /// bitwise later: drain the in-flight uplinks (they stay billed),
    /// DETACH every worker collecting its suspend blob (compressor RNG,
    /// error feedback, batch stream), and export the server optimizer.
    /// Requires every worker alive — a dead worker's accumulated error
    /// feedback is unrecoverable, so a checkpoint claiming to carry it
    /// would be a lie. Consumes the trainer; remote fleets are released
    /// back to idle (pooled transports keep their sockets open for the
    /// next ASSIGN), and any supervisor-spawned children are reaped.
    pub fn suspend(mut self) -> Result<JobCheckpoint> {
        self.runtime.drain_in_flight(&mut self.ledger)?;
        let blobs = self.runtime.detach_workers(true)?;
        let mut workers = Vec::with_capacity(blobs.len());
        for (w, blob) in blobs.into_iter().enumerate() {
            workers.push(blob.ok_or_else(|| {
                anyhow::anyhow!("worker {w} died; cannot checkpoint its state")
            })?);
        }
        let server = self
            .server
            .export_state()
            .context("exporting the server optimizer state")?;
        // Dedicated (non-pooled) clusters are done with their workers:
        // send SHUTDOWN so detached daemons exit instead of idling
        // forever, then reap any children we spawned. On a pooled fleet
        // transport this is a no-op — the scheduler keeps the sockets.
        self.runtime.shutdown()?;
        if let Some(sup) = self.supervisor.as_mut() {
            sup.reap(Duration::from_secs(10))?;
        }
        Ok(JobCheckpoint {
            round: self.next_round,
            cfg: self.cfg.clone(),
            theta: self.theta,
            server,
            workers,
            ledger: self.ledger,
            metrics: self.metrics,
            worker_ms_total: self.worker_ms_total,
            round_ms_total: self.round_ms_total,
        })
    }

    /// End-of-run teardown: bill the straggler uplinks still in flight
    /// (K < n only — transmitted messages the ledger must not lose;
    /// these post-date the last round metric, so they appear in the
    /// ledger-derived `RunResult` fields but not in metrics'
    /// `uplink_bits`), broadcast SHUTDOWN to remote workers, and reap
    /// any supervisor-spawned child processes. [`Trainer::run`] calls
    /// this after its last round; drive it yourself when stepping rounds
    /// manually over a tcp cluster, or the children only go away on
    /// drop.
    pub fn finish(&mut self) -> Result<()> {
        self.runtime.drain_in_flight(&mut self.ledger)?;
        self.runtime.shutdown()?;
        if let Some(sup) = self.supervisor.as_mut() {
            let reports = sup.reap(Duration::from_secs(10))?;
            let nonzero: Vec<String> = reports
                .iter()
                .filter(|r| !r.status.success())
                .map(|r| format!("slot {} {}", r.slot, r.status))
                .collect();
            let dead = self.runtime.dead_workers();
            if nonzero.len() > dead.len() {
                eprintln!(
                    "warning: worker process(es) exited non-zero [{}] \
                     ({} accounted as dead mid-run)",
                    nonzero.join(", "),
                    dead.len()
                );
            }
        }
        Ok(())
    }

    /// Run every remaining round (`next_round..rounds`) and finalize —
    /// the whole job for a fresh trainer, the tail for a resumed one.
    pub fn run(mut self) -> Result<RunResult> {
        while self.next_round < self.cfg.rounds {
            self.step(self.next_round)?;
        }
        self.finalize()
    }

    /// Teardown plus final evaluation: fold the run into its
    /// [`RunResult`]. `total_wall_ms` is the accumulated in-round wall
    /// time — carried through [`JobCheckpoint`]s, so a preempted job's
    /// result covers the whole job, not just its last segment.
    pub fn finalize(mut self) -> Result<RunResult> {
        self.finish()?;
        // Absorb any group-side charges the drain above produced.
        if let Some(h) = &self.tree {
            h.absorb_level1(&mut self.ledger);
        }
        // Capture the end-of-run straggler deliveries finish() drained.
        let links = self.runtime.link_stats();
        if !links.is_empty() {
            self.ledger.sync_sim_links(&links);
        }
        let final_eval = self.evaluator.eval(&self.theta)?;
        let server_ms_by_shard = self
            .server
            .shard_stats()
            .map(|st| st.step_ms.clone())
            .unwrap_or_default();
        Ok(RunResult {
            algo: self.algo_name.clone(),
            model: self.cfg.model.clone(),
            workers: self.cfg.workers,
            metrics: self.metrics,
            final_eval,
            total_wall_ms: self.round_ms_total,
            coord_overhead: if self.round_ms_total > 0.0 {
                // Clamped: timer jitter (worker stopwatch vs round
                // stopwatch) must not report a negative leader share.
                (1.0 - self.worker_ms_total / self.round_ms_total).clamp(0.0, 1.0)
            } else {
                0.0
            },
            stale_uplinks: self.ledger.stale_uplinks,
            dropped_uplinks: self.ledger.dropped_uplinks,
            framing_bits: self.ledger.framing_bits,
            rejoins: self.ledger.rejoins,
            ef_resets: self.ledger.ef_resets,
            ef_residual_lost_bits: self.ledger.ef_residual_lost_bits,
            uplink_bits_by_worker: self.ledger.uplink_bits_by_worker.clone(),
            uplink_bits_by_shard: self.ledger.uplink_bits_by_shard.clone(),
            uplink_bits_by_level: self.ledger.uplink_bits_by_level.clone(),
            downlink_bits_by_level: self.ledger.downlink_bits_by_level.clone(),
            framing_bits_by_level: self.ledger.framing_bits_by_level.clone(),
            server_ms_by_shard,
            sim_links: self.ledger.sim_links.clone(),
        })
    }

    pub fn eval_now(&mut self) -> Result<EvalStats> {
        self.evaluator.eval(&self.theta)
    }

    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }
}

/// One-call convenience: build + run.
pub fn train(cfg: &TrainConfig) -> Result<RunResult> {
    Trainer::new(cfg)?.run()
}

/// Split `v` into contiguous chunks of at most `size` (the last one may
/// be smaller). `slice::chunks` borrows; the per-group worker pools need
/// ownership.
fn chunk<T>(mut v: Vec<T>, size: usize) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    while v.len() > size {
        let rest = v.split_off(size);
        out.push(std::mem::replace(&mut v, rest));
    }
    out.push(v);
    out
}

// ---------------------------------------------------------------------------

/// Gradient sources for the pool. The analytic substrates produce `Send`
/// sources that can move into worker threads; the PJRT path is pinned to
/// the leader thread (`Rc` handles inside the executables).
enum Sources {
    Threadable(Vec<Box<dyn GradSource + Send>>),
    LeaderOnly(Vec<Box<dyn GradSource>>),
}

type Workload = (
    Sources,
    Box<dyn Evaluator>,
    Vec<f32>,
    Option<Rc<OptimizerExe>>,
);

/// The quadratic substrate for this config — one construction shared by
/// the leader's workload assembly and the remote worker daemon, so both
/// sides build bitwise-identical shards.
fn quadratic_problem(cfg: &TrainConfig) -> Result<QuadraticProblem> {
    // Dirichlet sharding has no labels here; non-iid is expressed
    // through σ_g > 0 instead.
    let sigma_g = match Sharding::parse(&cfg.sharding)? {
        Sharding::Iid => 0.0,
        Sharding::Dirichlet { alpha } => (1.0 / alpha).min(10.0),
    };
    Ok(QuadraticProblem::new(cfg.seed, 256, cfg.workers, 20.0, 1.0, sigma_g))
}

/// The logistic substrate for this config (see [`quadratic_problem`]).
fn logistic_problem(cfg: &TrainConfig) -> LogisticProblem {
    LogisticProblem::new(cfg.seed, 64, 10, 32, 0.5)
}

/// Build worker `wid`'s gradient source and protocol worker half from a
/// config — the remote half of the TCP handshake: a `comp-ams worker`
/// daemon calls this with the `(wid, TrainConfig)` the leader ASSIGNed,
/// and gets exactly the objects the leader's in-process pool would have
/// built for that worker (same constructors, same seeds, same per-worker
/// compressor salting), which is what makes a K = n TCP run bitwise
/// identical to `InProc`.
///
/// Only the analytic substrates are supported: PJRT sources need the
/// artifact bundle and are leader-pinned.
pub fn build_worker_parts(
    cfg: &TrainConfig,
    wid: usize,
) -> Result<(Box<dyn GradSource>, Box<dyn WorkerAlgo>)> {
    anyhow::ensure!(
        wid < cfg.workers,
        "wid {wid} out of range for {} workers",
        cfg.workers
    );
    let src: Box<dyn GradSource> = match cfg.model.as_str() {
        "quadratic" => Box::new(quadratic_problem(cfg)?.source_for(wid, cfg.seed)),
        "logistic" => Box::new(logistic_problem(cfg).source_for(wid, cfg.seed)),
        other => bail!(
            "multi-process workers support the analytic substrates \
             (quadratic | logistic), not '{other}'"
        ),
    };
    // Build the full worker-half set and keep ours: stochastic
    // compressors are salted by worker index, so construction must go
    // through the same path as the leader's. Byzantine wrapping happens
    // here too, so a remote daemon corrupts exactly the gradients the
    // leader's in-process pool would have.
    let spec = AlgoSpec::parse(&cfg.algo)?;
    let workers = spec.build(src.dim(), cfg.workers, cfg.rounds).0;
    let mut workers = apply_byzantine(&cfg.byzantine, workers)?;
    Ok((src, workers.swap_remove(wid)))
}

/// Wrap the configured adversarial workers (`--byzantine`) around their
/// honest protocol halves. Shared by the leader's in-process build and
/// [`build_worker_parts`] so both sides of a TCP cluster agree on who is
/// corrupted. Entries beyond `workers.len()` are ignored here (the leader
/// builds zero local halves for a TCP run); `TrainConfig::validate`
/// rejects genuinely out-of-range ids.
fn apply_byzantine(
    byzantine: &str,
    workers: Vec<Box<dyn WorkerAlgo>>,
) -> Result<Vec<Box<dyn WorkerAlgo>>> {
    let specs = parse_byzantine(byzantine)?;
    if specs.is_empty() {
        return Ok(workers);
    }
    Ok(workers
        .into_iter()
        .enumerate()
        .map(|(wid, algo)| match specs.iter().find(|s| s.wid == wid) {
            Some(s) => ByzantineWorker::wrap(algo, s.mode),
            None => algo,
        })
        .collect())
}

/// `n_sources` is how many *leader-side* gradient sources to build:
/// `cfg.workers` for the in-process transports, 0 for tcp (remote worker
/// processes own their sources). θ and the evaluator never depend on it.
fn build_workload(cfg: &TrainConfig, n_sources: usize) -> Result<Workload> {
    match cfg.model.as_str() {
        "quadratic" => {
            let p = quadratic_problem(cfg)?;
            let sources: Vec<Box<dyn GradSource + Send>> = (0..n_sources)
                .map(|w| Box::new(p.source_for(w, cfg.seed)) as _)
                .collect();
            let theta = vec![0.0f32; p.dim()];
            let eval = Box::new(QuadraticEvaluator { problem: p });
            Ok((Sources::Threadable(sources), eval, theta, None))
        }
        "logistic" => {
            let p = logistic_problem(cfg);
            let sources: Vec<Box<dyn GradSource + Send>> = (0..n_sources)
                .map(|w| Box::new(p.source_for(w, cfg.seed)) as _)
                .collect();
            let theta = vec![0.0f32; p.p()];
            let eval =
                Box::new(LogisticEvaluator { problem: p, seed: cfg.seed ^ 0xE0, n: 2000 });
            Ok((Sources::Threadable(sources), eval, theta, None))
        }
        // PJRT models are never multi-process (validate() rejects tcp for
        // them), so n_sources == cfg.workers here.
        name => build_pjrt_workload(cfg, name),
    }
}

fn build_pjrt_workload(cfg: &TrainConfig, name: &str) -> Result<Workload> {
    let rt = Rc::new(Runtime::cpu()?);
    let bundle = Rc::new(
        ModelBundle::load(&rt, Path::new(&cfg.artifacts), name).with_context(|| {
            format!(
                "loading model '{name}' from {} (run `make artifacts`?)",
                cfg.artifacts.display()
            )
        })?,
    );
    let entry = &bundle.entry;
    let seq_len = entry.x_shape.first().copied().unwrap_or(0);

    // Dataset per workload (DESIGN.md §4 substitutions).
    let mk_classif = |ds: Rc<dyn Dataset>| -> Result<Vec<ShardStream>> {
        let mut rng = crate::util::rng::Rng::seed(cfg.seed ^ 0x5A4D);
        let weights = Sharding::parse(&cfg.sharding)?.worker_weights(
            &mut rng,
            cfg.workers,
            ds.classes(),
        );
        Ok(weights
            .into_iter()
            .map(|w| ShardStream::Classif { ds: Rc::clone(&ds), weights: w })
            .collect())
    };

    let streams: Vec<ShardStream> = match name {
        "mnist_cnn" => mk_classif(Rc::new(SyntheticImages::mnist_like(cfg.seed)))?,
        "cifar_lenet" | "cifar_resnet" => {
            mk_classif(Rc::new(SyntheticImages::cifar_like(cfg.seed)))?
        }
        "imdb_lstm" => mk_classif(Rc::new(SyntheticText::imdb_like(cfg.seed, seq_len)))?,
        "logreg" => mk_classif(Rc::new(GaussianVectors::new(cfg.seed, 64, 4, 0.5)))?,
        "lm_small" | "lm_large" => {
            let corpus = Rc::new(ByteCorpus::generate(cfg.seed, 262_144, seq_len));
            (0..cfg.workers)
                .map(|_| ShardStream::Lm { corpus: Rc::clone(&corpus) })
                .collect()
        }
        other => bail!("no data substrate wired for model '{other}'"),
    };

    // The evaluator draws its own iid test stream (never label-skewed).
    let eval_stream = match &streams[0] {
        ShardStream::Classif { ds, .. } => {
            ShardStream::Classif { ds: Rc::clone(ds), weights: None }
        }
        ShardStream::Lm { corpus } => ShardStream::Lm { corpus: Rc::clone(corpus) },
    };
    let evaluator = Box::new(PjrtEvaluator::new(
        Rc::clone(&bundle),
        &eval_stream,
        cfg.seed,
        cfg.eval_batches,
    ));

    let sources: Vec<Box<dyn GradSource>> = streams
        .into_iter()
        .enumerate()
        .map(|(w, stream)| {
            Box::new(PjrtSource::new(Rc::clone(&bundle), stream, cfg.seed, w)) as _
        })
        .collect();
    let theta = bundle.init_theta.clone();
    let fused = Some(Rc::clone(&bundle.amsgrad));
    Ok((Sources::LeaderOnly(sources), evaluator, theta, fused))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    #[test]
    fn quadratic_comp_ams_descends() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.05");
        cfg.workers = 4;
        cfg.rounds = 300;
        cfg.lr = 0.05;
        cfg.eval_every = 0;
        let run = train(&cfg).unwrap();
        let first = run.metrics[0].train_loss;
        let last = run.final_train_loss(20);
        assert!(last < first - 0.5, "no descent: {first} -> {last}");
    }

    #[test]
    fn threaded_matches_sequential_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-blocksign:64");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.threaded = true;
        let b = train(&cfg).unwrap();
        for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(ma.train_loss, mb.train_loss, "round {}", ma.round);
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        assert_eq!(a.uplink_bits_by_worker, b.uplink_bits_by_worker);
    }

    #[test]
    fn loopback_transport_matches_inproc_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.transport = "loopback".into();
        let b = train(&cfg).unwrap();
        for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        // Every uplink crossed the byte framing; no staleness under the
        // full-quorum default.
        assert_eq!(b.stale_uplinks, 0);
        assert_eq!(b.dropped_uplinks, 0);
    }

    #[test]
    fn sharded_server_matches_unsharded_trajectory() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        cfg.rounds = 40;
        cfg.eval_every = 0;
        let a = train(&cfg).unwrap();
        cfg.server_shards = 4;
        let b = train(&cfg).unwrap();
        cfg.server_threaded = true;
        let c = train(&cfg).unwrap();
        for ((ma, mb), mc) in a.metrics.iter().zip(&b.metrics).zip(&c.metrics) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.train_loss.to_bits(), mc.train_loss.to_bits());
            assert_eq!(ma.uplink_bits, mb.uplink_bits);
        }
        // Per-shard accounting surfaces only for sharded runs, and the
        // deterministic routing bills identical bits on both backends.
        assert!(a.uplink_bits_by_shard.is_empty());
        assert!(a.server_ms_by_shard.is_empty());
        assert_eq!(b.uplink_bits_by_shard.len(), 4);
        assert_eq!(b.server_ms_by_shard.len(), 4);
        assert!(b.uplink_bits_by_shard.iter().all(|&bits| bits > 0));
        assert_eq!(b.uplink_bits_by_shard, c.uplink_bits_by_shard);
    }

    #[test]
    fn uplink_accounting_topk_vs_dense() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 2;
        cfg.rounds = 10;
        cfg.eval_every = 0;
        let dense = train(&cfg).unwrap();
        cfg.algo = "comp-ams-topk:0.01".into();
        let sparse = train(&cfg).unwrap();
        assert!(sparse.uplink_bits() < dense.uplink_bits() / 10);
    }

    #[test]
    fn coord_overhead_is_clamped_to_unit_interval() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-sgd");
        cfg.workers = 2;
        cfg.rounds = 5;
        cfg.eval_every = 0;
        let run = train(&cfg).unwrap();
        assert!(
            (0.0..=1.0).contains(&run.coord_overhead),
            "{}",
            run.coord_overhead
        );
    }

    #[test]
    fn suspend_resume_matches_uninterrupted_run() {
        for algo in
            ["comp-ams-topk:0.1", "comp-ams-randomk:0.1", "qadam", "1bitadam:10", "dist-sgd"]
        {
            let mut cfg = TrainConfig::preset("quadratic", algo);
            cfg.workers = 3;
            cfg.rounds = 30;
            cfg.eval_every = 0;
            let solo = train(&cfg).unwrap();
            let mut t = Trainer::new(&cfg).unwrap();
            for r in 0..17 {
                t.step(r).unwrap();
            }
            let ckpt = t.suspend().unwrap();
            assert_eq!(ckpt.round, 17, "{algo}");
            assert_eq!(ckpt.metrics.len(), 17, "{algo}");
            let resumed = Trainer::resume(&ckpt).unwrap().run().unwrap();
            assert_eq!(solo.metrics.len(), resumed.metrics.len(), "{algo}");
            for (a, b) in solo.metrics.iter().zip(&resumed.metrics) {
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{algo} diverged at round {}",
                    a.round
                );
                assert_eq!(a.uplink_bits, b.uplink_bits, "{algo} round {}", a.round);
            }
            assert_eq!(
                solo.final_eval.loss.to_bits(),
                resumed.final_eval.loss.to_bits(),
                "{algo}: final loss differs after resume"
            );
            assert_eq!(solo.uplink_bits_by_worker, resumed.uplink_bits_by_worker, "{algo}");
        }
    }

    #[test]
    fn suspend_resume_preserves_threaded_and_sharded_runs() {
        // The pool's threaded backend and the sharded server both carry
        // their own state machinery through export/import.
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        cfg.workers = 3;
        cfg.rounds = 24;
        cfg.eval_every = 0;
        cfg.threaded = true;
        cfg.server_shards = 4;
        let solo = train(&cfg).unwrap();
        let mut t = Trainer::new(&cfg).unwrap();
        for r in 0..11 {
            t.step(r).unwrap();
        }
        let resumed = Trainer::resume(&t.suspend().unwrap()).unwrap().run().unwrap();
        for (a, b) in solo.metrics.iter().zip(&resumed.metrics) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        }
        assert_eq!(solo.uplink_bits_by_shard, resumed.uplink_bits_by_shard);
    }

    #[test]
    fn logistic_learns_with_all_protocols() {
        for algo in ["dist-ams", "comp-ams-topk:0.05", "comp-ams-blocksign:64", "qadam",
                     "1bitadam:20", "dist-sgd"] {
            let mut cfg = TrainConfig::preset("logistic", algo);
            cfg.workers = 4;
            cfg.rounds = 250;
            cfg.lr = if algo == "dist-sgd" { 0.1 } else { 0.05 };
            cfg.eval_every = 0;
            let run = train(&cfg).unwrap();
            assert!(
                run.final_eval.accuracy > 0.5,
                "{algo}: acc={}",
                run.final_eval.accuracy
            );
        }
    }
}
