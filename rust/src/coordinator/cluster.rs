//! Worker execution backends.
//!
//! A [`WorkerPool`] pairs each worker's gradient source with its
//! [`WorkerAlgo`] half and runs the **entire** per-worker pipeline —
//! gradient → error feedback → compression → wire encoding — as one unit,
//! returning a [`WorkerRound`] per worker.
//!
//! Since the event-driven runtime landed ([`crate::coordinator::runtime`])
//! the pool speaks a dispatch/arrival protocol instead of a single
//! lockstep call: [`WorkerPool::send`] starts one worker's round and
//! [`WorkerPool::recv`] yields the next *completed* round in arrival
//! order, tagged with the worker id and the round it was dispatched for.
//! The synchronous [`WorkerPool::run_round`] convenience (dispatch all,
//! collect all, order by worker id) is kept for benches and tests.
//!
//! The sequential backend runs each worker's round on the leader thread
//! at `send` time (required for PJRT executables, and the deterministic
//! default) and queues the result, so arrivals come back in dispatch
//! order. The threaded backend keeps one persistent OS thread per worker
//! fed over mpsc channels — the real leader/worker message plumbing —
//! with all workers replying on **one shared uplink channel**, so the
//! leader observes true arrival order (the property partial participation
//! exploits). Both yield identical trajectories under the K = n default
//! because all randomness lives in worker-owned RNG streams, not in
//! scheduling (asserted by the `threaded_matches_sequential` integration
//! test and the cross-protocol property test).
//!
//! The server half is **not** pinned to the leader anymore: the same
//! sequential/threaded backend pattern is mirrored on the server side by
//! [`ShardedServer`](crate::algo::sharded::ShardedServer), which splits θ
//! across per-shard `ServerAlgo` instances on persistent shard threads.
//! Only the Pallas fused-update server (non-`Send` PJRT handles) remains
//! leader-only.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use crate::algo::{RoundCtx, WorkerAlgo};
use crate::compress::Payload;
use crate::grad::GradSource;

/// One worker's complete output for a round, produced where the payload
/// is produced (worker thread in the threaded backend).
#[derive(Debug)]
pub struct WorkerRound {
    /// Training loss on this worker's mini-batch.
    pub loss: f32,
    /// The encoded uplink message.
    pub payload: Payload,
    /// Exact wire bits of `payload`, computed at the production site
    /// (`payload.wire_bits()`). The event runtime re-derives the same
    /// value from the envelope it consumes — decode is exact — so the
    /// ledger's charge is identical whichever side counts it; this field
    /// serves the lockstep [`WorkerPool::run_round`] path (benches,
    /// tests).
    pub uplink_bits: u64,
}

/// What travels back on the uplink channel: worker id, the round the
/// reply answers, and the worker's result.
type RawReply = (usize, u64, Result<WorkerRound>);

/// Run one worker's full round: gradient, then the protocol's worker half.
fn worker_round(
    src: &mut dyn GradSource,
    algo: &mut dyn WorkerAlgo,
    theta: &[f32],
    ctx: &RoundCtx,
) -> Result<WorkerRound> {
    let (loss, grad) = src.grad(theta, ctx.round)?;
    let payload = algo.process(&grad, ctx)?;
    let uplink_bits = payload.wire_bits();
    Ok(WorkerRound { loss, payload, uplink_bits })
}

enum Cmd {
    Round { theta: Arc<Vec<f32>>, ctx: RoundCtx },
    Export { reply: Sender<Result<Vec<u8>>> },
    Stop,
}

/// Serialize one worker's full resumable state — gradient-source stream +
/// protocol worker half — into the blob that travels in checkpoints and,
/// for remote workers, in DETACH/ASSIGN frames.
pub fn export_worker_blob(src: &dyn GradSource, algo: &dyn WorkerAlgo) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    crate::util::bytes::put_bytes(&mut out, &src.export_state()?);
    crate::util::bytes::put_bytes(&mut out, &algo.export_state());
    Ok(out)
}

/// Restore a blob produced by [`export_worker_blob`] into a freshly-built
/// source/algo pair.
pub fn import_worker_blob(
    src: &mut dyn GradSource,
    algo: &mut dyn WorkerAlgo,
    bytes: &[u8],
) -> Result<()> {
    let mut c = crate::util::bytes::Cursor::new(bytes);
    let src_blob = c.bytes()?.to_vec();
    let algo_blob = c.bytes()?.to_vec();
    c.finish()?;
    src.import_state(&src_blob)?;
    algo.import_state(&algo_blob)
}

struct SeqWorker {
    src: Box<dyn GradSource>,
    algo: Box<dyn WorkerAlgo>,
}

struct WorkerHandle {
    tx: Sender<Cmd>,
    join: Option<JoinHandle<()>>,
}

enum Backend {
    /// Leader-thread workers plus the queue of completed-but-unconsumed
    /// rounds (`send` computes eagerly; `recv` pops in dispatch order).
    Sequential { workers: Vec<SeqWorker>, queue: VecDeque<RawReply> },
    /// One command channel per worker; replies multiplex onto a single
    /// shared uplink channel so `recv` sees genuine arrival order.
    Threaded { handles: Vec<WorkerHandle>, uplink: Receiver<RawReply> },
}

pub struct WorkerPool {
    backend: Backend,
}

impl WorkerPool {
    /// Leader-thread backend. `sources[i]` is paired with `algos[i]`.
    pub fn sequential(
        sources: Vec<Box<dyn GradSource>>,
        algos: Vec<Box<dyn WorkerAlgo>>,
    ) -> Result<Self> {
        ensure!(
            sources.len() == algos.len(),
            "pool mismatch: {} sources vs {} worker algos",
            sources.len(),
            algos.len()
        );
        let workers = sources
            .into_iter()
            .zip(algos)
            .map(|(src, algo)| SeqWorker { src, algo })
            .collect();
        Ok(WorkerPool {
            backend: Backend::Sequential { workers, queue: VecDeque::new() },
        })
    }

    /// One persistent OS thread per worker; each thread owns its gradient
    /// source *and* its protocol worker half, and replies on the shared
    /// uplink channel.
    pub fn threaded(
        sources: Vec<Box<dyn GradSource + Send>>,
        algos: Vec<Box<dyn WorkerAlgo>>,
    ) -> Result<Self> {
        ensure!(
            sources.len() == algos.len(),
            "pool mismatch: {} sources vs {} worker algos",
            sources.len(),
            algos.len()
        );
        let (up_tx, up_rx) = channel::<RawReply>();
        let handles = sources
            .into_iter()
            .zip(algos)
            .enumerate()
            .map(|(wid, (mut src, mut algo))| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let rep_tx = up_tx.clone();
                let join = std::thread::Builder::new()
                    .name(format!("worker-{wid}"))
                    .spawn(move || {
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Cmd::Round { theta, ctx } => {
                                    let reply = worker_round(
                                        src.as_mut(),
                                        algo.as_mut(),
                                        &theta,
                                        &ctx,
                                    );
                                    if rep_tx.send((wid, ctx.round, reply)).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Export { reply } => {
                                    let blob =
                                        export_worker_blob(src.as_ref(), algo.as_ref());
                                    if reply.send(blob).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Stop => break,
                            }
                        }
                    })
                    .expect("spawn worker thread");
                WorkerHandle { tx: cmd_tx, join: Some(join) }
            })
            .collect();
        Ok(WorkerPool { backend: Backend::Threaded { handles, uplink: up_rx } })
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Sequential { workers, .. } => workers.len(),
            Backend::Threaded { handles, .. } => handles.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_threaded(&self) -> bool {
        matches!(self.backend, Backend::Threaded { .. })
    }

    /// Dispatch one worker's round at θ. Sequential backend: the whole
    /// pipeline runs here and the result is queued for [`WorkerPool::recv`];
    /// threaded backend: the command is sent to the worker thread and the
    /// call returns immediately.
    pub fn send(&mut self, wid: usize, theta: &Arc<Vec<f32>>, ctx: &RoundCtx) -> Result<()> {
        match &mut self.backend {
            Backend::Sequential { workers, queue } => {
                let w = workers
                    .get_mut(wid)
                    .ok_or_else(|| anyhow!("no worker {wid} in pool"))?;
                let reply = worker_round(w.src.as_mut(), w.algo.as_mut(), theta, ctx);
                queue.push_back((wid, ctx.round, reply));
                Ok(())
            }
            Backend::Threaded { handles, .. } => handles
                .get(wid)
                .ok_or_else(|| anyhow!("no worker {wid} in pool"))?
                .tx
                .send(Cmd::Round { theta: Arc::clone(theta), ctx: *ctx })
                .map_err(|_| anyhow!("worker {wid} thread died")),
        }
    }

    /// Next completed round in arrival order: `(wid, round, result)`.
    /// Outer error = the backend itself died (worker threads gone, or a
    /// sequential recv with nothing dispatched); the inner result
    /// carries the worker's own error. Callers must not out-recv their
    /// dispatches: the sequential backend errors on an empty queue, but
    /// the threaded backend **blocks** on its open channel until the
    /// next dispatch replies (the runtime's in-flight bookkeeping is
    /// what guarantees one recv per outstanding send).
    fn recv_raw(&mut self) -> Result<RawReply> {
        match &mut self.backend {
            Backend::Sequential { queue, .. } => queue
                .pop_front()
                .ok_or_else(|| anyhow!("recv with no dispatched worker round")),
            Backend::Threaded { uplink, .. } => {
                uplink.recv().map_err(|_| anyhow!("worker thread died"))
            }
        }
    }

    /// Next completed round in arrival order, with worker errors surfaced.
    pub fn recv(&mut self) -> Result<(usize, u64, WorkerRound)> {
        let (wid, round, res) = self.recv_raw()?;
        Ok((wid, round, res?))
    }

    /// Run every worker's full round (gradient + EF + compress + encode)
    /// at θ; results are ordered by worker id in both backends. Lockstep
    /// convenience over [`WorkerPool::send`]/[`WorkerPool::recv`] — the
    /// event-driven runtime drives the two halves itself.
    pub fn run_round(&mut self, theta: &[f32], ctx: &RoundCtx) -> Result<Vec<WorkerRound>> {
        let n = self.len();
        let shared = Arc::new(theta.to_vec());
        for wid in 0..n {
            self.send(wid, &shared, ctx)?;
        }
        // Drain every worker's reply before surfacing any error: a
        // short-circuit would leave this round's remaining replies queued
        // and silently deliver them next round.
        let mut raws = Vec::with_capacity(n);
        for _ in 0..n {
            raws.push(self.recv_raw()?);
        }
        raws.sort_by_key(|(wid, _, _)| *wid);
        raws.into_iter().map(|(_, _, res)| res).collect()
    }

    /// Snapshot every worker's resumable state ([`export_worker_blob`]),
    /// ordered by worker id. Must only be called with no rounds in flight
    /// (the runtime drains first); a threaded worker answers the export
    /// command from its own thread, so the blobs are taken from the
    /// authoritative copies wherever they live.
    pub fn export_states(&mut self) -> Result<Vec<Vec<u8>>> {
        match &mut self.backend {
            Backend::Sequential { workers, queue } => {
                ensure!(
                    queue.is_empty(),
                    "export_states with {} undelivered worker rounds queued",
                    queue.len()
                );
                workers
                    .iter()
                    .map(|w| export_worker_blob(w.src.as_ref(), w.algo.as_ref()))
                    .collect()
            }
            Backend::Threaded { handles, .. } => {
                let mut rxs = Vec::with_capacity(handles.len());
                for (wid, h) in handles.iter().enumerate() {
                    let (tx, rx) = channel();
                    h.tx
                        .send(Cmd::Export { reply: tx })
                        .map_err(|_| anyhow!("worker {wid} thread died"))?;
                    rxs.push(rx);
                }
                rxs.into_iter()
                    .enumerate()
                    .map(|(wid, rx)| {
                        rx.recv().map_err(|_| anyhow!("worker {wid} thread died"))?
                    })
                    .collect()
            }
        }
    }

    /// Restore per-worker blobs produced by [`WorkerPool::export_states`]
    /// into a freshly-built sequential pool. Threaded pools import before
    /// spawning (the builder path hands state in ahead of construction),
    /// so only the sequential backend needs in-place import.
    pub fn import_states(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        ensure!(
            blobs.len() == self.len(),
            "state blob count {} vs {} pool workers",
            blobs.len(),
            self.len()
        );
        match &mut self.backend {
            Backend::Sequential { workers, .. } => {
                for (w, blob) in workers.iter_mut().zip(blobs) {
                    import_worker_blob(w.src.as_mut(), w.algo.as_mut(), blob)?;
                }
                Ok(())
            }
            Backend::Threaded { .. } => {
                anyhow::bail!("import_states on a threaded pool: import before spawning")
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Backend::Threaded { handles, .. } = &mut self.backend {
            for h in handles.iter() {
                let _ = h.tx.send(Cmd::Stop);
            }
            for h in handles.iter_mut() {
                if let Some(j) = h.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AlgoSpec;
    use crate::grad::quadratic::QuadraticProblem;

    fn sources(n: usize) -> Vec<Box<dyn GradSource + Send>> {
        let p = QuadraticProblem::new(1, 16, n, 4.0, 0.5, 1.0);
        (0..n)
            .map(|w| Box::new(p.source_for(w, 7)) as Box<dyn GradSource + Send>)
            .collect()
    }

    fn algos(n: usize, spec: &str) -> Vec<Box<dyn WorkerAlgo>> {
        AlgoSpec::parse(spec).unwrap().build(16, n, 100).0
    }

    #[test]
    fn threaded_equals_sequential_full_pipeline() {
        // Identical (loss, payload, bits) per worker per round — the whole
        // worker pipeline, not just the gradient, is deterministic.
        for spec in ["dist-sgd", "comp-ams-topk:0.2", "comp-ams-blocksign:8"] {
            let seq_sources: Vec<Box<dyn GradSource>> = sources(4)
                .into_iter()
                .map(|b| b as Box<dyn GradSource>)
                .collect();
            let mut seq = WorkerPool::sequential(seq_sources, algos(4, spec)).unwrap();
            let mut thr = WorkerPool::threaded(sources(4), algos(4, spec)).unwrap();
            let theta = vec![0.2f32; 16];
            for round in 0..5 {
                let ctx = RoundCtx::sync(round, 0.01);
                let a = seq.run_round(&theta, &ctx).unwrap();
                let b = thr.run_round(&theta, &ctx).unwrap();
                for (ra, rb) in a.iter().zip(&b) {
                    assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{spec}");
                    assert_eq!(ra.payload, rb.payload, "{spec}");
                    assert_eq!(ra.uplink_bits, rb.uplink_bits, "{spec}");
                }
            }
        }
    }

    #[test]
    fn uplink_bits_match_payload_encoding() {
        let seq_sources: Vec<Box<dyn GradSource>> = sources(2)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        let mut pool =
            WorkerPool::sequential(seq_sources, algos(2, "comp-ams-topk:0.2")).unwrap();
        let theta = vec![0.1f32; 16];
        let ctx = RoundCtx::sync(0, 0.01);
        for r in pool.run_round(&theta, &ctx).unwrap() {
            assert_eq!(r.uplink_bits, r.payload.wire_bits());
            assert_eq!(r.uplink_bits, r.payload.encode().len() as u64 * 8);
        }
    }

    #[test]
    fn send_recv_yields_tagged_arrivals() {
        // The dispatch/arrival protocol underneath the event runtime:
        // partial dispatch, arrival-order recv with (wid, round) tags.
        let seq_sources: Vec<Box<dyn GradSource>> = sources(3)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        let mut pool = WorkerPool::sequential(seq_sources, algos(3, "dist-sgd")).unwrap();
        let theta = Arc::new(vec![0.1f32; 16]);
        // Dispatch only workers 2 and 0, for different rounds.
        pool.send(2, &theta, &RoundCtx::sync(7, 0.01)).unwrap();
        pool.send(0, &theta, &RoundCtx::sync(8, 0.01)).unwrap();
        let (wid_a, round_a, wr_a) = pool.recv().unwrap();
        let (wid_b, round_b, wr_b) = pool.recv().unwrap();
        assert_eq!((wid_a, round_a), (2, 7));
        assert_eq!((wid_b, round_b), (0, 8));
        assert_eq!(wr_a.uplink_bits, wr_a.payload.wire_bits());
        assert_eq!(wr_b.uplink_bits, wr_b.payload.wire_bits());
        // Nothing else was dispatched: on the sequential backend an
        // over-recv errors (the threaded backend would block instead).
        assert!(pool.recv().is_err());
        // Out-of-range worker id is rejected.
        assert!(pool.send(9, &theta, &RoundCtx::sync(0, 0.01)).is_err());
    }

    #[test]
    fn threaded_send_recv_collects_all_dispatched() {
        let mut pool = WorkerPool::threaded(sources(4), algos(4, "dist-sgd")).unwrap();
        let theta = Arc::new(vec![0.2f32; 16]);
        for wid in 0..4 {
            pool.send(wid, &theta, &RoundCtx::sync(3, 0.01)).unwrap();
        }
        let mut wids: Vec<usize> = (0..4)
            .map(|_| {
                let (wid, round, _) = pool.recv().unwrap();
                assert_eq!(round, 3);
                wid
            })
            .collect();
        wids.sort_unstable();
        assert_eq!(wids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pool_reports_len_and_backend() {
        let thr = WorkerPool::threaded(sources(3), algos(3, "dist-sgd")).unwrap();
        assert_eq!(thr.len(), 3);
        assert!(!thr.is_empty());
        assert!(thr.is_threaded());
    }

    #[test]
    fn mismatched_sources_and_algos_rejected() {
        let seq_sources: Vec<Box<dyn GradSource>> = sources(2)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        assert!(WorkerPool::sequential(seq_sources, algos(3, "dist-sgd")).is_err());
    }
}
