//! Worker execution backends.
//!
//! [`WorkerPool::Sequential`] runs each worker's gradient on the leader
//! thread (required for PJRT executables, and the deterministic default).
//! [`WorkerPool::Threaded`] keeps one persistent OS thread per worker fed
//! over mpsc channels — the real leader/worker message plumbing. Both
//! yield identical trajectories because all randomness lives in the
//! worker-owned RNG streams, not in scheduling (asserted by the
//! `threaded_matches_sequential` integration test).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::grad::GradSource;

enum Cmd {
    Grad { theta: Arc<Vec<f32>>, round: u64 },
    Stop,
}

type GradReply = Result<(f32, Vec<f32>)>;

pub struct WorkerHandle {
    tx: Sender<Cmd>,
    rx: Receiver<GradReply>,
    join: Option<JoinHandle<()>>,
}

pub enum WorkerPool {
    Sequential(Vec<Box<dyn GradSource>>),
    Threaded(Vec<WorkerHandle>),
}

impl WorkerPool {
    pub fn sequential(sources: Vec<Box<dyn GradSource>>) -> Self {
        WorkerPool::Sequential(sources)
    }

    pub fn threaded(sources: Vec<Box<dyn GradSource + Send>>) -> Self {
        let handles = sources
            .into_iter()
            .enumerate()
            .map(|(wid, mut src)| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let (rep_tx, rep_rx) = channel::<GradReply>();
                let join = std::thread::Builder::new()
                    .name(format!("worker-{wid}"))
                    .spawn(move || {
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Cmd::Grad { theta, round } => {
                                    let reply = src.grad(&theta, round);
                                    if rep_tx.send(reply).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Stop => break,
                            }
                        }
                    })
                    .expect("spawn worker thread");
                WorkerHandle { tx: cmd_tx, rx: rep_rx, join: Some(join) }
            })
            .collect();
        WorkerPool::Threaded(handles)
    }

    pub fn len(&self) -> usize {
        match self {
            WorkerPool::Sequential(v) => v.len(),
            WorkerPool::Threaded(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Compute all workers' (loss, grad) at θ for this round.
    pub fn compute_all(&mut self, theta: &[f32], round: u64) -> Result<Vec<(f32, Vec<f32>)>> {
        match self {
            WorkerPool::Sequential(sources) => sources
                .iter_mut()
                .map(|s| s.grad(theta, round))
                .collect(),
            WorkerPool::Threaded(handles) => {
                let shared = Arc::new(theta.to_vec());
                for h in handles.iter() {
                    h.tx
                        .send(Cmd::Grad { theta: Arc::clone(&shared), round })
                        .map_err(|_| anyhow!("worker thread died"))?;
                }
                handles
                    .iter()
                    .map(|h| h.rx.recv().map_err(|_| anyhow!("worker thread died"))?)
                    .collect()
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let WorkerPool::Threaded(handles) = self {
            for h in handles.iter() {
                let _ = h.tx.send(Cmd::Stop);
            }
            for h in handles.iter_mut() {
                if let Some(j) = h.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::quadratic::QuadraticProblem;

    fn sources(n: usize) -> Vec<Box<dyn GradSource + Send>> {
        let p = QuadraticProblem::new(1, 16, n, 4.0, 0.5, 1.0);
        (0..n)
            .map(|w| Box::new(p.source_for(w, 7)) as Box<dyn GradSource + Send>)
            .collect()
    }

    #[test]
    fn threaded_equals_sequential() {
        let seq_sources: Vec<Box<dyn GradSource>> = sources(4)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        let mut seq = WorkerPool::sequential(seq_sources);
        let mut thr = WorkerPool::threaded(sources(4));
        let theta = vec![0.2f32; 16];
        for round in 0..5 {
            let a = seq.compute_all(&theta, round).unwrap();
            let b = thr.compute_all(&theta, round).unwrap();
            for ((la, ga), (lb, gb)) in a.iter().zip(&b) {
                assert_eq!(la, lb);
                assert_eq!(ga, gb);
            }
        }
    }

    #[test]
    fn pool_reports_len() {
        let thr = WorkerPool::threaded(sources(3));
        assert_eq!(thr.len(), 3);
        assert!(!thr.is_empty());
    }
}
