//! Worker execution backends.
//!
//! A [`WorkerPool`] pairs each worker's gradient source with its
//! [`WorkerAlgo`] half and runs the **entire** per-worker pipeline —
//! gradient → error feedback → compression → wire encoding — as one unit,
//! returning a [`WorkerRound`] per worker.
//!
//! The sequential backend runs each worker's round on the leader thread
//! (required for PJRT executables, and the deterministic default). The
//! threaded backend keeps one persistent OS thread per worker fed over
//! mpsc channels — the real leader/worker message plumbing — and moves
//! the worker's compressor/EF/local-optimizer state into that thread, so
//! compression cost parallelizes with gradient cost. Both yield identical
//! trajectories because all randomness lives in worker-owned RNG streams,
//! not in scheduling (asserted by the `threaded_matches_sequential`
//! integration test and the cross-protocol property test).
//!
//! The server half is **not** pinned to the leader anymore: the same
//! sequential/threaded backend pattern is mirrored on the server side by
//! [`ShardedServer`](crate::algo::sharded::ShardedServer), which splits θ
//! across per-shard `ServerAlgo` instances on persistent shard threads.
//! Only the Pallas fused-update server (non-`Send` PJRT handles) remains
//! leader-only.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use crate::algo::{RoundCtx, WorkerAlgo};
use crate::compress::Payload;
use crate::grad::GradSource;

/// One worker's complete output for a round, produced where the payload
/// is produced (worker thread in the threaded backend).
#[derive(Debug)]
pub struct WorkerRound {
    /// Training loss on this worker's mini-batch.
    pub loss: f32,
    /// The encoded uplink message.
    pub payload: Payload,
    /// Exact wire bits of `payload` — uplink accounting happens at the
    /// production site, not on the leader.
    pub uplink_bits: u64,
}

/// Run one worker's full round: gradient, then the protocol's worker half.
fn worker_round(
    src: &mut dyn GradSource,
    algo: &mut dyn WorkerAlgo,
    theta: &[f32],
    ctx: &RoundCtx,
) -> Result<WorkerRound> {
    let (loss, grad) = src.grad(theta, ctx.round)?;
    let payload = algo.process(&grad, ctx)?;
    let uplink_bits = payload.wire_bits();
    Ok(WorkerRound { loss, payload, uplink_bits })
}

enum Cmd {
    Round { theta: Arc<Vec<f32>>, ctx: RoundCtx },
    Stop,
}

struct SeqWorker {
    src: Box<dyn GradSource>,
    algo: Box<dyn WorkerAlgo>,
}

struct WorkerHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Result<WorkerRound>>,
    join: Option<JoinHandle<()>>,
}

enum Backend {
    Sequential(Vec<SeqWorker>),
    Threaded(Vec<WorkerHandle>),
}

pub struct WorkerPool {
    backend: Backend,
}

impl WorkerPool {
    /// Leader-thread backend. `sources[i]` is paired with `algos[i]`.
    pub fn sequential(
        sources: Vec<Box<dyn GradSource>>,
        algos: Vec<Box<dyn WorkerAlgo>>,
    ) -> Result<Self> {
        ensure!(
            sources.len() == algos.len(),
            "pool mismatch: {} sources vs {} worker algos",
            sources.len(),
            algos.len()
        );
        let workers = sources
            .into_iter()
            .zip(algos)
            .map(|(src, algo)| SeqWorker { src, algo })
            .collect();
        Ok(WorkerPool { backend: Backend::Sequential(workers) })
    }

    /// One persistent OS thread per worker; each thread owns its gradient
    /// source *and* its protocol worker half.
    pub fn threaded(
        sources: Vec<Box<dyn GradSource + Send>>,
        algos: Vec<Box<dyn WorkerAlgo>>,
    ) -> Result<Self> {
        ensure!(
            sources.len() == algos.len(),
            "pool mismatch: {} sources vs {} worker algos",
            sources.len(),
            algos.len()
        );
        let handles = sources
            .into_iter()
            .zip(algos)
            .enumerate()
            .map(|(wid, (mut src, mut algo))| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let (rep_tx, rep_rx) = channel::<Result<WorkerRound>>();
                let join = std::thread::Builder::new()
                    .name(format!("worker-{wid}"))
                    .spawn(move || {
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Cmd::Round { theta, ctx } => {
                                    let reply = worker_round(
                                        src.as_mut(),
                                        algo.as_mut(),
                                        &theta,
                                        &ctx,
                                    );
                                    if rep_tx.send(reply).is_err() {
                                        break;
                                    }
                                }
                                Cmd::Stop => break,
                            }
                        }
                    })
                    .expect("spawn worker thread");
                WorkerHandle { tx: cmd_tx, rx: rep_rx, join: Some(join) }
            })
            .collect();
        Ok(WorkerPool { backend: Backend::Threaded(handles) })
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Sequential(v) => v.len(),
            Backend::Threaded(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_threaded(&self) -> bool {
        matches!(self.backend, Backend::Threaded(_))
    }

    /// Run every worker's full round (gradient + EF + compress + encode)
    /// at θ; results are ordered by worker id in both backends.
    pub fn run_round(&mut self, theta: &[f32], ctx: &RoundCtx) -> Result<Vec<WorkerRound>> {
        match &mut self.backend {
            Backend::Sequential(workers) => workers
                .iter_mut()
                .map(|w| worker_round(w.src.as_mut(), w.algo.as_mut(), theta, ctx))
                .collect(),
            Backend::Threaded(handles) => {
                let shared = Arc::new(theta.to_vec());
                for h in handles.iter() {
                    h.tx
                        .send(Cmd::Round { theta: Arc::clone(&shared), ctx: *ctx })
                        .map_err(|_| anyhow!("worker thread died"))?;
                }
                // Drain every worker's reply before surfacing any error:
                // a short-circuit would leave this round's remaining
                // replies queued and silently deliver them next round.
                let mut replies = Vec::with_capacity(handles.len());
                for h in handles.iter() {
                    replies.push(
                        h.rx.recv().map_err(|_| anyhow!("worker thread died"))?,
                    );
                }
                replies.into_iter().collect()
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Backend::Threaded(handles) = &mut self.backend {
            for h in handles.iter() {
                let _ = h.tx.send(Cmd::Stop);
            }
            for h in handles.iter_mut() {
                if let Some(j) = h.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AlgoSpec;
    use crate::grad::quadratic::QuadraticProblem;

    fn sources(n: usize) -> Vec<Box<dyn GradSource + Send>> {
        let p = QuadraticProblem::new(1, 16, n, 4.0, 0.5, 1.0);
        (0..n)
            .map(|w| Box::new(p.source_for(w, 7)) as Box<dyn GradSource + Send>)
            .collect()
    }

    fn algos(n: usize, spec: &str) -> Vec<Box<dyn WorkerAlgo>> {
        AlgoSpec::parse(spec).unwrap().build(16, n, 100).0
    }

    #[test]
    fn threaded_equals_sequential_full_pipeline() {
        // Identical (loss, payload, bits) per worker per round — the whole
        // worker pipeline, not just the gradient, is deterministic.
        for spec in ["dist-sgd", "comp-ams-topk:0.2", "comp-ams-blocksign:8"] {
            let seq_sources: Vec<Box<dyn GradSource>> = sources(4)
                .into_iter()
                .map(|b| b as Box<dyn GradSource>)
                .collect();
            let mut seq = WorkerPool::sequential(seq_sources, algos(4, spec)).unwrap();
            let mut thr = WorkerPool::threaded(sources(4), algos(4, spec)).unwrap();
            let theta = vec![0.2f32; 16];
            for round in 0..5 {
                let ctx = RoundCtx { round, lr: 0.01 };
                let a = seq.run_round(&theta, &ctx).unwrap();
                let b = thr.run_round(&theta, &ctx).unwrap();
                for (ra, rb) in a.iter().zip(&b) {
                    assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{spec}");
                    assert_eq!(ra.payload, rb.payload, "{spec}");
                    assert_eq!(ra.uplink_bits, rb.uplink_bits, "{spec}");
                }
            }
        }
    }

    #[test]
    fn uplink_bits_match_payload_encoding() {
        let seq_sources: Vec<Box<dyn GradSource>> = sources(2)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        let mut pool =
            WorkerPool::sequential(seq_sources, algos(2, "comp-ams-topk:0.2")).unwrap();
        let theta = vec![0.1f32; 16];
        let ctx = RoundCtx { round: 0, lr: 0.01 };
        for r in pool.run_round(&theta, &ctx).unwrap() {
            assert_eq!(r.uplink_bits, r.payload.wire_bits());
            assert_eq!(r.uplink_bits, r.payload.encode().len() as u64 * 8);
        }
    }

    #[test]
    fn pool_reports_len_and_backend() {
        let thr = WorkerPool::threaded(sources(3), algos(3, "dist-sgd")).unwrap();
        assert_eq!(thr.len(), 3);
        assert!(!thr.is_empty());
        assert!(thr.is_threaded());
    }

    #[test]
    fn mismatched_sources_and_algos_rejected() {
        let seq_sources: Vec<Box<dyn GradSource>> = sources(2)
            .into_iter()
            .map(|b| b as Box<dyn GradSource>)
            .collect();
        assert!(WorkerPool::sequential(seq_sources, algos(3, "dist-sgd")).is_err());
    }
}
