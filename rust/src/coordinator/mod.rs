//! The L3 coordinator: the event-driven cluster runtime, transports,
//! communication accounting, metrics, and the training driver.
//!
//! One round of the paper's Algorithm 2, with the protocol split into its
//! worker and server halves and the leader running an event loop instead
//! of a lockstep barrier:
//!
//! ```text
//!   leader ──θ_t──▶ idle workers (downlink envelopes, charged per
//!                    dispatched worker — stragglers are skipped)
//!   worker i: g_i  = ∇f_i(θ_t; batch_i)        [grad::GradSource]
//!             msg_i = worker_algo_i.process(g_i) [EF + compression]
//!             bits_i = msg_i.wire_bits()          [uplink accounting]
//!   workers ──Event::Uplink{wid, round, envelope}──▶ leader (arrival order)
//!   leader: once K uplinks for round t are in ([`runtime`]):
//!           server_algo.step(θ, fresh + stale msgs)  [AMSGrad on the server]
//!           (sharded: msg slices routed to S parallel θ-shard servers)
//! ```
//!
//! The leader↔worker plumbing is abstracted behind [`transport::Transport`]
//! (`InProc` channels, the byte-framing `Loopback`, or real worker
//! *processes* over sockets — [`net::Tcp`], spawned and reaped by the
//! [`supervisor`], each running the [`worker`] daemon loop; either
//! in-process transport can additionally be wrapped in the seeded
//! network simulator [`sim::Sim`], `--transport sim:<inner>`), and the
//! round state machine — quorum collection, staleness classification,
//! stale-gradient application, dead-worker exclusion — lives in
//! [`runtime::ClusterRuntime`]. The whole per-worker pipeline
//! runs either sequentially on the leader thread (required for PJRT
//! executables), inside persistent worker threads ([`cluster`]), or in
//! separate worker processes (`--transport tcp --spawn-workers`); the
//! server update can likewise be split across parallel θ shards
//! ([`crate::algo::sharded::ShardedServer`], `--server-shards`). Under the
//! default full quorum (K = n) every backend × transport combination
//! produces bit-identical trajectories (each worker owns a seeded RNG
//! stream; server state is per-coordinate), which the integration and
//! property tests assert across all protocols.

pub mod cluster;
pub mod checkpoint;
pub mod comm;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod supervisor;
pub mod trainer;
pub mod transport;
pub mod tree;
pub mod worker;

pub use cluster::{WorkerPool, WorkerRound};
pub use comm::CommLedger;
pub use metrics::{RoundMetric, RunResult};
pub use checkpoint::JobCheckpoint;
pub use net::{Tcp, TcpLeader};
pub use runtime::{ClusterRuntime, RoundOutcome};
pub use scheduler::{Job, JobId, JobQueue, JobState, Scheduler};
pub use sim::{LinkStats, Sim, SimProfile};
pub use supervisor::Supervisor;
pub use trainer::{train, Trainer};
pub use transport::{Envelope, Event, InProc, Loopback, Transport, TransportSpec};
pub use tree::{parse_tree_kill, Topology, TreeHandle, TreeTransport, TOPOLOGY_CHOICES};
