//! The L3 coordinator: synchronous leader/worker rounds, communication
//! accounting, metrics, and the training driver.
//!
//! One round of the paper's Algorithm 2, with the protocol split into its
//! worker and server halves:
//!
//! ```text
//!   leader ──θ_t──▶ workers (downlink: n dense broadcasts, charged)
//!   worker i: g_i  = ∇f_i(θ_t; batch_i)        [grad::GradSource]
//!             msg_i = worker_algo_i.process(g_i) [EF + compression]
//!             bits_i = msg_i.wire_bits()          [uplink accounting]
//!   workers ──(loss_i, msg_i, bits_i)──▶ leader
//!   leader: server_algo.step(θ, msgs)           [AMSGrad on the server]
//!           (sharded: msg slices routed to S parallel θ-shard servers)
//! ```
//!
//! The whole per-worker pipeline — gradient, error feedback, compression,
//! wire encoding — runs either sequentially on the leader thread
//! (required for PJRT executables) or inside persistent worker threads
//! ([`cluster`]), each of which owns its worker's
//! [`WorkerAlgo`](crate::algo::WorkerAlgo) state. The server update can
//! likewise be split across parallel θ shards
//! ([`crate::algo::sharded::ShardedServer`], `--server-shards`). All
//! backend combinations produce bit-identical trajectories (each worker
//! owns a seeded RNG stream; server state is per-coordinate), which the
//! integration and property tests assert across all protocols.

pub mod cluster;
pub mod checkpoint;
pub mod comm;
pub mod metrics;
pub mod trainer;

pub use cluster::{WorkerPool, WorkerRound};
pub use comm::CommLedger;
pub use metrics::{RoundMetric, RunResult};
pub use trainer::{train, Trainer};
