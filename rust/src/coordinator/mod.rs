//! The L3 coordinator: synchronous leader/worker rounds, communication
//! accounting, metrics, and the training driver.
//!
//! One round of the paper's Algorithm 2:
//!
//! ```text
//!   leader ──θ_t──▶ workers (downlink: n dense broadcasts, charged)
//!   worker i: g_i = ∇f_i(θ_t; batch_i)        [grad::GradSource]
//!             msg_i = algo.worker_msg(g_i)    [compression + EF]
//!   workers ──msg_i──▶ leader (uplink: exact wire bits, charged)
//!   leader: algo.server_step(θ, msgs)         [AMSGrad on the server]
//! ```
//!
//! Gradient computation — the dominant cost — runs either sequentially on
//! the leader thread (required for PJRT executables) or on persistent
//! worker threads ([`cluster`]). Both produce bit-identical trajectories
//! (each worker owns a seeded RNG stream), which the integration tests
//! assert.

pub mod cluster;
pub mod checkpoint;
pub mod comm;
pub mod metrics;
pub mod trainer;

pub use comm::CommLedger;
pub use metrics::{RoundMetric, RunResult};
pub use trainer::{train, Trainer};
