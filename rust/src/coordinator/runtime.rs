//! Event-driven cluster runtime: quorum rounds over a [`Transport`].
//!
//! The lockstep call graph (`Trainer::step` → `WorkerPool::run_round` →
//! `ServerAlgo::step`) blocked the leader on the *slowest* worker every
//! round, which is exactly the regime where COMP-AMS's linear-speedup
//! claim (paper Thm. 4.2) stops being realizable. [`ClusterRuntime`]
//! replaces it with a message-driven round state machine:
//!
//! ```text
//!   round t:
//!     dispatch  θ_t → every idle worker          (downlink, charged per
//!                                                 dispatched worker)
//!     collect   Event::Uplink{wid, round, msg}   (arrival order) until
//!               K uplinks tagged `round == t` have arrived
//!     classify  each arrival by staleness s = t − msg.round():
//!                 s == 0                 fresh   → applied
//!                 0 < s ≤ max_staleness  stale   → applied, counted
//!                 s > max_staleness      dropped → counted, not applied
//!     step      server.step(θ, applied, ctx)     with ctx.observed_round
//!                                                 = oldest applied round
//! ```
//!
//! **Partial participation** (`--quorum K`, K < n): the server steps as
//! soon as K on-time uplinks are in; the other workers keep computing and
//! their uplinks arrive in later rounds as *stale gradients*. A worker
//! whose uplink has not been consumed yet is a straggler: it is not
//! re-dispatched (and not billed a θ downlink) until its outstanding
//! round arrives. When fewer than K workers were dispatched (the rest are
//! stragglers mid-flight), the round's quorum is the dispatched count —
//! the liveness floor that keeps in-process transports deadlock-free.
//!
//! **Worker death** (multi-process transports only): a worker whose
//! connection drops ([`Event::Exit`], or a failed downlink write) is a
//! *dead straggler* — not dispatched again, any uplink it still owed
//! counted in `dropped_uplinks`, and the collect loop's target shrinks
//! so the quorum keeps stepping on the survivors. The run only errors
//! once no live worker is left to dispatch. Death also zeroes the
//! worker's error-feedback accumulator (it lived in the dead process):
//! the runtime charges that loss to `CommLedger::{ef_resets,
//! ef_residual_lost_bits}` (sized by [`ClusterRuntime::set_ef_state_bits`])
//! so the dropped gradient mass is reported, not hidden.
//!
//! **Rejoin**: death is not permanent. While any wid is dead, each
//! dispatch first offers the transport a [`Transport::try_rejoin`] —
//! on socket transports a replacement process that HELLO'd the leader's
//! listen socket is re-ASSIGNed the dead wid — and every revived wid is
//! flipped live again (`CommLedger::rejoins`), restoring the quorum
//! target on this very dispatch. The replacement starts from the
//! current θ (next downlink) with a fresh EF accumulator.
//!
//! **Synchronous mode is the default and is bitwise-exact**: with K = n
//! every round dispatches all n workers, waits for all n uplinks, orders
//! them by worker id, and steps once — the numerically identical
//! computation (same summation order, same `1/n` loss weighting, same
//! ledger charges) the lockstep trainer performed, across both worker
//! backends and both transports (asserted by the transport/quorum
//! property test).
//!
//! The round train-loss is averaged over the uplinks that actually
//! arrived this round (`Σ loss_i / arrivals`), not divided by a fixed n —
//! under partial participation a `/ n` mean would silently mis-weight the
//! rounds where stragglers sat out.
//!
//! [`Transport`]: super::transport::Transport

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::algo::{RoundCtx, ServerAlgo};
use crate::compress::PayloadView;
use crate::util::timer::Stopwatch;

use super::comm::CommLedger;
use super::transport::{Event, Transport, UplinkMsg};

/// What one runtime round produced, for the metrics stream.
#[derive(Clone, Copy, Debug)]
pub struct RoundOutcome {
    pub round: u64,
    /// Mean worker train loss over the uplinks that arrived this round.
    pub train_loss: f32,
    /// On-time uplinks applied (the quorum).
    pub fresh: usize,
    /// Straggler uplinks applied as stale gradients this round.
    pub stale: usize,
    /// Straggler uplinks past `max_staleness`, dropped unapplied.
    pub dropped: usize,
    /// Wall-clock ms from first dispatch until the quorum was collected
    /// (the worker-side share of the round).
    pub worker_ms: f64,
}

/// The leader's event loop: owns the transport and the per-worker
/// in-flight state, drives one quorum round at a time.
pub struct ClusterRuntime {
    transport: Box<dyn Transport>,
    /// Resolved quorum K, 1 ..= n.
    quorum: usize,
    /// Maximum staleness (in rounds) at which a straggler uplink is still
    /// applied; beyond it the uplink is dropped (and accounted).
    max_staleness: u64,
    /// `in_flight[wid]` = the round whose uplink we still owe this worker
    /// credit for (`None` = idle, eligible for dispatch).
    in_flight: Vec<Option<u64>>,
    /// Workers whose process/connection is gone — dead stragglers:
    /// skipped at dispatch, excluded from quorum targets, revivable via
    /// [`Transport::try_rejoin`].
    dead: Vec<bool>,
    /// Per-worker error-feedback accumulator size in bits
    /// ([`AlgoSpec::ef_state_bits`](crate::algo::AlgoSpec::ef_state_bits));
    /// charged to the ledger when a worker dies with live EF state. Zero
    /// (the default) for EF-free protocols.
    ef_state_bits: u64,
    /// Set when a round or drain errored mid-collection: the in-flight
    /// bookkeeping can no longer be trusted (e.g. a worker's errored
    /// reply was consumed without clearing its slot), so further rounds
    /// would mis-dispatch or deadlock. All entry points refuse to run.
    poisoned: bool,
}

impl ClusterRuntime {
    /// `quorum` = 0 means full participation (K = n).
    pub fn new(
        transport: Box<dyn Transport>,
        quorum: usize,
        max_staleness: u64,
    ) -> Result<ClusterRuntime> {
        let n = transport.n_workers();
        ensure!(n > 0, "runtime needs at least one worker");
        let quorum = if quorum == 0 { n } else { quorum };
        ensure!(
            quorum <= n,
            "quorum {quorum} exceeds worker count {n}"
        );
        Ok(ClusterRuntime {
            transport,
            quorum,
            max_staleness,
            in_flight: vec![None; n],
            dead: vec![false; n],
            ef_state_bits: 0,
            poisoned: false,
        })
    }

    /// Declare how many bits of error-feedback state each worker holds
    /// (see [`AlgoSpec::ef_state_bits`](crate::algo::AlgoSpec::ef_state_bits)),
    /// so worker deaths charge the lost residual to
    /// [`CommLedger::ef_residual_lost_bits`]. Leave at 0 for EF-free
    /// protocols.
    pub fn set_ef_state_bits(&mut self, bits: u64) {
        self.ef_state_bits = bits;
    }

    /// Centralized death transition: flip `dead[wid]` and — exactly once
    /// per death — account the EF accumulator that died with the process.
    fn mark_dead(&mut self, wid: usize, ledger: &mut CommLedger) {
        if self.dead[wid] {
            return;
        }
        self.dead[wid] = true;
        if self.ef_state_bits > 0 {
            ledger.ef_resets += 1;
            ledger.ef_residual_lost_bits += self.ef_state_bits;
        }
    }

    pub fn n_workers(&self) -> usize {
        self.transport.n_workers()
    }

    /// Per-link delivery statistics from the transport — populated only
    /// when the seeded network simulator is in the stack
    /// ([`Sim`](super::sim::Sim)); empty otherwise. The trainer mirrors
    /// these into [`CommLedger::sim_links`] after every round, the same
    /// way sharded-server routing is mirrored.
    pub fn link_stats(&self) -> Vec<super::sim::LinkStats> {
        self.transport.link_stats()
    }

    pub fn quorum(&self) -> usize {
        self.quorum
    }

    /// Worker ids whose process/connection is gone (dead stragglers,
    /// until a replacement rejoins). Empty for in-process transports.
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.dead.len()).filter(|&w| self.dead[w]).collect()
    }

    /// Worker ids with an uplink still in flight (useful between rounds
    /// for ops introspection and fault-injection tests).
    pub fn straggling_workers(&self) -> Vec<usize> {
        (0..self.in_flight.len())
            .filter(|&w| self.in_flight[w].is_some())
            .collect()
    }

    /// Broadcast end-of-run to the cluster (SHUTDOWN frames on socket
    /// transports; no-op in process). Deliberately allowed on a poisoned
    /// runtime — child processes must still be told to exit.
    pub fn shutdown(&mut self) -> Result<()> {
        self.transport.shutdown()
    }

    /// Drive one round of the state machine (dispatch → collect →
    /// classify → server step), mutating θ in place and charging the
    /// ledger. `round`/`lr` come from the schedule; `server` applies the
    /// aggregated batch.
    ///
    /// An `Err` poisons the runtime: the in-flight bookkeeping may have
    /// lost a consumed (errored) uplink, so later rounds would silently
    /// exclude that worker or block forever waiting for it — callers
    /// that catch a round error must rebuild the runtime, and every
    /// subsequent call here fails fast instead.
    pub fn run_round(
        &mut self,
        theta: &mut [f32],
        server: &mut dyn ServerAlgo,
        round: u64,
        lr: f32,
        ledger: &mut CommLedger,
    ) -> Result<RoundOutcome> {
        ensure!(
            !self.poisoned,
            "cluster runtime poisoned by an earlier round error; rebuild the Trainer"
        );
        let out = self.run_round_inner(theta, server, round, lr, ledger);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn run_round_inner(
        &mut self,
        theta: &mut [f32],
        server: &mut dyn ServerAlgo,
        round: u64,
        lr: f32,
        ledger: &mut CommLedger,
    ) -> Result<RoundOutcome> {
        let n = self.n_workers();
        let ctx = RoundCtx::sync(round, lr);
        let wsw = Stopwatch::start();

        // Rejoin: while any wid is dead, offer the transport a chance to
        // re-admit replacements before dispatching — a revived wid gets
        // this very round's downlink, so the quorum target recovers
        // immediately. (A dead wid never has an uplink in flight: both
        // death paths below clear or never set its slot.)
        if self.dead.iter().any(|&d| d) {
            for wid in self.transport.try_rejoin()? {
                ensure!(wid < n, "transport rejoined unknown worker {wid}");
                if self.dead[wid] && self.in_flight[wid].is_none() {
                    self.dead[wid] = false;
                    ledger.rejoins += 1;
                }
            }
        }

        // Dispatch: θ goes to every live idle worker; stragglers still
        // owe an uplink and are skipped (and not billed a broadcast); a
        // failed downlink write means the worker process died under us —
        // mark it dead rather than dispatched.
        let shared = Arc::new(theta.to_vec());
        let mut dispatched = 0usize;
        for wid in 0..n {
            if self.dead[wid] || self.in_flight[wid].is_some() {
                continue;
            }
            if self.transport.send_downlink(wid, &shared, &ctx)? {
                self.in_flight[wid] = Some(round);
                dispatched += 1;
            } else {
                self.mark_dead(wid, ledger);
            }
        }
        ensure!(
            dispatched > 0,
            "round {round}: no live idle worker to dispatch ({} of {n} workers dead)",
            self.dead.iter().filter(|&&d| d).count()
        );
        ledger.charge_downlink(
            self.transport.downlink_wire_bits(theta.len()),
            dispatched,
        );
        ledger.charge_framing(dispatched as u64 * self.transport.frame_overhead_bits());

        // Collect: consume arrivals until K uplinks for *this* round are
        // in. Only `dispatched` workers can produce round-t uplinks, so
        // the quorum is floored at the dispatched count for liveness —
        // and shrinks further as dispatched workers die (`pending` is how
        // many round-t uplinks can still arrive).
        let target = self.quorum.min(dispatched);
        let mut pending = dispatched;
        let mut arrivals: Vec<Arrival> = Vec::with_capacity(dispatched);
        let mut fresh = 0usize;
        while fresh < target && pending > 0 {
            match self.transport.recv_event()? {
                Event::Uplink { wid, round: observed, msg } => {
                    ensure!(wid < n, "uplink from unknown worker {wid}");
                    ensure!(
                        msg.wid() as usize == wid && msg.round() == observed,
                        "transport event (wid {wid}, round {observed}) disagrees with its \
                         envelope header (wid {}, round {})",
                        msg.wid(),
                        msg.round()
                    );
                    ensure!(
                        self.in_flight[wid] == Some(observed),
                        "worker {wid} uplinked round {observed} but owes {:?}",
                        self.in_flight[wid]
                    );
                    self.in_flight[wid] = None;
                    if observed == round {
                        fresh += 1;
                        pending -= 1;
                    }
                    ledger.charge_framing(self.transport.frame_overhead_bits());
                    arrivals.push(Arrival { wid, observed, loss: msg.loss(), msg });
                }
                Event::Exit { wid } => {
                    ensure!(wid < n, "exit event from unknown worker {wid}");
                    if !self.dead[wid] {
                        self.mark_dead(wid, ledger);
                        if let Some(owed) = self.in_flight[wid].take() {
                            // The uplink this worker owed will never
                            // arrive: account the absence.
                            ledger.dropped_uplinks += 1;
                            if owed == round {
                                pending -= 1;
                            }
                        }
                    }
                }
            }
        }
        ensure!(
            !arrivals.is_empty(),
            "round {round}: every dispatched worker died before uplinking"
        );
        let worker_ms = wsw.ms();

        // Classify in worker-id order (a deterministic aggregation order;
        // with K = n this is exactly the lockstep summation).
        arrivals.sort_by_key(|a| a.wid);
        let count = arrivals.len() as f32;
        let mut train_loss = 0.0f32;
        let mut applied: Vec<UplinkMsg> = Vec::with_capacity(arrivals.len());
        let mut observed_round = round;
        let mut stale = 0usize;
        let mut dropped = 0usize;
        for a in arrivals {
            train_loss += a.loss / count;
            ledger.charge_uplink(a.wid, a.msg.payload_wire_bits());
            let staleness = round - a.observed;
            if staleness == 0 {
                applied.push(a.msg);
            } else if staleness <= self.max_staleness {
                stale += 1;
                observed_round = observed_round.min(a.observed);
                applied.push(a.msg);
            } else {
                dropped += 1;
            }
        }
        ledger.stale_uplinks += stale as u64;
        ledger.dropped_uplinks += dropped as u64;

        // Step: one server update over the applied batch; protocols see
        // the batch's staleness through ctx.observed_round. The batch can
        // be empty when worker deaths left only past-staleness arrivals
        // this round — then θ simply doesn't move (a 0-message "average"
        // would be 0/0). Frame-backed uplinks reach the server as
        // borrowed views straight into the received bytes (zero-copy).
        if !applied.is_empty() {
            let step_ctx = RoundCtx { round, observed_round, lr };
            let views: Vec<PayloadView<'_>> =
                applied.iter().map(|m| m.payload()).collect();
            server.step(theta, &views, &step_ctx)?;
        }

        Ok(RoundOutcome {
            round,
            train_loss,
            fresh,
            stale,
            dropped,
            worker_ms,
        })
    }

    /// Consume every still-in-flight uplink. Called once after the last
    /// round: under K < n the final rounds leave up to n − K straggler
    /// uplinks in the transport, and those messages were *transmitted*
    /// even though no round will ever apply them — so their wire bits are
    /// charged to the ledger (they are not classified as stale/dropped,
    /// which are per-round application counters). No-op at K = n.
    /// Returns how many uplinks were drained. Fails fast on a poisoned
    /// runtime (see [`ClusterRuntime::run_round`]) — the threaded
    /// backend would otherwise block forever on an uplink that was
    /// already consumed as an error.
    pub fn drain_in_flight(&mut self, ledger: &mut CommLedger) -> Result<usize> {
        ensure!(
            !self.poisoned,
            "cluster runtime poisoned by an earlier round error; rebuild the Trainer"
        );
        let out = self.drain_inner(ledger);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn drain_inner(&mut self, ledger: &mut CommLedger) -> Result<usize> {
        let mut drained = 0usize;
        while self.in_flight.iter().any(Option::is_some) {
            match self.transport.recv_event()? {
                Event::Uplink { wid, round: observed, msg } => {
                    ensure!(
                        wid < self.in_flight.len(),
                        "uplink from unknown worker {wid}"
                    );
                    ensure!(
                        self.in_flight[wid] == Some(observed),
                        "worker {wid} uplinked round {observed} but owes {:?}",
                        self.in_flight[wid]
                    );
                    self.in_flight[wid] = None;
                    ledger.charge_uplink(wid, msg.payload_wire_bits());
                    ledger.charge_framing(self.transport.frame_overhead_bits());
                    drained += 1;
                }
                Event::Exit { wid } => {
                    ensure!(
                        wid < self.in_flight.len(),
                        "exit event from unknown worker {wid}"
                    );
                    if !self.dead[wid] {
                        self.mark_dead(wid, ledger);
                        if self.in_flight[wid].take().is_some() {
                            // Never transmitted: accounted as dropped, no
                            // wire bits charged.
                            ledger.dropped_uplinks += 1;
                        }
                    }
                }
            }
        }
        Ok(drained)
    }

    /// Release the workers from this runtime's job without terminating
    /// them, collecting each worker's suspend blob when `want_state` —
    /// the transport-level half of [`Trainer::suspend`](super::trainer::Trainer::suspend).
    /// Requires a clean runtime with no uplinks in flight (call
    /// [`ClusterRuntime::drain_in_flight`] first); after a detach the
    /// runtime is spent and no further rounds can run.
    pub fn detach_workers(&mut self, want_state: bool) -> Result<Vec<Option<Vec<u8>>>> {
        ensure!(
            !self.poisoned,
            "cluster runtime poisoned by an earlier round error; rebuild the Trainer"
        );
        ensure!(
            self.in_flight.iter().all(Option::is_none),
            "detach with {} uplinks still in flight; drain first",
            self.in_flight.iter().filter(|f| f.is_some()).count()
        );
        self.transport.detach(want_state)
    }
}

/// An arrival after header validation (flattened [`Event::Uplink`]).
struct Arrival {
    wid: usize,
    observed: u64,
    loss: f32,
    msg: UplinkMsg,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AlgoSpec;
    use crate::compress::Payload;
    use crate::coordinator::cluster::WorkerPool;
    use crate::coordinator::transport::{InProc, Loopback};
    use crate::grad::quadratic::QuadraticProblem;
    use crate::grad::GradSource;

    fn runtime(
        n: usize,
        algo: &str,
        quorum: usize,
        max_staleness: u64,
        loopback: bool,
    ) -> (ClusterRuntime, Box<dyn ServerAlgo>) {
        let problem = QuadraticProblem::new(1, 16, n, 4.0, 0.5, 1.0);
        let sources: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| Box::new(problem.source_for(w, 7)) as Box<dyn GradSource>)
            .collect();
        let (workers, server) = AlgoSpec::parse(algo).unwrap().build(16, n, 1000);
        let pool = WorkerPool::sequential(sources, workers).unwrap();
        let transport: Box<dyn Transport> = if loopback {
            Box::new(Loopback::new(pool))
        } else {
            Box::new(InProc::new(pool))
        };
        (ClusterRuntime::new(transport, quorum, max_staleness).unwrap(), server)
    }

    #[test]
    fn zero_quorum_resolves_to_full_participation() {
        let (rt, _) = runtime(4, "dist-sgd", 0, 2, false);
        assert_eq!(rt.quorum(), 4);
        assert_eq!(rt.n_workers(), 4);
        let problem = QuadraticProblem::new(1, 16, 2, 4.0, 0.5, 1.0);
        let sources: Vec<Box<dyn GradSource>> = (0..2)
            .map(|w| Box::new(problem.source_for(w, 7)) as Box<dyn GradSource>)
            .collect();
        let (workers, _) = AlgoSpec::parse("dist-sgd").unwrap().build(16, 2, 10);
        let pool = WorkerPool::sequential(sources, workers).unwrap();
        assert!(ClusterRuntime::new(Box::new(InProc::new(pool)), 3, 0).is_err());
    }

    #[test]
    fn full_quorum_round_applies_all_workers_fresh() {
        let (mut rt, mut server) = runtime(3, "dist-sgd", 0, 2, false);
        let mut theta = vec![0.5f32; 16];
        let mut ledger = CommLedger::new();
        for r in 0..5 {
            let out = rt
                .run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger)
                .unwrap();
            assert_eq!(out.fresh, 3);
            assert_eq!(out.stale, 0);
            assert_eq!(out.dropped, 0);
            assert!(out.train_loss.is_finite());
        }
        assert_eq!(ledger.stale_uplinks, 0);
        assert_eq!(ledger.dropped_uplinks, 0);
        assert_eq!(ledger.uplink_msgs, 15);
        // Downlink billed to all 3 workers each of the 5 rounds.
        assert_eq!(ledger.downlink_bits, 5 * 3 * 8 * (5 + 4 * 16));
    }

    #[test]
    fn partial_quorum_alternates_stale_application() {
        // n=4, K=2, sequential transport: round 0 applies workers {0,1}
        // fresh; round 1 consumes {2,3}'s round-0 uplinks as stale plus
        // {0,1} fresh; round 2 starts the cycle over.
        let (mut rt, mut server) = runtime(4, "dist-sgd", 2, 2, false);
        let mut theta = vec![0.5f32; 16];
        let mut ledger = CommLedger::new();

        let out0 = rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        assert_eq!((out0.fresh, out0.stale, out0.dropped), (2, 0, 0));
        // Round 0 dispatched all 4 (everyone idle), billed 4 broadcasts.
        assert_eq!(ledger.downlink_bits, 4 * 8 * (5 + 4 * 16));

        let out1 = rt.run_round(&mut theta, server.as_mut(), 1, 0.01, &mut ledger).unwrap();
        assert_eq!((out1.fresh, out1.stale, out1.dropped), (2, 2, 0));
        // Round 1 dispatched only the 2 idle workers — stragglers are not
        // billed a broadcast for the round they sat out.
        assert_eq!(ledger.downlink_bits, (4 + 2) * 8 * (5 + 4 * 16));

        let out2 = rt.run_round(&mut theta, server.as_mut(), 2, 0.01, &mut ledger).unwrap();
        assert_eq!((out2.fresh, out2.stale, out2.dropped), (2, 0, 0));

        assert_eq!(ledger.stale_uplinks, 2);
        assert_eq!(ledger.dropped_uplinks, 0);
        // Every consumed uplink is charged, stale or not.
        assert_eq!(ledger.uplink_msgs, 2 + 4 + 2);
    }

    #[test]
    fn staleness_bound_drops_and_accounts() {
        // max_staleness = 0: the round-1 stale pair is dropped, not applied.
        let (mut rt, mut server) = runtime(4, "dist-sgd", 2, 0, false);
        let mut theta = vec![0.5f32; 16];
        let mut ledger = CommLedger::new();
        rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        let out1 = rt.run_round(&mut theta, server.as_mut(), 1, 0.01, &mut ledger).unwrap();
        assert_eq!((out1.fresh, out1.stale, out1.dropped), (2, 0, 2));
        assert_eq!(ledger.dropped_uplinks, 2);
        assert_eq!(ledger.stale_uplinks, 0);
        // Dropped uplinks were still transmitted: their bits are charged
        // and their losses entered the round mean (4 arrivals).
        assert_eq!(ledger.uplink_msgs, 6);
    }

    #[test]
    fn round_error_poisons_the_runtime() {
        // A worker that errors mid-round consumes its uplink slot as an
        // Err, so the in-flight bookkeeping is no longer trustworthy:
        // the runtime must refuse further rounds and drains instead of
        // mis-dispatching or blocking.
        struct FailingSource {
            fail_from: u64,
        }
        impl GradSource for FailingSource {
            fn dim(&self) -> usize {
                8
            }
            fn grad(&mut self, theta: &[f32], round: u64) -> anyhow::Result<(f32, Vec<f32>)> {
                anyhow::ensure!(round < self.fail_from, "synthetic worker failure");
                Ok((0.0, vec![0.1f32; theta.len()]))
            }
        }
        let sources: Vec<Box<dyn GradSource>> = (0..2)
            .map(|_| Box::new(FailingSource { fail_from: 1 }) as Box<dyn GradSource>)
            .collect();
        let (workers, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(8, 2, 10);
        let pool = WorkerPool::sequential(sources, workers).unwrap();
        let mut rt =
            ClusterRuntime::new(Box::new(InProc::new(pool)), 0, 2).unwrap();
        let mut theta = vec![0.5f32; 8];
        let mut ledger = CommLedger::new();
        rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        // Round 1 fails inside a worker...
        assert!(rt.run_round(&mut theta, server.as_mut(), 1, 0.01, &mut ledger).is_err());
        // ...after which every entry point fails fast instead of running
        // with corrupted in-flight state.
        let err = rt
            .run_round(&mut theta, server.as_mut(), 2, 0.01, &mut ledger)
            .unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert!(rt.drain_in_flight(&mut ledger).unwrap_err().to_string().contains("poisoned"));
    }

    #[test]
    fn drain_bills_end_of_run_stragglers() {
        // n=4, K=2: after round 0 two uplinks are still in flight; the
        // end-of-run drain consumes and charges them without touching
        // the stale/dropped classification counters.
        let (mut rt, mut server) = runtime(4, "dist-sgd", 2, 2, false);
        let mut theta = vec![0.5f32; 16];
        let mut ledger = CommLedger::new();
        rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        assert_eq!(ledger.uplink_msgs, 2);
        let drained = rt.drain_in_flight(&mut ledger).unwrap();
        assert_eq!(drained, 2);
        assert_eq!(ledger.uplink_msgs, 4);
        assert_eq!(ledger.uplink_bits_by_worker.len(), 4);
        assert!(ledger.uplink_bits_by_worker.iter().all(|&b| b > 0));
        assert_eq!(ledger.stale_uplinks, 0);
        assert_eq!(ledger.dropped_uplinks, 0);
        // Nothing left: draining again is a no-op.
        assert_eq!(rt.drain_in_flight(&mut ledger).unwrap(), 0);
    }

    /// Scripted in-process stand-in for a process-boundary transport:
    /// each dispatched worker "replies" instantly with a dense uplink —
    /// unless scripted to die at that round (dispatch succeeds, an
    /// `Event::Exit` lands instead of the uplink: the crashed-mid-round
    /// case) or to be already unreachable (send fails: the crashed-while-
    /// idle case).
    struct ScriptedTransport {
        n: usize,
        queue: std::collections::VecDeque<Event>,
        /// `Some(r)`: die on receiving the round-r (or later) downlink.
        die_at: Vec<Option<u64>>,
        /// Connection already gone: send_downlink returns Ok(false).
        unreachable: Vec<bool>,
        /// Replacement processes "knocking on the listen socket": wids
        /// pushed here (from test code, between rounds) are revived by
        /// the next `try_rejoin`. Shared so the test keeps a handle.
        rejoin_requests: std::sync::Arc<std::sync::Mutex<Vec<usize>>>,
    }

    impl ScriptedTransport {
        fn new(n: usize) -> Self {
            ScriptedTransport {
                n,
                queue: Default::default(),
                die_at: vec![None; n],
                unreachable: vec![false; n],
                rejoin_requests: Default::default(),
            }
        }
    }

    impl Transport for ScriptedTransport {
        fn n_workers(&self) -> usize {
            self.n
        }

        fn send_downlink(
            &mut self,
            wid: usize,
            theta: &Arc<Vec<f32>>,
            ctx: &RoundCtx,
        ) -> Result<bool> {
            if self.unreachable[wid] {
                return Ok(false);
            }
            if self.die_at[wid].is_some_and(|r| ctx.round >= r) {
                self.unreachable[wid] = true;
                self.queue.push_back(Event::Exit { wid });
                return Ok(true); // the downlink write itself succeeded
            }
            self.queue.push_back(Event::Uplink {
                wid,
                round: ctx.round,
                msg: UplinkMsg::from_payload(
                    wid as u32,
                    ctx.round,
                    1.0,
                    Payload::Dense(vec![0.1f32; theta.len()]),
                ),
            });
            Ok(true)
        }

        fn recv_event(&mut self) -> Result<Event> {
            self.queue
                .pop_front()
                .ok_or_else(|| anyhow::anyhow!("scripted transport drained dry"))
        }

        fn frame_overhead_bits(&self) -> u64 {
            200
        }

        fn try_rejoin(&mut self) -> Result<Vec<usize>> {
            let mut revived = Vec::new();
            for wid in self.rejoin_requests.lock().unwrap().drain(..) {
                // A fresh process replaces the dead one: reachable again,
                // and its crash script does not carry over.
                self.unreachable[wid] = false;
                self.die_at[wid] = None;
                revived.push(wid);
            }
            Ok(revived)
        }
    }

    #[test]
    fn mid_round_death_becomes_permanent_straggler() {
        let mut t = ScriptedTransport::new(3);
        t.die_at[2] = Some(2);
        let mut rt = ClusterRuntime::new(Box::new(t), 2, 2).unwrap();
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 3, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        for r in 0..6 {
            let out = rt
                .run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger)
                .unwrap_or_else(|e| panic!("round {r}: {e:#}"));
            assert!(out.fresh >= 1, "round {r} stepped on nothing");
        }
        assert_eq!(rt.dead_workers(), vec![2]);
        // Worker 2's round-2 uplink never arrived: dropped, no bits.
        assert_eq!(ledger.dropped_uplinks, 1);
        // From round 3 on, only workers 0 and 1 are dispatched or billed.
        assert_eq!(ledger.uplink_bits_by_worker.len(), 3);
        assert!(ledger.uplink_bits_by_worker[2] < ledger.uplink_bits_by_worker[0]);
        // Framing: 200 bits per dispatched downlink and consumed uplink.
        assert!(ledger.framing_bits > 0);
        // Nothing left in flight: the drain is a no-op.
        assert_eq!(rt.drain_in_flight(&mut ledger).unwrap(), 0);
        assert!(rt.straggling_workers().is_empty());
    }

    #[test]
    fn unreachable_worker_is_skipped_not_fatal() {
        let mut t = ScriptedTransport::new(2);
        t.unreachable[1] = true;
        let mut rt = ClusterRuntime::new(Box::new(t), 0, 2).unwrap();
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 2, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        let out = rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        // Full participation resolved to the one live worker.
        assert_eq!((out.fresh, out.stale, out.dropped), (1, 0, 0));
        assert_eq!(rt.dead_workers(), vec![1]);
        // It never received a dispatch, so nothing was owed or dropped.
        assert_eq!(ledger.dropped_uplinks, 0);
        // Downlink billed only for the worker actually dispatched.
        assert_eq!(ledger.downlink_bits, 8 * (5 + 4 * 4));
    }

    #[test]
    fn losing_every_worker_errors_and_poisons() {
        let mut t = ScriptedTransport::new(1);
        t.die_at[0] = Some(0);
        let mut rt = ClusterRuntime::new(Box::new(t), 0, 2).unwrap();
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 1, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        let err = rt
            .run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger)
            .unwrap_err();
        assert!(err.to_string().contains("died before uplinking"), "{err}");
        // And the next round fails fast on the poison flag.
        let err = rt
            .run_round(&mut theta, server.as_mut(), 1, 0.01, &mut ledger)
            .unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
    }

    #[test]
    fn drain_absorbs_exit_of_an_in_flight_worker() {
        // Worker 1 dies on its round-2 dispatch and the run stops right
        // there: its Exit is still queued when the drain runs — the
        // drain must clear the in-flight slot and count the drop instead
        // of blocking.
        let mut t = ScriptedTransport::new(2);
        t.die_at[1] = Some(1);
        let mut rt = ClusterRuntime::new(Box::new(t), 1, 2).unwrap();
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 2, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        for r in 0..3 {
            rt.run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger).unwrap();
        }
        let before = ledger.dropped_uplinks;
        let drained = rt.drain_in_flight(&mut ledger).unwrap();
        // Whatever was still owed is now resolved: either consumed as a
        // transmitted straggler (drained) or dropped at the Exit.
        assert!(rt.straggling_workers().is_empty());
        assert!(drained > 0 || ledger.dropped_uplinks > before);
        assert_eq!(rt.dead_workers(), vec![1]);
    }

    #[test]
    fn rejoin_revives_a_dead_worker_and_accounts_the_lost_ef_state() {
        // n=3, K=2: worker 2 dies on its round-2 dispatch, a replacement
        // knocks before round 5. The wid must come back into the
        // dispatch/quorum rotation, the death must charge the lost EF
        // accumulator exactly once, and dropped_uplinks must stop
        // growing after the rejoin.
        let mut t = ScriptedTransport::new(3);
        t.die_at[2] = Some(2);
        let knocking = t.rejoin_requests.clone();
        let mut rt = ClusterRuntime::new(Box::new(t), 2, 2).unwrap();
        rt.set_ef_state_bits(32 * 4);
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 3, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        for r in 0..5 {
            rt.run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger).unwrap();
        }
        assert_eq!(rt.dead_workers(), vec![2]);
        assert_eq!(ledger.ef_resets, 1);
        assert_eq!(ledger.ef_residual_lost_bits, 32 * 4);
        assert_eq!(ledger.dropped_uplinks, 1);
        let bits_at_death = ledger.uplink_bits_by_worker[2];

        knocking.lock().unwrap().push(2);
        for r in 5..10 {
            let out = rt
                .run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger)
                .unwrap();
            assert!(out.fresh >= 1);
        }
        assert!(rt.dead_workers().is_empty());
        assert_eq!(ledger.rejoins, 1);
        // The replacement is uplinking again...
        assert!(ledger.uplink_bits_by_worker[2] > bits_at_death);
        // ...and no further uplinks were dropped, nor EF charged again.
        assert_eq!(ledger.dropped_uplinks, 1);
        assert_eq!(ledger.ef_resets, 1);
        assert_eq!(ledger.ef_residual_lost_bits, 32 * 4);
    }

    #[test]
    fn ef_loss_is_charged_once_per_death_even_across_rejoin_cycles() {
        // Die → rejoin → die again: two distinct processes died holding
        // EF state, so two resets are charged; the rejoin itself charges
        // nothing.
        let mut t = ScriptedTransport::new(2);
        t.unreachable[1] = true;
        let knocking = t.rejoin_requests.clone();
        let mut rt = ClusterRuntime::new(Box::new(t), 1, 2).unwrap();
        rt.set_ef_state_bits(128);
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 2, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        rt.run_round(&mut theta, server.as_mut(), 0, 0.01, &mut ledger).unwrap();
        assert_eq!(rt.dead_workers(), vec![1]);
        assert_eq!(ledger.ef_resets, 1);

        knocking.lock().unwrap().push(1);
        rt.run_round(&mut theta, server.as_mut(), 1, 0.01, &mut ledger).unwrap();
        assert!(rt.dead_workers().is_empty());
        assert_eq!(ledger.rejoins, 1);
        assert_eq!(ledger.ef_resets, 1);

        // Second incarnation dies too (unreachable again from round 2).
        // We can't reach into the boxed transport, so script it via a
        // queued Exit: kill it right after its round-2 dispatch.
        // (die_at was cleared by the rejoin; use a fresh runtime check
        // instead — mark_dead is what's under test and Exit drives it.)
        rt.run_round(&mut theta, server.as_mut(), 2, 0.01, &mut ledger).unwrap();
        rt.mark_dead(1, &mut ledger);
        assert_eq!(ledger.ef_resets, 2);
        assert_eq!(ledger.ef_residual_lost_bits, 256);
        // Re-marking an already-dead wid must not double charge.
        rt.mark_dead(1, &mut ledger);
        assert_eq!(ledger.ef_resets, 2);
    }

    #[test]
    fn ef_free_protocols_charge_no_residual_loss_on_death() {
        let mut t = ScriptedTransport::new(2);
        t.die_at[1] = Some(0);
        let mut rt = ClusterRuntime::new(Box::new(t), 1, 2).unwrap();
        // ef_state_bits left at its 0 default (dist-sgd keeps no EF).
        let (_, mut server) = AlgoSpec::parse("dist-sgd").unwrap().build(4, 2, 100);
        let mut theta = vec![0.5f32; 4];
        let mut ledger = CommLedger::new();
        for r in 0..3 {
            rt.run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger).unwrap();
        }
        assert_eq!(rt.dead_workers(), vec![1]);
        assert_eq!(ledger.ef_resets, 0);
        assert_eq!(ledger.ef_residual_lost_bits, 0);
    }

    #[test]
    fn loopback_full_quorum_matches_inproc_bitwise() {
        let run = |loopback: bool| {
            let (mut rt, mut server) = runtime(3, "comp-ams-topk:0.3", 0, 2, loopback);
            let mut theta = vec![0.5f32; 16];
            let mut ledger = CommLedger::new();
            let mut losses = Vec::new();
            for r in 0..10 {
                losses.push(
                    rt.run_round(&mut theta, server.as_mut(), r, 0.01, &mut ledger)
                        .unwrap()
                        .train_loss,
                );
            }
            (losses, theta, ledger.uplink_bits)
        };
        let (la, ta, ba) = run(false);
        let (lb, tb, bb) = run(true);
        assert_eq!(ba, bb);
        for (a, b) in la.iter().zip(&lb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ta.iter().zip(&tb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
