//! The worker daemon: one remote worker process of a TCP cluster.
//!
//! `comp-ams worker --leader HOST:PORT` runs this loop. The daemon
//! connects to the leader, handshakes (HELLO → ASSIGN, which carries its
//! `wid`, an optional resume blob, and the full serialized
//! [`TrainConfig`]), rebuilds its gradient shard and protocol worker
//! half from exactly the constructors the in-process pool uses
//! ([`build_worker_parts`]), and then services rounds:
//!
//! ```text
//!   DOWNLINK frame → Envelope::decode → (θ, RoundCtx::sync(round, lr))
//!     → src.grad(θ) → algo.process(grad)            [the worker pipeline]
//!     → Envelope{wid, round, loss, payload} → UPLINK frame
//! ```
//!
//! The worker-side `RoundCtx` comes entirely off the wire — the same
//! `RoundCtx::sync`-from-frame path the `Loopback` transport proved —
//! so a K = n TCP run is bitwise identical to `InProc`.
//!
//! ## Multi-job service
//!
//! The daemon outlives a single job. A DETACH frame ends the current job:
//! the worker answers with one STATE frame (its suspend blob — error
//! feedback, compressor RNG, batch stream — when `want_state` is set,
//! empty otherwise) and returns to **idle**, awaiting the next ASSIGN.
//! This is what lets the resident scheduler ([`super::scheduler`]) run
//! many jobs over one worker fleet without re-handshaking. A SHUTDOWN
//! (either mid-idle or mid-job) or a leader that closes the socket while
//! the worker is idle ends the daemon cleanly; a leader that vanishes
//! *mid-job* is an error (non-zero exit, so a supervisor — or a human —
//! can tell).
//!
//! `exit_after` is fault injection for the crash tests: the daemon exits
//! (status 17) on receiving the downlink for that round, *before*
//! uplinking — dying with an uplink in flight, exactly the permanent-
//! straggler case the supervisor/runtime pair must absorb.

use std::io::{ErrorKind, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algo::RoundCtx;
use crate::compress::Payload;
use crate::config::TrainConfig;

use super::cluster::{export_worker_blob, import_worker_blob};
use super::net::{begin_frame, finish_frame, read_frame, write_frame, FrameKind};
use super::transport::{encode_envelope_into, Envelope};
use super::trainer::build_worker_parts;

/// Exit status of an `--exit-after` fault-injected death (distinguishes
/// the injected crash from real failures in test assertions).
pub const INJECTED_EXIT_STATUS: i32 = 17;

/// How long the daemon keeps retrying the initial connect (covers the
/// two-terminal case where the worker is started before the leader).
const CONNECT_PATIENCE: Duration = Duration::from_secs(10);

fn connect_with_retry(leader: &str, patience: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + patience;
    loop {
        match TcpStream::connect(leader) {
            Ok(s) => return Ok(s),
            Err(e) => {
                // Only keep retrying the transient not-up-yet kinds; a
                // bad/unresolvable address should fail fast, not spin out
                // the whole patience window.
                let transient = matches!(
                    e.kind(),
                    ErrorKind::ConnectionRefused
                        | ErrorKind::ConnectionReset
                        | ErrorKind::TimedOut
                        | ErrorKind::AddrNotAvailable
                );
                if !transient || Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to leader {leader}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Run the worker daemon: HELLO once, then serve ASSIGN→rounds→DETACH
/// cycles until SHUTDOWN (or until the leader closes the socket while
/// the daemon is idle).
pub fn run_worker(leader: &str, exit_after: Option<u64>) -> Result<()> {
    let mut stream = connect_with_retry(leader, CONNECT_PATIENCE)?;
    stream.set_nodelay(true)?;
    write_frame(&mut stream, FrameKind::Hello, &[])?;
    loop {
        // Idle: waiting for the next job.
        let (wid, resume, cfg) = match read_frame(&mut stream)? {
            Some((FrameKind::Assign, body)) => decode_assign(&body)?,
            Some((FrameKind::Shutdown, _)) => {
                eprintln!("[worker] shutdown received while idle, exiting");
                return Ok(());
            }
            Some((kind, _)) => bail!("expected ASSIGN while idle, got {kind:?}"),
            // An idle worker belongs to no job: the leader closing the
            // socket here is a legitimate end of service, not a crash.
            None => {
                eprintln!("[worker] leader closed the connection while idle, exiting");
                return Ok(());
            }
        };
        if serve_job(&mut stream, wid, resume, &cfg, exit_after)? {
            return Ok(());
        }
    }
}

/// Serve one assigned job to completion. Returns `Ok(true)` when the job
/// ended with SHUTDOWN (daemon should exit) and `Ok(false)` when it
/// ended with DETACH (daemon goes back to idle for the next ASSIGN).
fn serve_job(
    stream: &mut TcpStream,
    wid: u32,
    resume: Vec<u8>,
    cfg: &TrainConfig,
    exit_after: Option<u64>,
) -> Result<bool> {
    let (mut src, mut algo) = build_worker_parts(cfg, wid as usize)?;
    if !resume.is_empty() {
        import_worker_blob(src.as_mut(), algo.as_mut(), &resume)
            .context("restoring suspended worker state from ASSIGN")?;
    }
    eprintln!(
        "[worker {wid}] assigned: model={} algo={} dim={}{}",
        cfg.model,
        cfg.algo,
        src.dim(),
        if resume.is_empty() { "" } else { " (resumed)" }
    );
    // Pooled uplink scratch: frame header + envelope + payload body are
    // serialized into this one buffer and sent with a single write_all;
    // capacity is reused across rounds (zero steady-state allocations on
    // the dense path).
    let mut frame: Vec<u8> = Vec::new();
    loop {
        match read_frame(stream)? {
            Some((FrameKind::Downlink, body)) => {
                let env = Envelope::decode(&body)?;
                ensure!(
                    env.wid == wid,
                    "downlink addressed to wid {} arrived at worker {wid}",
                    env.wid
                );
                let theta = match env.payload {
                    Payload::Dense(v) => v,
                    other => bail!("downlink decoded to {other:?}, expected dense θ"),
                };
                if exit_after.is_some_and(|r| env.round >= r) {
                    // Injected crash: die mid-round, uplink owed.
                    eprintln!("[worker {wid}] fault injection: exiting at round {}", env.round);
                    std::process::exit(INJECTED_EXIT_STATUS);
                }
                // The whole RoundCtx comes off the wire (lr rides the
                // envelope's scalar slot on downlinks).
                let ctx = RoundCtx::sync(env.round, env.loss);
                let (loss, grad) = src.grad(&theta, ctx.round)?;
                let payload = algo.process(&grad, &ctx)?;
                frame.clear();
                begin_frame(&mut frame, FrameKind::Uplink);
                encode_envelope_into(wid, env.round, loss, &payload.view(), &mut frame);
                finish_frame(&mut frame)?;
                stream.write_all(&frame)?;
                stream.flush()?;
            }
            Some((FrameKind::Detach, body)) => {
                let want_state = body.first().copied().unwrap_or(0) != 0;
                let blob = if want_state {
                    export_worker_blob(src.as_ref(), algo.as_ref())
                        .context("exporting worker state for DETACH")?
                } else {
                    Vec::new()
                };
                write_frame(stream, FrameKind::State, &blob)?;
                eprintln!("[worker {wid}] detached, back to idle");
                return Ok(false);
            }
            Some((FrameKind::Shutdown, _)) => {
                eprintln!("[worker {wid}] shutdown received, exiting");
                return Ok(true);
            }
            Some((kind, _)) => bail!("unexpected {kind:?} frame on the downlink stream"),
            None => bail!("leader closed the connection mid-run"),
        }
    }
}

fn decode_assign(body: &[u8]) -> Result<(u32, Vec<u8>, TrainConfig)> {
    ensure!(body.len() >= 8, "ASSIGN body truncated: {} bytes", body.len());
    let wid = u32::from_le_bytes(body[0..4].try_into().unwrap());
    let resume_len = u32::from_le_bytes(body[4..8].try_into().unwrap()) as usize;
    ensure!(
        body.len() >= 8 + resume_len,
        "ASSIGN resume blob truncated: {} of {resume_len} bytes",
        body.len().saturating_sub(8)
    );
    let resume = body[8..8 + resume_len].to_vec();
    let json =
        std::str::from_utf8(&body[8 + resume_len..]).context("ASSIGN config is not UTF-8")?;
    let cfg = TrainConfig::from_json(&crate::util::json::parse(json)?)
        .context("parsing the ASSIGN TrainConfig")?;
    ensure!(
        (wid as usize) < cfg.workers,
        "assigned wid {wid} out of range for {} workers",
        cfg.workers
    );
    Ok((wid, resume, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::net::encode_assign;

    #[test]
    fn assign_roundtrip_decodes_wid_blob_and_config() {
        let cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.1");
        let json = cfg.to_json().to_string_pretty();
        let (wid, resume, back) =
            decode_assign(&encode_assign(3, &[], &json)).unwrap();
        assert_eq!(wid, 3);
        assert!(resume.is_empty());
        assert_eq!(back.model, "quadratic");
        assert_eq!(back.algo, "comp-ams-topk:0.1");
        assert_eq!(back.workers, cfg.workers);
        // Resume blobs survive byte-exactly, config intact after them.
        let blob = vec![0u8, 255, 7, 42];
        let (wid, resume, back) =
            decode_assign(&encode_assign(1, &blob, &json)).unwrap();
        assert_eq!(wid, 1);
        assert_eq!(resume, blob);
        assert_eq!(back.algo, cfg.algo);
    }

    #[test]
    fn assign_rejects_garbage() {
        assert!(decode_assign(&[1, 2]).is_err());
        let cfg = TrainConfig::preset("quadratic", "dist-sgd");
        let json = cfg.to_json().to_string_pretty();
        // wid out of range.
        assert!(decode_assign(&encode_assign(99, &[], &json)).is_err());
        // Not JSON after the blob.
        assert!(decode_assign(&encode_assign(0, &[], "not json at all")).is_err());
        // Resume length pointing past the end of the body.
        let mut body = Vec::new();
        body.extend(0u32.to_le_bytes());
        body.extend(1000u32.to_le_bytes());
        body.extend_from_slice(json.as_bytes());
        assert!(decode_assign(&body).is_err());
    }

    #[test]
    fn connect_to_dead_leader_errors_out() {
        // Port 1 is never listening; the retry loop must give up with a
        // context-ful error rather than hang forever.
        let t = Instant::now();
        assert!(connect_with_retry("127.0.0.1:1", Duration::from_millis(200)).is_err());
        assert!(t.elapsed() < Duration::from_secs(30));
    }
}
