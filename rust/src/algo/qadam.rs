//! QAdam baseline (Chen et al. 2021a, as described in the paper §3.2).
//!
//! Every worker keeps a **local** copy of the Adam moments (m_i, v_i) —
//! the 2× model-size memory overhead the paper contrasts COMP-AMS
//! against — and uplinks the compressed update ratio m_i/√(v_i+ε) with
//! error feedback ([`QAdamWorker`]). The server averages the decoded
//! ratios and applies θ ← θ − lr · mean_i C(m_i/√(v_i+ε))
//! ([`QAdamServer`]).

use anyhow::Result;

use crate::compress::{Compressor, CompressorSpec, ErrorFeedback, Payload, PayloadView};
use crate::optim::{BETA1, BETA2, EPS};

use super::{
    aggregate_payloads, per_worker_spec, AggMode, Protocol, RoundCtx, ServerAlgo, WorkerAlgo,
};

/// Worker half: local Adam moments + EF + compressor.
pub struct QAdamWorker {
    compressor: Box<dyn Compressor>,
    ef: ErrorFeedback,
    /// Worker-local first moment.
    m: Vec<f32>,
    /// Worker-local second moment.
    v: Vec<f32>,
    ratio_buf: Vec<f32>,
}

impl QAdamWorker {
    pub fn new(dim: usize, compressor: Box<dyn Compressor>) -> Self {
        QAdamWorker {
            compressor,
            ef: ErrorFeedback::new(dim, true),
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            ratio_buf: vec![0.0; dim],
        }
    }
}

impl WorkerAlgo for QAdamWorker {
    fn process(&mut self, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        for i in 0..grad.len() {
            self.m[i] = BETA1 * self.m[i] + (1.0 - BETA1) * grad[i];
            self.v[i] = BETA2 * self.v[i] + (1.0 - BETA2) * grad[i] * grad[i];
            self.ratio_buf[i] = self.m[i] / (self.v[i].sqrt() + EPS);
        }
        self.ef.compress(&self.ratio_buf, self.compressor.as_mut())
    }

    fn state_bytes(&self) -> usize {
        // m + v per worker — the §3.2 memory argument.
        2 * self.m.len() * std::mem::size_of::<f32>()
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        crate::util::bytes::put_bytes(&mut out, &self.compressor.export_state());
        crate::util::bytes::put_bytes(&mut out, &self.ef.export_state());
        crate::util::bytes::put_f32s(&mut out, &self.m);
        crate::util::bytes::put_f32s(&mut out, &self.v);
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let comp = c.bytes()?.to_vec();
        let ef = c.bytes()?.to_vec();
        let m = c.f32s()?;
        let v = c.f32s()?;
        c.finish()?;
        anyhow::ensure!(
            m.len() == self.m.len() && v.len() == self.v.len(),
            "qadam moment dim mismatch: blob {} vs {}",
            m.len(),
            self.m.len()
        );
        self.compressor.import_state(&comp)?;
        self.ef.import_state(&ef)?;
        self.m = m;
        self.v = v;
        Ok(())
    }
}

/// Server half: stateless averaging + lr step over the decoded ratios.
/// Per-coordinate (no cross-coordinate state at all), so it shards
/// exactly under [`crate::algo::sharded::ShardedServer`].
pub struct QAdamServer {
    comp_name: String,
    avg: Vec<f32>,
    /// Batch estimator over the decoded update ratios (`--robust-agg`).
    agg: AggMode,
}

impl QAdamServer {
    pub fn new(comp_name: String) -> Self {
        QAdamServer { comp_name, avg: Vec::new(), agg: AggMode::Mean }
    }
}

impl ServerAlgo for QAdamServer {
    fn name(&self) -> String {
        format!("qadam[{}]", self.comp_name)
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        aggregate_payloads(msgs, theta.len(), &mut avg, self.agg)?;
        crate::util::math::axpy(-ctx.lr, &avg, theta);
        self.avg = avg;
        Ok(())
    }

    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        self.agg = mode;
        Ok(())
    }
}

/// Build the full QAdam protocol: n worker halves + the server half.
pub fn protocol(dim: usize, n: usize, compressor: CompressorSpec) -> Protocol {
    let comp_name = compressor.build().name();
    let workers: Vec<Box<dyn WorkerAlgo>> = (0..n)
        .map(|w| {
            Box::new(QAdamWorker::new(dim, per_worker_spec(&compressor, w).build()))
                as Box<dyn WorkerAlgo>
        })
        .collect();
    (workers, Box::new(QAdamServer::new(comp_name)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_is_bounded_like_adam() {
        // |m/√v| ≤ √(1/(1-β2)) for any gradient sequence; the uplinked
        // ratios should never explode even with huge gradients.
        let mut w = QAdamWorker::new(8, CompressorSpec::Identity.build());
        let ctx = RoundCtx::sync(0, 0.001);
        for r in 0..50 {
            let g = vec![1e6f32; 8];
            let msg = w.process(&g, &ctx).unwrap();
            let d = msg.to_dense(8).unwrap();
            for &x in &d {
                assert!(x.abs() < 40.0, "round {r}: ratio {x}");
            }
        }
    }

    #[test]
    fn descends_quadratic() {
        let (mut workers, mut server) =
            protocol(4, 2, CompressorSpec::BlockSign { block: 4 });
        let mut theta = vec![2.0f32; 4];
        for r in 0..400 {
            let ctx = RoundCtx::sync(r, 0.02);
            let g: Vec<f32> = theta.clone();
            let msgs: Vec<Payload> = workers
                .iter_mut()
                .map(|w| w.process(&g, &ctx).unwrap())
                .collect();
            server.step(&mut theta, &crate::compress::as_views(&msgs), &ctx).unwrap();
        }
        assert!(crate::util::math::norm2(&theta) < 0.5);
    }

    #[test]
    fn reports_local_state_overhead() {
        let w = QAdamWorker::new(1000, CompressorSpec::Identity.build());
        assert_eq!(w.state_bytes(), 8000);
    }
}
