//! QAdam baseline (Chen et al. 2021a, as described in the paper §3.2).
//!
//! Every worker keeps a **local** copy of the Adam moments (m_i, v_i) —
//! the 2× model-size memory overhead the paper contrasts COMP-AMS
//! against — and uplinks the compressed update ratio m_i/√(v_i+ε) with
//! error feedback. The server averages the decoded ratios and applies
//! θ ← θ − lr · mean_i C(m_i/√(v_i+ε)).

use anyhow::Result;

use crate::compress::{Compressor, CompressorSpec, ErrorFeedback, Payload};
use crate::optim::{BETA1, BETA2, EPS};

use super::{average_payloads, Algorithm, RoundCtx};

pub struct QAdam {
    compressors: Vec<Box<dyn Compressor>>,
    efs: Vec<ErrorFeedback>,
    /// Worker-local first moments.
    m: Vec<Vec<f32>>,
    /// Worker-local second moments.
    v: Vec<Vec<f32>>,
    ratio_buf: Vec<f32>,
    avg: Vec<f32>,
}

impl QAdam {
    pub fn new(dim: usize, n: usize, compressor: CompressorSpec) -> Self {
        QAdam {
            compressors: (0..n).map(|_| compressor.build()).collect(),
            efs: (0..n).map(|_| ErrorFeedback::new(dim, true)).collect(),
            m: vec![vec![0.0; dim]; n],
            v: vec![vec![0.0; dim]; n],
            ratio_buf: vec![0.0; dim],
            avg: Vec::new(),
        }
    }
}

impl Algorithm for QAdam {
    fn name(&self) -> String {
        format!("qadam[{}]", self.compressors[0].name())
    }

    fn worker_msg(&mut self, wid: usize, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        let m = &mut self.m[wid];
        let v = &mut self.v[wid];
        for i in 0..grad.len() {
            m[i] = BETA1 * m[i] + (1.0 - BETA1) * grad[i];
            v[i] = BETA2 * v[i] + (1.0 - BETA2) * grad[i] * grad[i];
            self.ratio_buf[i] = m[i] / (v[i].sqrt() + EPS);
        }
        self.efs[wid].compress(&self.ratio_buf, self.compressors[wid].as_mut())
    }

    fn server_step(
        &mut self,
        theta: &mut [f32],
        msgs: &[Payload],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        average_payloads(msgs, theta.len(), &mut avg)?;
        crate::util::math::axpy(-ctx.lr, &avg, theta);
        self.avg = avg;
        Ok(())
    }

    fn worker_state_bytes(&self) -> usize {
        // m + v per worker — the §3.2 memory argument.
        2 * self.m[0].len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_is_bounded_like_adam() {
        // |m/√v| ≤ √(1/(1-β2)) for any gradient sequence; the uplinked
        // ratios should never explode even with huge gradients.
        let mut q = QAdam::new(8, 1, CompressorSpec::Identity);
        let ctx = RoundCtx { round: 0, lr: 0.001 };
        for r in 0..50 {
            let g = vec![1e6f32; 8];
            let msg = q.worker_msg(0, &g, &ctx).unwrap();
            let d = msg.to_dense(8).unwrap();
            for &x in &d {
                assert!(x.abs() < 40.0, "round {r}: ratio {x}");
            }
        }
    }

    #[test]
    fn descends_quadratic() {
        let mut q = QAdam::new(4, 2, CompressorSpec::BlockSign { block: 4 });
        let mut theta = vec![2.0f32; 4];
        for r in 0..400 {
            let ctx = RoundCtx { round: r, lr: 0.02 };
            let msgs: Vec<Payload> = (0..2)
                .map(|w| {
                    let g: Vec<f32> = theta.clone();
                    q.worker_msg(w, &g, &ctx).unwrap()
                })
                .collect();
            q.server_step(&mut theta, &msgs, &ctx).unwrap();
        }
        assert!(crate::util::math::norm2(&theta) < 0.5);
    }

    #[test]
    fn reports_local_state_overhead() {
        let q = QAdam::new(1000, 4, CompressorSpec::Identity);
        assert_eq!(q.worker_state_bytes(), 8000);
    }
}
