//! Full-precision distributed (momentum) SGD — the appendix Fig. 4
//! reference ("converges faster but generalizes slightly worse").

use anyhow::Result;

use crate::compress::Payload;
use crate::optim::{MomentumSgd, ServerOpt};

use super::{average_payloads, Algorithm, RoundCtx};

pub struct DistSgd {
    opt: MomentumSgd,
    avg: Vec<f32>,
}

impl DistSgd {
    pub fn new(dim: usize, momentum: f32) -> Self {
        DistSgd { opt: MomentumSgd::new(dim, momentum), avg: Vec::new() }
    }
}

impl Algorithm for DistSgd {
    fn name(&self) -> String {
        "dist-sgd".into()
    }

    fn worker_msg(&mut self, _wid: usize, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        Ok(Payload::Dense(grad.to_vec()))
    }

    fn server_step(
        &mut self,
        theta: &mut [f32],
        msgs: &[Payload],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        average_payloads(msgs, theta.len(), &mut avg)?;
        self.opt.step(theta, &avg, ctx.lr);
        self.avg = avg;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaging_two_workers_matches_mean_gradient_step() {
        let mut algo = DistSgd::new(3, 0.0);
        let mut theta = vec![0.0f32; 3];
        let ctx = RoundCtx { round: 0, lr: 1.0 };
        let msgs = vec![
            Payload::Dense(vec![1.0, 0.0, 2.0]),
            Payload::Dense(vec![3.0, 0.0, 0.0]),
        ];
        algo.server_step(&mut theta, &msgs, &ctx).unwrap();
        assert_eq!(theta, vec![-2.0, 0.0, -1.0]);
    }
}
