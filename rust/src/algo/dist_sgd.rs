//! Full-precision distributed (momentum) SGD — the appendix Fig. 4
//! reference ("converges faster but generalizes slightly worse").

use anyhow::Result;

use crate::compress::{Payload, PayloadView};
use crate::optim::{MomentumSgd, ServerOpt};

use super::{aggregate_payloads, AggMode, Protocol, RoundCtx, ServerAlgo, WorkerAlgo};

/// Worker half: stateless dense uplink.
pub struct DistSgdWorker;

impl WorkerAlgo for DistSgdWorker {
    fn process(&mut self, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        Ok(Payload::Dense(grad.to_vec()))
    }
}

/// Server half: momentum SGD on the averaged gradient. The velocity is
/// per-coordinate, so it shards exactly under
/// [`crate::algo::sharded::ShardedServer`].
pub struct DistSgdServer {
    opt: MomentumSgd,
    avg: Vec<f32>,
    /// Batch estimator (`--robust-agg`), plain mean by default.
    agg: AggMode,
}

impl DistSgdServer {
    pub fn new(dim: usize, momentum: f32) -> Self {
        DistSgdServer {
            opt: MomentumSgd::new(dim, momentum),
            avg: Vec::new(),
            agg: AggMode::Mean,
        }
    }
}

impl ServerAlgo for DistSgdServer {
    fn name(&self) -> String {
        "dist-sgd".into()
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        aggregate_payloads(msgs, theta.len(), &mut avg, self.agg)?;
        self.opt.step(theta, &avg, ctx.lr);
        self.avg = avg;
        Ok(())
    }

    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        self.agg = mode;
        Ok(())
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        crate::util::bytes::put_f32s(&mut out, &self.opt.buf);
        Ok(out)
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let buf = c.f32s()?;
        c.finish()?;
        anyhow::ensure!(
            buf.len() == self.opt.buf.len(),
            "dist-sgd velocity dim mismatch: blob {} vs {}",
            buf.len(),
            self.opt.buf.len()
        );
        self.opt.buf = buf;
        Ok(())
    }
}

/// Build the full Dist-SGD protocol: n worker halves + the server half.
pub fn protocol(dim: usize, n: usize, momentum: f32) -> Protocol {
    let workers: Vec<Box<dyn WorkerAlgo>> =
        (0..n).map(|_| Box::new(DistSgdWorker) as Box<dyn WorkerAlgo>).collect();
    (workers, Box::new(DistSgdServer::new(dim, momentum)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaging_two_workers_matches_mean_gradient_step() {
        let mut server = DistSgdServer::new(3, 0.0);
        let mut theta = vec![0.0f32; 3];
        let ctx = RoundCtx::sync(0, 1.0);
        let msgs = vec![
            Payload::Dense(vec![1.0, 0.0, 2.0]),
            Payload::Dense(vec![3.0, 0.0, 0.0]),
        ];
        server.step(&mut theta, &crate::compress::as_views(&msgs), &ctx).unwrap();
        assert_eq!(theta, vec![-2.0, 0.0, -1.0]);
    }

    #[test]
    fn worker_half_is_a_dense_passthrough() {
        let mut w = DistSgdWorker;
        let ctx = RoundCtx::sync(0, 0.1);
        let g = vec![1.0f32, -2.0];
        assert_eq!(w.process(&g, &ctx).unwrap(), Payload::Dense(g.clone()));
        assert_eq!(w.state_bytes(), 0);
    }
}
