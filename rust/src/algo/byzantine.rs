//! Adversarial worker modes: deterministic byzantine fault injection at
//! the [`WorkerAlgo`] boundary (`--byzantine wid:mode`).
//!
//! A byzantine worker runs the *same* gradient source and protocol half
//! as an honest one — the attack is a pure function applied to the raw
//! stochastic gradient just before `process()`, so compression, error
//! feedback, and the wire accounting all see the corrupted gradient
//! exactly as a real malicious node would present it:
//!
//! | mode           | uplink gradient                                    |
//! |----------------|----------------------------------------------------|
//! | `scale:<f>`    | `f · g` — amplified (or, with `f < 0`, an amplified sign-flip that can zero the batch mean) |
//! | `signflip`     | `-g` — the classic sign-flipping attack            |
//! | `stale`        | the *previous* round's honest gradient (round 0 passes through) — a replay adversary |
//!
//! Because the corruption is deterministic given the worker's seeded RNG
//! stream, byzantine runs reproduce bit-for-bit — the point of the fault
//! testbed. The robust server-side estimators ([`AggMode`](super::AggMode),
//! `--robust-agg median|trimmed:<k>`) are the countermeasure the
//! integration tests pit these attacks against.

use anyhow::{anyhow, bail, Result};

use crate::compress::Payload;
use crate::util::bytes::{self, Cursor};

use super::{RoundCtx, WorkerAlgo};

/// The accepted `--byzantine` entry spellings (comma-separable),
/// enumerated in every parse error.
pub const BYZANTINE_CHOICES: &str = "<wid>:scale:<factor> | <wid>:signflip | <wid>:stale";

/// One worker's corruption mode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ByzMode {
    /// Send `factor · g` (negative factors amplify-and-flip).
    Scale(f32),
    /// Send `-g`.
    SignFlip,
    /// Replay the previous round's honest gradient (pass-through on the
    /// worker's first round).
    StaleReplay,
}

/// A parsed `--byzantine` entry: which worker, corrupted how.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ByzSpec {
    pub wid: usize,
    pub mode: ByzMode,
}

/// Parse the `--byzantine` flag: comma-separated `wid:mode` entries
/// (see [`BYZANTINE_CHOICES`]); the empty string means no adversaries.
pub fn parse_byzantine(s: &str) -> Result<Vec<ByzSpec>> {
    let mut out: Vec<ByzSpec> = Vec::new();
    if s.trim().is_empty() {
        return Ok(out);
    }
    for entry in s.split(',') {
        let entry = entry.trim();
        let (wid_str, mode_str) = entry.split_once(':').ok_or_else(|| {
            anyhow!("bad byzantine entry '{entry}' (accepted forms: {BYZANTINE_CHOICES})")
        })?;
        let wid: usize = wid_str.parse().map_err(|_| {
            anyhow!(
                "bad worker id '{wid_str}' in byzantine entry '{entry}' \
                 (accepted forms: {BYZANTINE_CHOICES})"
            )
        })?;
        let mode = match mode_str {
            "signflip" => ByzMode::SignFlip,
            "stale" => ByzMode::StaleReplay,
            other => match other.strip_prefix("scale:") {
                Some(f_str) => ByzMode::Scale(f_str.parse().map_err(|_| {
                    anyhow!(
                        "bad scale factor '{f_str}' in byzantine entry '{entry}' \
                         (accepted forms: {BYZANTINE_CHOICES})"
                    )
                })?),
                None => bail!(
                    "unknown byzantine mode '{other}' in entry '{entry}' \
                     (accepted forms: {BYZANTINE_CHOICES})"
                ),
            },
        };
        if out.iter().any(|spec| spec.wid == wid) {
            bail!("duplicate byzantine entry for worker {wid}");
        }
        out.push(ByzSpec { wid, mode });
    }
    Ok(out)
}

/// A [`WorkerAlgo`] decorator that corrupts the raw gradient before the
/// wrapped protocol half sees it. Wraps any worker half of any protocol,
/// so every attack composes with every compressor and EF setting.
pub struct ByzantineWorker {
    inner: Box<dyn WorkerAlgo>,
    mode: ByzMode,
    /// `StaleReplay` only: the previous round's honest gradient.
    last: Vec<f32>,
}

impl ByzantineWorker {
    pub fn wrap(inner: Box<dyn WorkerAlgo>, mode: ByzMode) -> Box<dyn WorkerAlgo> {
        Box::new(ByzantineWorker { inner, mode, last: Vec::new() })
    }
}

impl WorkerAlgo for ByzantineWorker {
    fn process(&mut self, grad: &[f32], ctx: &RoundCtx) -> Result<Payload> {
        let g: Vec<f32> = match self.mode {
            ByzMode::Scale(f) => grad.iter().map(|x| f * x).collect(),
            ByzMode::SignFlip => grad.iter().map(|x| -x).collect(),
            ByzMode::StaleReplay => {
                let replay = if self.last.is_empty() {
                    grad.to_vec()
                } else {
                    std::mem::take(&mut self.last)
                };
                self.last = grad.to_vec();
                replay
            }
        };
        self.inner.process(&g, ctx)
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        bytes::put_f32s(&mut out, &self.last);
        bytes::put_bytes(&mut out, &self.inner.export_state());
        out
    }

    fn import_state(&mut self, blob: &[u8]) -> Result<()> {
        let mut c = Cursor::new(blob);
        self.last = c.f32s()?;
        let inner_blob = c.bytes()?.to_vec();
        c.finish()?;
        self.inner.import_state(&inner_blob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Inner double that records the gradient it was handed and echoes it
    /// back as a dense payload.
    struct Echo {
        seen: Vec<Vec<f32>>,
    }

    impl WorkerAlgo for Echo {
        fn process(&mut self, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
            self.seen.push(grad.to_vec());
            Ok(Payload::Dense(grad.to_vec()))
        }
    }

    fn wrapped(mode: ByzMode) -> Box<dyn WorkerAlgo> {
        ByzantineWorker::wrap(Box::new(Echo { seen: Vec::new() }), mode)
    }

    #[test]
    fn parse_all_forms_and_rejections() {
        assert_eq!(parse_byzantine("").unwrap(), vec![]);
        assert_eq!(parse_byzantine("  ").unwrap(), vec![]);
        assert_eq!(
            parse_byzantine("0:signflip").unwrap(),
            vec![ByzSpec { wid: 0, mode: ByzMode::SignFlip }]
        );
        assert_eq!(
            parse_byzantine("2:scale:-3, 1:stale").unwrap(),
            vec![
                ByzSpec { wid: 2, mode: ByzMode::Scale(-3.0) },
                ByzSpec { wid: 1, mode: ByzMode::StaleReplay },
            ]
        );
        for bad in ["nope", "0", "0:flip", "x:signflip", "0:scale:", "0:scale:x"] {
            let err = parse_byzantine(bad).unwrap_err().to_string();
            assert!(err.contains(BYZANTINE_CHOICES), "{bad}: {err}");
        }
        assert!(parse_byzantine("0:stale,0:signflip")
            .unwrap_err()
            .to_string()
            .contains("duplicate"));
    }

    #[test]
    fn scale_and_signflip_corrupt_pointwise() {
        let ctx = RoundCtx::sync(0, 0.1);
        let mut w = wrapped(ByzMode::Scale(-3.0));
        let p = w.process(&[1.0, -2.0], &ctx).unwrap();
        assert_eq!(p, Payload::Dense(vec![-3.0, 6.0]));
        let mut w = wrapped(ByzMode::SignFlip);
        let p = w.process(&[1.0, -2.0], &ctx).unwrap();
        assert_eq!(p, Payload::Dense(vec![-1.0, 2.0]));
    }

    #[test]
    fn stale_replay_lags_one_round_after_passthrough_start() {
        let ctx = RoundCtx::sync(0, 0.1);
        let mut w = wrapped(ByzMode::StaleReplay);
        // Round 0: nothing buffered yet — the honest gradient goes out.
        assert_eq!(w.process(&[1.0], &ctx).unwrap(), Payload::Dense(vec![1.0]));
        // Round t > 0: always the previous round's gradient.
        assert_eq!(w.process(&[2.0], &ctx).unwrap(), Payload::Dense(vec![1.0]));
        assert_eq!(w.process(&[3.0], &ctx).unwrap(), Payload::Dense(vec![2.0]));
    }

    #[test]
    fn state_roundtrip_preserves_replay_buffer() {
        let ctx = RoundCtx::sync(0, 0.1);
        let mut w = wrapped(ByzMode::StaleReplay);
        w.process(&[1.0, 2.0], &ctx).unwrap();
        w.process(&[5.0, 6.0], &ctx).unwrap();
        let blob = w.export_state();
        let mut resumed = wrapped(ByzMode::StaleReplay);
        resumed.import_state(&blob).unwrap();
        // Both continue by replaying [5, 6] next.
        assert_eq!(
            resumed.process(&[9.0, 9.0], &ctx).unwrap(),
            w.process(&[9.0, 9.0], &ctx).unwrap()
        );
        assert!(w.import_state(&[1, 2, 3]).is_err());
    }
}
