//! The aggregate-and-forward half of the tree topology
//! ([`crate::coordinator::tree`]).
//!
//! A sub-leader is a [`crate::coordinator::runtime::ClusterRuntime`] whose
//! "server step" does not touch θ at all: [`GroupForwardServer`] aggregates
//! its group's uplinks with the same estimator the root uses
//! ([`aggregate_payloads`], so `--robust-agg` applies at *both* levels),
//! re-compresses the group aggregate through its **own error-feedback
//! accumulator** (Wang et al. 2111.00705: EF at every compression point
//! preserves the convergence guarantees), and parks the resulting payload
//! for the tree transport to forward upward as one uplink.
//!
//! Bitwise contract: with the identity group compressor, the forwarded
//! payload is exactly the dense group mean — op-for-op the flat server's
//! aggregation over the same messages in the same order — which is what
//! makes the degenerate tree (one group spanning all workers, no downlink
//! compression) bit-identical to the flat star in loss and θ.

use anyhow::{ensure, Result};

use crate::compress::{Compressor, CompressorSpec, ErrorFeedback, Payload, PayloadView};

use super::{aggregate_payloads, AggMode, RoundCtx, ServerAlgo};

/// Sub-leader server half: aggregate the group's uplinks, re-compress the
/// aggregate with error feedback, park it for forwarding. Never mutates θ.
pub struct GroupForwardServer {
    dim: usize,
    compressor: Box<dyn Compressor>,
    comp_name: String,
    /// Sub-leader's own EF accumulator over the *group aggregate* —
    /// disabled (zero residual) for the identity compressor, so the
    /// degenerate tree forwards the exact mean.
    ef: ErrorFeedback,
    agg: AggMode,
    avg: Vec<f32>,
    forwarded: Option<Payload>,
}

impl GroupForwardServer {
    pub fn new(dim: usize, spec: &CompressorSpec) -> Self {
        let has_ef = *spec != CompressorSpec::Identity;
        GroupForwardServer {
            dim,
            compressor: spec.build(),
            comp_name: spec.build().name(),
            ef: ErrorFeedback::new(dim, has_ef),
            agg: AggMode::Mean,
            avg: Vec::new(),
            forwarded: None,
        }
    }

    /// Take the payload parked by the last [`ServerAlgo::step`] (the
    /// compressed group aggregate the tree transport forwards to the
    /// root). `None` if no step has run since the last take.
    pub fn take_forwarded(&mut self) -> Option<Payload> {
        self.forwarded.take()
    }

    /// This sub-leader's EF residual norm (diagnostics / tests).
    pub fn residual_norm(&self) -> f64 {
        self.ef.residual_norm()
    }
}

impl ServerAlgo for GroupForwardServer {
    fn name(&self) -> String {
        format!("group-forward[{}]", self.comp_name)
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        _ctx: &RoundCtx,
    ) -> Result<()> {
        // θ is advanced only at the root; the sub-leader's "step" is
        // aggregate → EF-compress → park.
        ensure!(
            theta.len() == self.dim,
            "group-forward dim mismatch: θ is {} but server was built for {}",
            theta.len(),
            self.dim
        );
        let mut avg = std::mem::take(&mut self.avg);
        aggregate_payloads(msgs, self.dim, &mut avg, self.agg)?;
        let payload = self.ef.compress(&avg, self.compressor.as_mut())?;
        self.avg = avg;
        self.forwarded = Some(payload);
        Ok(())
    }

    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        self.agg = mode;
        Ok(())
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        crate::util::bytes::put_bytes(&mut out, &self.compressor.export_state());
        crate::util::bytes::put_bytes(&mut out, &self.ef.export_state());
        Ok(out)
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let comp = c.bytes()?.to_vec();
        let ef = c.bytes()?.to_vec();
        c.finish()?;
        self.compressor.import_state(&comp)?;
        self.ef.import_state(&ef)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::average_payloads;
    use crate::compress::as_views;

    fn ctx(round: u64) -> RoundCtx {
        RoundCtx::sync(round, 0.01)
    }

    #[test]
    fn identity_forwards_the_exact_group_mean() {
        let dim = 8;
        let mut s = GroupForwardServer::new(dim, &CompressorSpec::Identity);
        let msgs = vec![
            Payload::Dense(vec![1.0; dim]),
            Payload::Sparse { dim: dim as u32, idx: vec![0, 3], val: vec![2.0, -4.0] },
            Payload::Dense(vec![-0.5; dim]),
        ];
        let views = as_views(&msgs);
        let mut theta = vec![0.7f32; dim];
        let before = theta.clone();
        s.step(&mut theta, &views, &ctx(0)).unwrap();
        assert_eq!(theta, before, "sub-leaders must never touch θ");
        let fwd = s.take_forwarded().unwrap();
        let mut want = Vec::new();
        average_payloads(&views, dim, &mut want).unwrap();
        match fwd {
            Payload::Dense(got) => {
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("identity forward must be dense, got {other:?}"),
        }
        // Identity keeps no residual: the forward is lossless.
        assert_eq!(s.residual_norm(), 0.0);
        assert!(s.take_forwarded().is_none(), "take is one-shot");
    }

    #[test]
    fn compressing_group_aggregate_accumulates_residual() {
        let dim = 128;
        let spec = CompressorSpec::TopK { ratio: 0.1 };
        let mut s = GroupForwardServer::new(dim, &spec);
        let mut rng = crate::util::rng::Rng::seed(9);
        let mut theta = vec![0.0f32; dim];
        for r in 0..5 {
            let msgs = vec![
                Payload::Dense(rng.normal_vec(dim)),
                Payload::Dense(rng.normal_vec(dim)),
            ];
            s.step(&mut theta, &as_views(&msgs), &ctx(r)).unwrap();
            let fwd = s.take_forwarded().unwrap();
            assert!(fwd.wire_bits() < Payload::Dense(vec![0.0; dim]).wire_bits());
        }
        assert!(s.residual_norm() > 0.0, "top-k must leave a residual");
    }

    #[test]
    fn robust_agg_applies_at_the_group_level() {
        // 3 honest messages plus one scaled adversary inside the group:
        // trimmed:1 must forward the honest direction, not the zero mean.
        let dim = 4;
        let honest = Payload::Dense(vec![1.0; dim]);
        let evil = Payload::Dense(vec![-3.0; dim]);
        let msgs = vec![honest.clone(), honest.clone(), honest, evil];
        let mut s = GroupForwardServer::new(dim, &CompressorSpec::Identity);
        s.set_agg_mode(AggMode::Trimmed(1)).unwrap();
        let mut theta = vec![0.0f32; dim];
        s.step(&mut theta, &as_views(&msgs), &ctx(0)).unwrap();
        let fwd = s.take_forwarded().unwrap().to_dense(dim).unwrap();
        assert!(fwd.iter().all(|&x| x == 1.0), "{fwd:?}");
    }

    #[test]
    fn state_roundtrip_restores_residual() {
        let dim = 64;
        let spec = CompressorSpec::TopK { ratio: 0.1 };
        let mut a = GroupForwardServer::new(dim, &spec);
        let mut rng = crate::util::rng::Rng::seed(11);
        let mut theta = vec![0.0f32; dim];
        for r in 0..3 {
            let msgs = vec![Payload::Dense(rng.normal_vec(dim))];
            a.step(&mut theta, &as_views(&msgs), &ctx(r)).unwrap();
            a.take_forwarded();
        }
        let blob = a.export_state().unwrap();
        let mut b = GroupForwardServer::new(dim, &spec);
        b.import_state(&blob).unwrap();
        let g = rng.normal_vec(dim);
        let msgs = vec![Payload::Dense(g)];
        a.step(&mut theta, &as_views(&msgs), &ctx(3)).unwrap();
        b.step(&mut theta, &as_views(&msgs), &ctx(3)).unwrap();
        assert_eq!(a.take_forwarded(), b.take_forwarded());
    }
}
