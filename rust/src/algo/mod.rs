//! Distributed optimization protocols — the paper's Algorithm 2 and every
//! baseline in its evaluation (§5.1).
//!
//! A protocol is **two-sided**, mirroring Algorithm 2's layout:
//!
//! | trait          | runs on        | owns                                        |
//! |----------------|----------------|---------------------------------------------|
//! | [`WorkerAlgo`] | worker thread  | compressor, EF accumulator, local optimizer state (QAdam m/v, 1BitAdam m) |
//! | [`ServerAlgo`] | leader thread  | aggregation buffers, server optimizer state, fused-kernel routing |
//!
//! [`AlgoSpec::build`] instantiates one `WorkerAlgo` **per worker** plus a
//! single `ServerAlgo`. `WorkerAlgo: Send` so the coordinator's threaded
//! backend can move each instance into its worker thread and run the full
//! per-worker pipeline (gradient → EF → compress → encode) off the leader.
//!
//! The server half no longer has to be a single leader-pinned object:
//! because every server optimizer here is strictly per-coordinate,
//! [`AlgoSpec::build_server`] can instantiate one `Send` server half per
//! contiguous θ shard and [`sharded::ShardedServer`] runs the S shard
//! updates sequentially or on a leader-side thread pool, with
//! trajectories bitwise identical to the unsharded server. The one
//! exception is the Pallas fused-update backend
//! ([`comp_ams::FusedCompAmsServer`]): it holds non-`Send` PJRT handles
//! compiled for the full θ, so it stays on the leader and is mutually
//! exclusive with sharding.
//!
//! Per-protocol split (worker uplink / server update):
//!
//! | name            | worker side ([`WorkerAlgo`])     | server side ([`ServerAlgo`]) |
//! |-----------------|----------------------------------|------------------------------|
//! | `dist-ams`      | dense gradient                   | AMSGrad                      |
//! | `comp-ams-*`    | C(g + e) with error feedback     | AMSGrad (state on server)    |
//! | `qadam`         | C(m/√v) with EF (local m, v)     | lr · avg ratio               |
//! | `1bitadam`      | dense g (warm-up) then C(m) + EF | Adam, then frozen-v momentum |
//! | `dist-sgd`      | dense gradient                   | (momentum) SGD               |
//!
//! Migration note: the old fused `Algorithm` trait (`worker_msg` +
//! `server_step` on one `&mut self` object) is gone — `worker_msg` became
//! [`WorkerAlgo::process`] on a per-worker instance, `server_step` became
//! [`ServerAlgo::step`], and `worker_state_bytes` became
//! [`WorkerAlgo::state_bytes`] (still *per worker*).

pub mod byzantine;
pub mod comp_ams;
pub mod dist_sgd;
pub mod group;
pub mod onebit_adam;
pub mod qadam;
pub mod sharded;

pub use byzantine::{parse_byzantine, ByzMode, ByzSpec, ByzantineWorker};
pub use comp_ams::{CompAmsServer, CompAmsWorker, FusedCompAmsServer};
pub use group::GroupForwardServer;
pub use dist_sgd::{DistSgdServer, DistSgdWorker};
pub use onebit_adam::{OneBitAdamServer, OneBitAdamWorker};
pub use qadam::{QAdamServer, QAdamWorker};
pub use sharded::{ShardStats, ShardedServer};

use std::rc::Rc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::compress::{CompressorSpec, Payload, PayloadView};
use crate::runtime::OptimizerExe;

/// Per-round context handed to both sides of the protocol.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    /// The leader's round counter (the round being stepped).
    pub round: u64,
    /// The round at which the oldest gradient in flight was computed.
    /// Equal to `round` on the synchronous path; with partial
    /// participation ([`crate::coordinator::runtime`]) it lags behind by
    /// up to `max_staleness`, so protocols can observe the staleness of
    /// the batch they are applying (`round - observed_round`).
    pub observed_round: u64,
    pub lr: f32,
}

impl RoundCtx {
    /// A synchronous-round context: every gradient in the batch was
    /// computed at `round` (the only case before partial participation,
    /// and still the K = n default).
    pub fn sync(round: u64, lr: f32) -> RoundCtx {
        RoundCtx { round, observed_round: round, lr }
    }
}

/// The worker half of a protocol: one instance per worker, owning that
/// worker's compressor, error-feedback accumulator, and any local
/// optimizer state. `Send` so the threaded coordinator can run the whole
/// gradient → EF → compress → encode pipeline inside the worker thread.
pub trait WorkerAlgo: Send {
    /// Turn this worker's raw stochastic gradient into the uplink
    /// message (compression + any worker-local state updates).
    fn process(&mut self, grad: &[f32], ctx: &RoundCtx) -> Result<Payload>;

    /// Extra per-worker memory (bytes) beyond the error accumulator —
    /// the paper's §3.2 memory-footprint comparison.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Serialize this worker half's trajectory state (EF residual,
    /// compressor RNG, local moments) for suspend/resume. A resumed
    /// worker built from the same config with this blob imported
    /// continues the trajectory bitwise. Stateless halves return empty.
    fn export_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore a blob produced by [`WorkerAlgo::export_state`].
    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        if !bytes.is_empty() {
            bail!(
                "stateless worker half got a {}-byte state blob",
                bytes.len()
            );
        }
        Ok(())
    }
}

/// The server half of a protocol: consumes all n uplink messages and
/// updates `theta`.
///
/// The trait itself is object-safe and not `Send`-bound — the fused PJRT
/// backend holds non-`Send` handles — but every pure-Rust implementation
/// is `Send`, which is what lets [`AlgoSpec::build_server`] hand per-shard
/// instances to the [`sharded::ShardedServer`] thread pool.
pub trait ServerAlgo {
    fn name(&self) -> String;

    /// Apply one aggregated update to `theta`. Uplinks arrive as borrowed
    /// [`PayloadView`]s — for frame-backed messages these index straight
    /// into the received bytes, so the server never materializes owned
    /// index/value vectors (the zero-copy uplink path). Owned payloads
    /// enter via [`Payload::view`] / [`crate::compress::as_views`].
    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()>;

    /// Per-shard accounting when this server partitions θ across several
    /// shard optimizers ([`sharded::ShardedServer`] overrides this);
    /// `None` for single-shard servers.
    fn shard_stats(&self) -> Option<&ShardStats> {
        None
    }

    /// Select the estimator this server applies to each round's batch of
    /// uplink messages (`--robust-agg`): plain averaging (the default),
    /// or a byzantine-tolerant composition — coordinate-wise median or
    /// trimmed mean ([`AggMode`]). Servers whose update is not a
    /// pluggable batch-aggregation (post-warmup 1BitAdam's frozen-v
    /// momentum merge, the fused PJRT backend) accept only
    /// [`AggMode::Mean`]; `TrainConfig::validate` rejects those combos
    /// up front with a friendlier message, so this default is the
    /// backstop.
    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        if mode == AggMode::Mean {
            Ok(())
        } else {
            bail!(
                "server '{}' supports only mean aggregation (robust-agg: {AGG_CHOICES})",
                self.name()
            )
        }
    }

    /// Tell this server its uplinks are **pre-aggregated group means**
    /// rather than raw worker messages (the tree topology's root —
    /// [`crate::coordinator::tree`] — where each message is a
    /// sub-leader's forwarded aggregate). Averaging servers need no
    /// change (the mean of group means is the tree's estimator), so the
    /// default is a no-op; servers that *classify* messages by payload
    /// kind (post-warmup 1BitAdam treats dense uplinks as cross-phase
    /// stragglers to discard) override this to disable that filtering.
    /// [`sharded::ShardedServer`] forwards the flag to every shard.
    fn set_pre_aggregated(&mut self, _pre: bool) {}

    /// Serialize the server optimizer's trajectory state (moments,
    /// preconditioners, step counters) for suspend/resume. Stateless
    /// servers return empty; [`sharded::ShardedServer`] concatenates its
    /// per-shard blobs.
    fn export_state(&self) -> Result<Vec<u8>> {
        Ok(Vec::new())
    }

    /// Restore a blob produced by [`ServerAlgo::export_state`].
    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        if !bytes.is_empty() {
            bail!(
                "server '{}' is stateless but got a {}-byte state blob",
                self.name(),
                bytes.len()
            );
        }
        Ok(())
    }
}

/// A fully instantiated protocol: one worker half per worker plus the
/// server half. What [`AlgoSpec::build`] returns.
pub type Protocol = (Vec<Box<dyn WorkerAlgo>>, Box<dyn ServerAlgo>);

/// Parsed protocol spec (from CLI/config strings).
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoSpec {
    DistAms,
    CompAms { compressor: CompressorSpec, error_feedback: bool },
    QAdam { compressor: CompressorSpec },
    OneBitAdam { warmup_rounds: u64, block: usize },
    DistSgd { momentum: f32 },
}

impl AlgoSpec {
    /// Parse e.g. `dist-ams`, `comp-ams-topk:0.01`, `comp-ams-blocksign:4096`,
    /// `comp-ams-topk:0.01:noef`, `qadam`, `1bitadam:100`, `dist-sgd`.
    pub fn parse(s: &str) -> Result<AlgoSpec> {
        if s == "dist-ams" {
            return Ok(AlgoSpec::DistAms);
        }
        if let Some(rest) = s.strip_prefix("comp-ams-") {
            let (comp_str, noef) = match rest.strip_suffix(":noef") {
                Some(c) => (c, true),
                None => (rest, false),
            };
            return Ok(AlgoSpec::CompAms {
                compressor: CompressorSpec::parse(comp_str)?,
                error_feedback: !noef,
            });
        }
        if s == "qadam" {
            // QAdam's published variant is 1-bit; blocksign over the ratio.
            return Ok(AlgoSpec::QAdam {
                compressor: CompressorSpec::BlockSign { block: 4096 },
            });
        }
        if let Some(rest) = s.strip_prefix("qadam-") {
            return Ok(AlgoSpec::QAdam { compressor: CompressorSpec::parse(rest)? });
        }
        if s == "1bitadam" {
            return Ok(AlgoSpec::OneBitAdam { warmup_rounds: 0, block: 4096 });
        }
        if let Some(rest) = s.strip_prefix("1bitadam:") {
            return Ok(AlgoSpec::OneBitAdam { warmup_rounds: rest.parse()?, block: 4096 });
        }
        if s == "dist-sgd" {
            return Ok(AlgoSpec::DistSgd { momentum: 0.9 });
        }
        bail!(
            "unknown algorithm '{s}' (dist-ams | comp-ams-<compressor> | qadam | \
             1bitadam[:warmup] | dist-sgd)"
        )
    }

    /// Instantiate for `n` workers over a `dim`-dimensional model.
    /// `total_rounds` lets 1BitAdam derive its warm-up from the schedule
    /// (paper: 1/20 of total epochs) when the spec says 0.
    pub fn build(&self, dim: usize, n: usize, total_rounds: u64) -> Protocol {
        self.build_fused(dim, n, total_rounds, None)
    }

    /// Like [`AlgoSpec::build`], but routes AMSGrad-family server updates
    /// through the Pallas fused-update artifact when one is supplied.
    /// Protocols whose server is not AMSGrad ignore `fused`.
    pub fn build_fused(
        &self,
        dim: usize,
        n: usize,
        total_rounds: u64,
        fused: Option<Rc<OptimizerExe>>,
    ) -> Protocol {
        match self {
            AlgoSpec::DistAms => comp_ams::protocol(
                dim,
                n,
                CompressorSpec::Identity,
                false,
                "dist-ams",
                fused,
            ),
            AlgoSpec::CompAms { compressor, error_feedback } => comp_ams::protocol(
                dim,
                n,
                compressor.clone(),
                *error_feedback,
                "comp-ams",
                fused,
            ),
            AlgoSpec::QAdam { compressor } => qadam::protocol(dim, n, compressor.clone()),
            AlgoSpec::OneBitAdam { warmup_rounds, block } => onebit_adam::protocol(
                dim,
                n,
                resolve_warmup(*warmup_rounds, total_rounds),
                *block,
            ),
            AlgoSpec::DistSgd { momentum } => dist_sgd::protocol(dim, n, *momentum),
        }
    }

    /// Build just the server half over a `dim`-slice of θ, without fused
    /// routing. Unlike [`AlgoSpec::build_fused`], the result is `Send`:
    /// this is the per-shard constructor [`sharded::ShardedServer`] uses
    /// to move shard optimizers onto leader-side threads. Server state is
    /// per-coordinate for every protocol, so S shard servers over a
    /// contiguous partition reproduce the unsharded trajectory bitwise.
    pub fn build_server(
        &self,
        dim: usize,
        total_rounds: u64,
    ) -> Box<dyn ServerAlgo + Send> {
        match self {
            AlgoSpec::DistAms => {
                Box::new(comp_ams::server(dim, &CompressorSpec::Identity, "dist-ams"))
            }
            AlgoSpec::CompAms { compressor, .. } => {
                Box::new(comp_ams::server(dim, compressor, "comp-ams"))
            }
            AlgoSpec::QAdam { compressor } => {
                Box::new(QAdamServer::new(compressor.build().name()))
            }
            AlgoSpec::OneBitAdam { warmup_rounds, .. } => Box::new(
                OneBitAdamServer::new(dim, resolve_warmup(*warmup_rounds, total_rounds)),
            ),
            AlgoSpec::DistSgd { momentum } => {
                Box::new(DistSgdServer::new(dim, *momentum))
            }
        }
    }

    /// Size in bits of one worker's error-feedback accumulator (a dense
    /// f32 `e ∈ R^dim`) under this protocol — the state that is
    /// irrecoverably lost when a worker process dies and a replacement
    /// rejoins with `e = 0`. Zero for protocols that keep no worker-side
    /// residual (dist-ams, dist-sgd, comp-ams with `:noef`). The cluster
    /// runtime charges this to [`CommLedger::ef_residual_lost_bits`]
    /// (crate::coordinator::comm::CommLedger) per death so runs with
    /// crashes report the dropped gradient mass instead of hiding it.
    pub fn ef_state_bits(&self, dim: usize) -> u64 {
        let has_ef = match self {
            AlgoSpec::DistAms | AlgoSpec::DistSgd { .. } => false,
            AlgoSpec::CompAms { error_feedback, .. } => *error_feedback,
            // QAdam and 1BitAdam always run error feedback.
            AlgoSpec::QAdam { .. } | AlgoSpec::OneBitAdam { .. } => true,
        };
        if has_ef { 32 * dim as u64 } else { 0 }
    }
}

/// 1BitAdam warm-up horizon: the spec value, or — when the spec says 0 —
/// 1/20 of the training budget (paper §5.1).
fn resolve_warmup(spec_rounds: u64, total_rounds: u64) -> u64 {
    if spec_rounds == 0 {
        (total_rounds / 20).max(1)
    } else {
        spec_rounds
    }
}

/// Give stateful compressors (Random-k, QSGD) distinct streams per worker;
/// deterministic compressors are cloned as-is.
pub(crate) fn per_worker_spec(spec: &CompressorSpec, wid: usize) -> CompressorSpec {
    match spec {
        CompressorSpec::RandomK { ratio, seed } => CompressorSpec::RandomK {
            ratio: *ratio,
            seed: seed ^ (wid as u64 + 1),
        },
        CompressorSpec::Qsgd { levels, seed } => CompressorSpec::Qsgd {
            levels: *levels,
            seed: seed ^ (wid as u64 + 1),
        },
        c => c.clone(),
    }
}

/// Average the decoded payloads into a dense gradient (shared helper).
pub fn average_payloads(
    msgs: &[PayloadView<'_>],
    dim: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    out.clear();
    out.resize(dim, 0.0);
    for m in msgs {
        m.add_into(out)?;
    }
    let inv = 1.0 / msgs.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    Ok(())
}

/// The accepted `--robust-agg` spellings, enumerated in every parse and
/// validation error.
pub const AGG_CHOICES: &str = "mean | median | trimmed:<k>";

/// Batch-aggregation estimator applied by a [`ServerAlgo`] to each round's
/// decoded uplink gradients (`--robust-agg`).
///
/// `Mean` is the paper's `(1/m) Σ_i C(g_i)`. `Median` and `Trimmed(k)`
/// are the classical coordinate-wise byzantine-tolerant estimators: the
/// per-coordinate median of the batch, and the per-coordinate mean after
/// dropping the `k` smallest and `k` largest values. Both are pure
/// functions of the sorted batch (ties broken by `f32::total_cmp`), so
/// robust runs stay bit-for-bit reproducible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggMode {
    Mean,
    Median,
    /// Coordinate-wise trimmed mean dropping the `k` extremes per side.
    Trimmed(usize),
}

impl AggMode {
    /// Parse `mean`, `median`, or `trimmed:<k>` (k ≥ 1). The empty string
    /// means `mean` (unset config field).
    pub fn parse(s: &str) -> Result<AggMode> {
        match s {
            "" | "mean" => Ok(AggMode::Mean),
            "median" => Ok(AggMode::Median),
            other => {
                if let Some(k_str) = other.strip_prefix("trimmed:") {
                    let k: usize = k_str.parse().map_err(|_| {
                        anyhow!(
                            "bad trim count '{k_str}' in robust-agg '{other}' \
                             (accepted forms: {AGG_CHOICES})"
                        )
                    })?;
                    ensure!(
                        k >= 1,
                        "trimmed:<k> needs k >= 1 (trimmed:0 is just 'mean'; \
                         accepted forms: {AGG_CHOICES})"
                    );
                    return Ok(AggMode::Trimmed(k));
                }
                bail!("unknown robust-agg '{other}' (accepted forms: {AGG_CHOICES})")
            }
        }
    }
}

impl std::fmt::Display for AggMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AggMode::Mean => write!(f, "mean"),
            AggMode::Median => write!(f, "median"),
            AggMode::Trimmed(k) => write!(f, "trimmed:{k}"),
        }
    }
}

/// Aggregate the decoded payloads into a dense gradient under `mode`.
/// [`AggMode::Mean`] delegates to [`average_payloads`] (sparse payloads
/// are accumulated without densifying); the robust estimators decode each
/// message to dense and sort per coordinate. When the batch `m` is too
/// small for `Trimmed(k)` to keep anything (`m ≤ 2k`), `k` is clamped to
/// `(m - 1) / 2` — the estimator degrades toward the median rather than
/// producing an empty mean. `TrainConfig::validate` rejects configs whose
/// *quorum* batch would need the clamp, so it only engages on transient
/// short batches (crashed workers below quorum).
pub fn aggregate_payloads(
    msgs: &[PayloadView<'_>],
    dim: usize,
    out: &mut Vec<f32>,
    mode: AggMode,
) -> Result<()> {
    if mode == AggMode::Mean {
        return average_payloads(msgs, dim, out);
    }
    ensure!(!msgs.is_empty(), "robust aggregation over an empty batch");
    let dense: Vec<Vec<f32>> = msgs.iter().map(|m| m.to_dense(dim)).collect::<Result<_>>()?;
    let m = dense.len();
    out.clear();
    out.resize(dim, 0.0);
    let mut col = vec![0.0f32; m];
    for j in 0..dim {
        for (i, g) in dense.iter().enumerate() {
            col[i] = g[j];
        }
        col.sort_by(|a, b| a.total_cmp(b));
        out[j] = match mode {
            AggMode::Mean => unreachable!("mean handled above"),
            AggMode::Median => {
                if m % 2 == 1 {
                    col[m / 2]
                } else {
                    0.5 * (col[m / 2 - 1] + col[m / 2])
                }
            }
            AggMode::Trimmed(k) => {
                let k = k.min((m - 1) / 2);
                let kept = &col[k..m - k];
                kept.iter().sum::<f32>() / kept.len() as f32
            }
        };
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::as_views;

    #[test]
    fn parse_all_forms() {
        assert_eq!(AlgoSpec::parse("dist-ams").unwrap(), AlgoSpec::DistAms);
        assert_eq!(
            AlgoSpec::parse("comp-ams-topk:0.01").unwrap(),
            AlgoSpec::CompAms {
                compressor: CompressorSpec::TopK { ratio: 0.01 },
                error_feedback: true
            }
        );
        assert_eq!(
            AlgoSpec::parse("comp-ams-topk:0.01:noef").unwrap(),
            AlgoSpec::CompAms {
                compressor: CompressorSpec::TopK { ratio: 0.01 },
                error_feedback: false
            }
        );
        assert!(matches!(AlgoSpec::parse("qadam").unwrap(), AlgoSpec::QAdam { .. }));
        assert_eq!(
            AlgoSpec::parse("1bitadam:50").unwrap(),
            AlgoSpec::OneBitAdam { warmup_rounds: 50, block: 4096 }
        );
        assert!(AlgoSpec::parse("nope").is_err());
    }

    #[test]
    fn ef_state_bits_tracks_error_feedback() {
        let d = 256;
        for (algo, bits) in [
            ("dist-ams", 0),
            ("dist-sgd", 0),
            ("comp-ams-topk:0.01", 32 * 256),
            ("comp-ams-topk:0.01:noef", 0),
            ("qadam", 32 * 256),
            ("1bitadam:50", 32 * 256),
        ] {
            assert_eq!(
                AlgoSpec::parse(algo).unwrap().ef_state_bits(d),
                bits,
                "{algo}"
            );
        }
    }

    #[test]
    fn average_payloads_mixed_kinds() {
        let msgs = vec![
            Payload::Dense(vec![2.0, 0.0, 0.0]),
            Payload::Sparse { dim: 3, idx: vec![1], val: vec![4.0] },
        ];
        let mut out = Vec::new();
        average_payloads(&as_views(&msgs), 3, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn agg_mode_parses_and_rejects() {
        assert_eq!(AggMode::parse("").unwrap(), AggMode::Mean);
        assert_eq!(AggMode::parse("mean").unwrap(), AggMode::Mean);
        assert_eq!(AggMode::parse("median").unwrap(), AggMode::Median);
        assert_eq!(AggMode::parse("trimmed:2").unwrap(), AggMode::Trimmed(2));
        assert_eq!(AggMode::Trimmed(2).to_string(), "trimmed:2");
        for bad in ["trim", "trimmed", "trimmed:", "trimmed:x", "trimmed:0", "avg"] {
            let err = AggMode::parse(bad).unwrap_err().to_string();
            assert!(err.contains(AGG_CHOICES), "{bad}: {err}");
        }
    }

    #[test]
    fn median_and_trimmed_mean_per_coordinate() {
        // Three honest gradients plus one adversarial outlier.
        let msgs = vec![
            Payload::Dense(vec![1.0, -2.0]),
            Payload::Dense(vec![1.2, -2.2]),
            Payload::Dense(vec![0.8, -1.8]),
            Payload::Dense(vec![-100.0, 100.0]),
        ];
        let mut out = Vec::new();
        let views = as_views(&msgs);
        // Even batch: median is the mean of the middle two order stats.
        aggregate_payloads(&views, 2, &mut out, AggMode::Median).unwrap();
        assert_eq!(out, vec![0.5 * (0.8 + 1.0), 0.5 * (-2.2 + -2.0)]);
        // Trimmed:1 drops the outlier (and one honest extreme) per side.
        aggregate_payloads(&views, 2, &mut out, AggMode::Trimmed(1)).unwrap();
        assert_eq!(out, vec![0.5 * (0.8 + 1.0), 0.5 * (-2.2 + -2.0)]);
        // Odd batch: exact middle order statistic.
        aggregate_payloads(&views[..3], 2, &mut out, AggMode::Median).unwrap();
        assert_eq!(out, vec![1.0, -2.0]);
        // Mean delegates to average_payloads (handles sparse unchanged).
        aggregate_payloads(&views[..3], 2, &mut out, AggMode::Mean).unwrap();
        let mut avg = Vec::new();
        average_payloads(&views[..3], 2, &mut avg).unwrap();
        assert_eq!(out, avg);
    }

    #[test]
    fn trimmed_mean_clamps_on_short_batches() {
        // m = 2 with k = 1 would keep nothing; the clamp degrades to
        // (m-1)/2 = 0 trims, i.e. the plain mean of the short batch.
        let msgs =
            vec![Payload::Dense(vec![1.0]), Payload::Dense(vec![3.0])];
        let mut out = Vec::new();
        aggregate_payloads(&as_views(&msgs), 1, &mut out, AggMode::Trimmed(1)).unwrap();
        assert_eq!(out, vec![2.0]);
        assert!(aggregate_payloads(&[], 1, &mut out, AggMode::Median).is_err());
    }

    #[test]
    fn default_set_agg_mode_accepts_mean_only() {
        struct Plain;
        impl ServerAlgo for Plain {
            fn name(&self) -> String {
                "plain".into()
            }
            fn step(
                &mut self,
                _theta: &mut [f32],
                _msgs: &[PayloadView<'_>],
                _ctx: &RoundCtx,
            ) -> Result<()> {
                Ok(())
            }
        }
        let mut s = Plain;
        assert!(s.set_agg_mode(AggMode::Mean).is_ok());
        let err = s.set_agg_mode(AggMode::Median).unwrap_err().to_string();
        assert!(err.contains("plain") && err.contains(AGG_CHOICES), "{err}");
    }

    #[test]
    fn build_yields_one_worker_half_per_worker() {
        let (workers, server) = AlgoSpec::DistAms.build(10, 2, 100);
        assert_eq!(workers.len(), 2);
        assert_eq!(server.name(), "dist-ams");
        let (workers, server) =
            AlgoSpec::parse("comp-ams-topk:0.01").unwrap().build(10, 3, 100);
        assert_eq!(workers.len(), 3);
        assert!(server.name().contains("topk"));
    }

    #[test]
    fn worker_halves_are_send() {
        fn assert_send<T: Send + ?Sized>() {}
        assert_send::<dyn WorkerAlgo>();
        assert_send::<Box<dyn WorkerAlgo>>();
    }

    #[test]
    fn build_server_matches_full_build_name_per_protocol() {
        for spec_str in
            ["dist-ams", "comp-ams-topk:0.01", "qadam", "1bitadam:50", "dist-sgd"]
        {
            let spec = AlgoSpec::parse(spec_str).unwrap();
            let (_, full) = spec.build(10, 2, 100);
            // The Send bound is part of the signature (compile-time check).
            let shard: Box<dyn ServerAlgo + Send> = spec.build_server(10, 100);
            assert_eq!(shard.name(), full.name(), "{spec_str}");
            assert!(shard.shard_stats().is_none());
        }
        // `1bitadam` (warmup 0) derives its warm-up from the schedule the
        // same way in both constructors.
        let spec = AlgoSpec::parse("1bitadam").unwrap();
        assert_eq!(
            spec.build_server(10, 200).name(),
            spec.build(10, 2, 200).1.name()
        );
    }

    #[test]
    fn per_worker_spec_salts_stateful_compressors() {
        let rk = CompressorSpec::RandomK { ratio: 0.1, seed: 7 };
        assert_ne!(per_worker_spec(&rk, 0), per_worker_spec(&rk, 1));
        let tk = CompressorSpec::TopK { ratio: 0.1 };
        assert_eq!(per_worker_spec(&tk, 0), per_worker_spec(&tk, 1));
    }
}
