//! Distributed optimization protocols — the paper's Algorithm 2 and every
//! baseline in its evaluation (§5.1):
//!
//! | name            | worker uplink                    | server update            |
//! |-----------------|----------------------------------|--------------------------|
//! | `dist-ams`      | dense gradient                   | AMSGrad                  |
//! | `comp-ams-*`    | C(g + e) with error feedback     | AMSGrad (state on server)|
//! | `qadam`         | C(m/√v) with EF (local m, v)     | lr · avg ratio           |
//! | `1bitadam`      | dense g (warm-up) then C(m) + EF | Adam, then frozen-v momentum |
//! | `dist-sgd`      | dense gradient                   | (momentum) SGD           |
//!
//! A protocol is a single [`Algorithm`] object: `worker_msg` is the code
//! that would run on worker i (its per-worker state is indexed by `wid`),
//! `server_step` is the leader. The coordinator routes payloads between
//! them and charges the byte ledger.

pub mod comp_ams;
pub mod dist_sgd;
pub mod onebit_adam;
pub mod qadam;

pub use comp_ams::CompAms;
pub use dist_sgd::DistSgd;
pub use onebit_adam::OneBitAdam;
pub use qadam::QAdam;

use anyhow::{bail, Result};

use crate::compress::{CompressorSpec, Payload};

/// Per-round context handed to both sides of the protocol.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    pub round: u64,
    pub lr: f32,
}

pub trait Algorithm {
    fn name(&self) -> String;

    /// Worker `wid` turns its raw stochastic gradient into the uplink
    /// message (compression + any worker-local state updates).
    fn worker_msg(&mut self, wid: usize, grad: &[f32], ctx: &RoundCtx) -> Result<Payload>;

    /// The leader consumes all n uplink messages and updates `theta`.
    fn server_step(&mut self, theta: &mut [f32], msgs: &[Payload], ctx: &RoundCtx)
        -> Result<()>;

    /// Extra per-worker memory (bytes) beyond the error accumulator —
    /// the paper's §3.2 memory-footprint comparison.
    fn worker_state_bytes(&self) -> usize {
        0
    }
}

/// Parsed protocol spec (from CLI/config strings).
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoSpec {
    DistAms,
    CompAms { compressor: CompressorSpec, error_feedback: bool },
    QAdam { compressor: CompressorSpec },
    OneBitAdam { warmup_rounds: u64, block: usize },
    DistSgd { momentum: f32 },
}

impl AlgoSpec {
    /// Parse e.g. `dist-ams`, `comp-ams-topk:0.01`, `comp-ams-blocksign:4096`,
    /// `comp-ams-topk:0.01:noef`, `qadam`, `1bitadam:100`, `dist-sgd`.
    pub fn parse(s: &str) -> Result<AlgoSpec> {
        if s == "dist-ams" {
            return Ok(AlgoSpec::DistAms);
        }
        if let Some(rest) = s.strip_prefix("comp-ams-") {
            let (comp_str, noef) = match rest.strip_suffix(":noef") {
                Some(c) => (c, true),
                None => (rest, false),
            };
            return Ok(AlgoSpec::CompAms {
                compressor: CompressorSpec::parse(comp_str)?,
                error_feedback: !noef,
            });
        }
        if s == "qadam" {
            // QAdam's published variant is 1-bit; blocksign over the ratio.
            return Ok(AlgoSpec::QAdam {
                compressor: CompressorSpec::BlockSign { block: 4096 },
            });
        }
        if let Some(rest) = s.strip_prefix("qadam-") {
            return Ok(AlgoSpec::QAdam { compressor: CompressorSpec::parse(rest)? });
        }
        if s == "1bitadam" {
            return Ok(AlgoSpec::OneBitAdam { warmup_rounds: 0, block: 4096 });
        }
        if let Some(rest) = s.strip_prefix("1bitadam:") {
            return Ok(AlgoSpec::OneBitAdam { warmup_rounds: rest.parse()?, block: 4096 });
        }
        if s == "dist-sgd" {
            return Ok(AlgoSpec::DistSgd { momentum: 0.9 });
        }
        bail!(
            "unknown algorithm '{s}' (dist-ams | comp-ams-<compressor> | qadam | \
             1bitadam[:warmup] | dist-sgd)"
        )
    }

    /// Instantiate for `n` workers over a `dim`-dimensional model.
    /// `warmup_override` lets the trainer set 1BitAdam's warm-up from the
    /// schedule (paper: 1/20 of total epochs) when the spec says 0.
    pub fn build(&self, dim: usize, n: usize, total_rounds: u64) -> Box<dyn Algorithm> {
        match self {
            AlgoSpec::DistAms => Box::new(CompAms::new(
                dim,
                n,
                CompressorSpec::Identity,
                false,
                "dist-ams",
            )),
            AlgoSpec::CompAms { compressor, error_feedback } => Box::new(CompAms::new(
                dim,
                n,
                compressor.clone(),
                *error_feedback,
                "comp-ams",
            )),
            AlgoSpec::QAdam { compressor } => {
                Box::new(QAdam::new(dim, n, compressor.clone()))
            }
            AlgoSpec::OneBitAdam { warmup_rounds, block } => {
                let warmup = if *warmup_rounds == 0 {
                    // Paper §5.1: warm-up = 1/20 of the training budget.
                    (total_rounds / 20).max(1)
                } else {
                    *warmup_rounds
                };
                Box::new(OneBitAdam::new(dim, n, warmup, *block))
            }
            AlgoSpec::DistSgd { momentum } => Box::new(DistSgd::new(dim, *momentum)),
        }
    }
}

/// Average the decoded payloads into a dense gradient (shared helper).
pub fn average_payloads(msgs: &[Payload], dim: usize, out: &mut Vec<f32>) -> Result<()> {
    out.clear();
    out.resize(dim, 0.0);
    for m in msgs {
        m.add_into(out)?;
    }
    let inv = 1.0 / msgs.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(AlgoSpec::parse("dist-ams").unwrap(), AlgoSpec::DistAms);
        assert_eq!(
            AlgoSpec::parse("comp-ams-topk:0.01").unwrap(),
            AlgoSpec::CompAms {
                compressor: CompressorSpec::TopK { ratio: 0.01 },
                error_feedback: true
            }
        );
        assert_eq!(
            AlgoSpec::parse("comp-ams-topk:0.01:noef").unwrap(),
            AlgoSpec::CompAms {
                compressor: CompressorSpec::TopK { ratio: 0.01 },
                error_feedback: false
            }
        );
        assert!(matches!(AlgoSpec::parse("qadam").unwrap(), AlgoSpec::QAdam { .. }));
        assert_eq!(
            AlgoSpec::parse("1bitadam:50").unwrap(),
            AlgoSpec::OneBitAdam { warmup_rounds: 50, block: 4096 }
        );
        assert!(AlgoSpec::parse("nope").is_err());
    }

    #[test]
    fn average_payloads_mixed_kinds() {
        let msgs = vec![
            Payload::Dense(vec![2.0, 0.0, 0.0]),
            Payload::Sparse { dim: 3, idx: vec![1], val: vec![4.0] },
        ];
        let mut out = Vec::new();
        average_payloads(&msgs, 3, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn build_names() {
        assert_eq!(AlgoSpec::DistAms.build(10, 2, 100).name(), "dist-ams");
        assert!(AlgoSpec::parse("comp-ams-topk:0.01")
            .unwrap()
            .build(10, 2, 100)
            .name()
            .contains("topk"));
    }
}
