//! Sharded server: split θ across S parallel [`ServerAlgo`] shards.
//!
//! PR 1 moved the whole worker pipeline onto worker threads, which leaves
//! the leader's dense server update as the serial bottleneck (Amdahl). The
//! fix is the classic parameter-server partition: θ is cut into S
//! contiguous shards, each shard gets its **own** server optimizer built
//! by [`AlgoSpec::build_server`], each round's worker payloads are sliced
//! per shard with [`Payload::slice_range`], and the S shard updates run
//! either sequentially or on a pool of persistent leader-side shard
//! threads — mirroring the sequential/threaded [`WorkerPool`] backends.
//!
//! Correctness rests on two facts, both asserted by tests:
//!
//! 1. **Slicing is exact**: decoding a payload slice is bitwise identical
//!    to slicing the full decode (see `compress::wire`).
//! 2. **Server state is per-coordinate**: AMSGrad/Adam moments, the
//!    1BitAdam preconditioner, and SGD velocity never mix coordinates,
//!    and every cross-shard scalar (round counter, lr, 1/n averaging
//!    weight) comes from the shared [`RoundCtx`]. So S shard optimizers
//!    over a contiguous partition walk exactly the trajectory of one
//!    full-θ optimizer — S=1, sequential-S, and threaded-S are all
//!    bitwise identical.
//!
//! This is also the architectural step toward multi-process parameter
//! serving: each shard already sees only its own `(θ-slice, payload
//! slices)` view, so a shard can later move behind a channel or socket
//! without touching the protocol code.
//!
//! [`WorkerPool`]: crate::coordinator::cluster::WorkerPool

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use crate::compress::{as_views, Payload, PayloadView};
use crate::util::timer::Stopwatch;

use super::{AggMode, AlgoSpec, RoundCtx, ServerAlgo};

/// Fenceposts of a contiguous partition of `0..dim` into `shards` ranges
/// whose lengths differ by at most one (the first `dim % shards` shards
/// take the extra coordinate). Returns `shards + 1` offsets starting at 0
/// and ending at `dim`.
pub fn shard_bounds(dim: usize, shards: usize) -> Vec<usize> {
    assert!(shards >= 1 && shards <= dim, "bad partition: {shards} shards of {dim}");
    let base = dim / shards;
    let rem = dim % shards;
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0);
    let mut off = 0;
    for s in 0..shards {
        off += base + usize::from(s < rem);
        bounds.push(off);
    }
    bounds
}

/// Cumulative per-shard accounting, surfaced through
/// [`ServerAlgo::shard_stats`] into the `CommLedger` / `RunResult`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// The `S + 1` fenceposts of the θ partition ([`shard_bounds`]).
    pub bounds: Vec<usize>,
    /// Cumulative wire bits of the sliced payloads routed to each shard —
    /// what each shard's future standalone process would receive on its
    /// uplink once shards live behind real transport.
    pub routed_bits: Vec<u64>,
    /// Cumulative wall-clock ms spent inside each shard's `step`
    /// (measured on the shard thread in the threaded backend).
    pub step_ms: Vec<f64>,
}

impl ShardStats {
    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }
}

enum Cmd {
    Step { theta: Vec<f32>, msgs: Vec<Payload>, ctx: RoundCtx },
    Export { reply: Sender<Result<Vec<u8>>> },
    Import { bytes: Vec<u8>, reply: Sender<Result<()>> },
    SetAgg { mode: AggMode, reply: Sender<Result<()>> },
    SetPre { pre: bool, reply: Sender<()> },
    Stop,
}

struct Reply {
    theta: Vec<f32>,
    ms: f64,
}

struct ShardHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Result<Reply>>,
    join: Option<JoinHandle<()>>,
}

/// One persistent leader-side thread owning one shard's server optimizer.
/// The thread receives this round's θ-slice and sliced payloads, runs the
/// shard update, and sends the updated slice back.
fn spawn_shard(sid: usize, mut server: Box<dyn ServerAlgo + Send>) -> ShardHandle {
    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (rep_tx, rep_rx) = channel::<Result<Reply>>();
    let join = std::thread::Builder::new()
        .name(format!("shard-{sid}"))
        .spawn(move || {
            while let Ok(cmd) = cmd_rx.recv() {
                match cmd {
                    Cmd::Step { mut theta, msgs, ctx } => {
                        let sw = Stopwatch::start();
                        let res = server.step(&mut theta, &as_views(&msgs), &ctx);
                        let reply = res.map(|()| Reply { theta, ms: sw.ms() });
                        if rep_tx.send(reply).is_err() {
                            break;
                        }
                    }
                    Cmd::Export { reply } => {
                        if reply.send(server.export_state()).is_err() {
                            break;
                        }
                    }
                    Cmd::Import { bytes, reply } => {
                        if reply.send(server.import_state(&bytes)).is_err() {
                            break;
                        }
                    }
                    Cmd::SetAgg { mode, reply } => {
                        if reply.send(server.set_agg_mode(mode)).is_err() {
                            break;
                        }
                    }
                    Cmd::SetPre { pre, reply } => {
                        server.set_pre_aggregated(pre);
                        if reply.send(()).is_err() {
                            break;
                        }
                    }
                    Cmd::Stop => break,
                }
            }
        })
        .expect("spawn shard thread");
    ShardHandle { tx: cmd_tx, rx: rep_rx, join: Some(join) }
}

enum Backend {
    Sequential(Vec<Box<dyn ServerAlgo + Send>>),
    Threaded(Vec<ShardHandle>),
}

/// A [`ServerAlgo`] that partitions θ into S contiguous shards, each with
/// its own independently-built server half, and routes every worker
/// payload to each shard as a [`Payload::slice_range`] slice. See the
/// module docs for why this is bitwise-exact.
pub struct ShardedServer {
    name: String,
    backend: Backend,
    stats: ShardStats,
    /// Set when a step errored partway (e.g. a shard thread died after
    /// some shards were already dispatched or updated): θ and the queued
    /// shard replies are then out of sync with the next round, so every
    /// later step refuses to run instead of silently pairing a stale
    /// reply with a fresh dispatch.
    poisoned: bool,
}

impl ShardedServer {
    /// Partition `dim` coordinates into `shards` and build one server
    /// half per shard from `spec`. `threaded` selects the persistent
    /// shard-thread backend (trajectories are identical either way).
    ///
    /// Fails if `shards` is 0 or exceeds `dim`. Fused Pallas routing is
    /// deliberately not supported here — the fused executable is compiled
    /// for full-θ shapes (the config layer rejects that combination).
    pub fn new(
        spec: &AlgoSpec,
        dim: usize,
        total_rounds: u64,
        shards: usize,
        threaded: bool,
    ) -> Result<ShardedServer> {
        ensure!(shards >= 1, "server shards must be >= 1");
        ensure!(
            shards <= dim,
            "more server shards ({shards}) than model coordinates ({dim})"
        );
        let bounds = shard_bounds(dim, shards);
        let servers: Vec<Box<dyn ServerAlgo + Send>> = (0..shards)
            .map(|s| spec.build_server(bounds[s + 1] - bounds[s], total_rounds))
            .collect();
        let name = servers[0].name();
        let stats = ShardStats {
            bounds,
            routed_bits: vec![0; shards],
            step_ms: vec![0.0; shards],
        };
        let backend = if threaded {
            Backend::Threaded(
                servers
                    .into_iter()
                    .enumerate()
                    .map(|(s, srv)| spawn_shard(s, srv))
                    .collect(),
            )
        } else {
            Backend::Sequential(servers)
        };
        Ok(ShardedServer { name, backend, stats, poisoned: false })
    }

    pub fn shards(&self) -> usize {
        self.stats.shards()
    }

    pub fn is_threaded(&self) -> bool {
        matches!(self.backend, Backend::Threaded(_))
    }
}

impl ServerAlgo for ShardedServer {
    /// The protocol name is the per-shard server's name (all shards agree)
    /// so sharding never changes how a run is labelled in results.
    fn name(&self) -> String {
        self.name.clone()
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        ensure!(
            !self.poisoned,
            "sharded server poisoned by an earlier partial-step error; rebuild it"
        );
        let out = self.step_inner(theta, msgs, ctx);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn shard_stats(&self) -> Option<&ShardStats> {
        Some(&self.stats)
    }

    /// Forward the estimator to every shard. Coordinate-wise median and
    /// trimmed mean commute with the contiguous θ partition (each shard
    /// sorts only its own coordinates), so a robust sharded server stays
    /// bitwise identical to the robust unsharded one.
    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        match &mut self.backend {
            Backend::Sequential(servers) => {
                for s in servers {
                    s.set_agg_mode(mode)?;
                }
            }
            Backend::Threaded(handles) => {
                let mut rxs = Vec::with_capacity(handles.len());
                for h in handles.iter() {
                    let (tx, rx) = channel();
                    h.tx
                        .send(Cmd::SetAgg { mode, reply: tx })
                        .map_err(|_| anyhow!("shard thread died"))?;
                    rxs.push(rx);
                }
                for rx in rxs {
                    rx.recv().map_err(|_| anyhow!("shard thread died"))??;
                }
            }
        }
        Ok(())
    }

    /// Forward the pre-aggregated flag to every shard (each shard sees
    /// the same forwarded group means, sliced to its θ range).
    fn set_pre_aggregated(&mut self, pre: bool) {
        match &mut self.backend {
            Backend::Sequential(servers) => {
                for s in servers {
                    s.set_pre_aggregated(pre);
                }
            }
            Backend::Threaded(handles) => {
                let mut rxs = Vec::with_capacity(handles.len());
                for h in handles.iter() {
                    let (tx, rx) = channel();
                    if h.tx.send(Cmd::SetPre { pre, reply: tx }).is_err() {
                        continue;
                    }
                    rxs.push(rx);
                }
                for rx in rxs {
                    let _ = rx.recv();
                }
            }
        }
    }

    /// Concatenate every shard's state blob (length-prefixed, in shard
    /// order). Importing into a sharded server with the same partition
    /// restores each shard exactly; the partition itself is rebuilt from
    /// the config, so only per-shard optimizer state travels.
    fn export_state(&self) -> Result<Vec<u8>> {
        ensure!(
            !self.poisoned,
            "sharded server poisoned by an earlier partial-step error; refusing to export"
        );
        let mut out = Vec::new();
        match &self.backend {
            Backend::Sequential(servers) => {
                for s in servers {
                    crate::util::bytes::put_bytes(&mut out, &s.export_state()?);
                }
            }
            Backend::Threaded(handles) => {
                // Dispatch to all shards first, then collect, so export
                // runs in parallel like a step.
                let mut rxs = Vec::with_capacity(handles.len());
                for h in handles {
                    let (tx, rx) = channel();
                    h.tx
                        .send(Cmd::Export { reply: tx })
                        .map_err(|_| anyhow!("shard thread died"))?;
                    rxs.push(rx);
                }
                for rx in rxs {
                    let blob = rx.recv().map_err(|_| anyhow!("shard thread died"))??;
                    crate::util::bytes::put_bytes(&mut out, &blob);
                }
            }
        }
        Ok(out)
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let shards = self.stats.shards();
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let mut blobs = Vec::with_capacity(shards);
        for _ in 0..shards {
            blobs.push(c.bytes()?.to_vec());
        }
        c.finish()?;
        match &mut self.backend {
            Backend::Sequential(servers) => {
                for (s, blob) in servers.iter_mut().zip(blobs) {
                    s.import_state(&blob)?;
                }
            }
            Backend::Threaded(handles) => {
                let mut rxs = Vec::with_capacity(handles.len());
                for (h, blob) in handles.iter().zip(blobs) {
                    let (tx, rx) = channel();
                    h.tx
                        .send(Cmd::Import { bytes: blob, reply: tx })
                        .map_err(|_| anyhow!("shard thread died"))?;
                    rxs.push(rx);
                }
                for rx in rxs {
                    rx.recv().map_err(|_| anyhow!("shard thread died"))??;
                }
            }
        }
        Ok(())
    }
}

impl ShardedServer {
    fn step_inner(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let bounds = self.stats.bounds.clone();
        let dim = *bounds.last().unwrap();
        ensure!(
            theta.len() == dim,
            "sharded server built for dim {dim}, got θ of {}",
            theta.len()
        );
        let shards = bounds.len() - 1;

        // Route: split every worker payload across all shard ranges in
        // one pass (`slice_into_shards` — sorted sparse payloads walk
        // their k indices once instead of once per shard).
        let mut routed: Vec<Vec<Payload>> =
            (0..shards).map(|_| Vec::with_capacity(msgs.len())).collect();
        for m in msgs {
            for (s, slice) in m.slice_into_shards(&bounds)?.into_iter().enumerate() {
                self.stats.routed_bits[s] += slice.wire_bits();
                routed[s].push(slice);
            }
        }

        match &mut self.backend {
            Backend::Sequential(servers) => {
                for (s, (server, sub)) in servers.iter_mut().zip(routed).enumerate() {
                    let sw = Stopwatch::start();
                    server.step(&mut theta[bounds[s]..bounds[s + 1]], &as_views(&sub), ctx)?;
                    self.stats.step_ms[s] += sw.ms();
                }
            }
            Backend::Threaded(handles) => {
                for (s, (h, sub)) in handles.iter().zip(routed).enumerate() {
                    let slice = theta[bounds[s]..bounds[s + 1]].to_vec();
                    h.tx
                        .send(Cmd::Step { theta: slice, msgs: sub, ctx: *ctx })
                        .map_err(|_| anyhow!("shard thread died"))?;
                }
                // Drain every shard's reply before surfacing any error —
                // a short-circuit would leave replies queued and silently
                // deliver them next round (same rationale as WorkerPool).
                let mut replies = Vec::with_capacity(handles.len());
                for h in handles.iter() {
                    replies
                        .push(h.rx.recv().map_err(|_| anyhow!("shard thread died"))?);
                }
                for (s, r) in replies.into_iter().enumerate() {
                    let Reply { theta: updated, ms } = r?;
                    theta[bounds[s]..bounds[s + 1]].copy_from_slice(&updated);
                    self.stats.step_ms[s] += ms;
                }
            }
        }
        Ok(())
    }
}

impl Drop for ShardedServer {
    fn drop(&mut self) {
        if let Backend::Threaded(handles) = &mut self.backend {
            for h in handles.iter() {
                let _ = h.tx.send(Cmd::Stop);
            }
            for h in handles.iter_mut() {
                if let Some(j) = h.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_partition_evenly_with_remainder_up_front() {
        assert_eq!(shard_bounds(10, 1), vec![0, 10]);
        assert_eq!(shard_bounds(10, 2), vec![0, 5, 10]);
        assert_eq!(shard_bounds(11, 3), vec![0, 4, 8, 11]);
        assert_eq!(shard_bounds(5, 5), vec![0, 1, 2, 3, 4, 5]);
        // Lengths differ by at most one and cover everything.
        let b = shard_bounds(1013, 7);
        assert_eq!(*b.last().unwrap(), 1013);
        let lens: Vec<usize> = b.windows(2).map(|w| w[1] - w[0]).collect();
        assert_eq!(lens.iter().sum::<usize>(), 1013);
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn rejects_zero_or_oversized_shard_counts() {
        let spec = AlgoSpec::parse("dist-sgd").unwrap();
        assert!(ShardedServer::new(&spec, 8, 100, 0, false).is_err());
        assert!(ShardedServer::new(&spec, 8, 100, 9, false).is_err());
    }

    /// Drive a full-θ server and a sharded server with identical message
    /// streams; trajectories must agree bitwise.
    fn assert_sharded_matches_unsharded(spec_str: &str, shards: usize, threaded: bool) {
        let dim = 37; // prime, so every shard count partitions unevenly
        let n = 3;
        let rounds = 25;
        let spec = AlgoSpec::parse(spec_str).unwrap();
        let run = |sharded: Option<(usize, bool)>| -> Vec<f32> {
            let (mut workers, full) = spec.build(dim, n, rounds);
            let mut server: Box<dyn ServerAlgo> = match sharded {
                None => full,
                Some((s, thr)) => {
                    Box::new(ShardedServer::new(&spec, dim, rounds, s, thr).unwrap())
                }
            };
            let mut theta: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
            for r in 0..rounds {
                let ctx = RoundCtx::sync(r, 0.02);
                // Deterministic per-worker pseudo-gradients.
                let msgs: Vec<Payload> = workers
                    .iter_mut()
                    .enumerate()
                    .map(|(w, wk)| {
                        let g: Vec<f32> = (0..dim)
                            .map(|i| ((r as usize * 31 + w * 7 + i) as f32 * 0.11).cos())
                            .collect();
                        wk.process(&g, &ctx).unwrap()
                    })
                    .collect();
                server.step(&mut theta, &as_views(&msgs), &ctx).unwrap();
            }
            theta
        };
        let a = run(None);
        let b = run(Some((shards, threaded)));
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{spec_str} S={shards} threaded={threaded}: θ[{i}] {x} vs {y}"
            );
        }
    }

    #[test]
    fn sharded_trajectory_is_bitwise_identical_across_protocols() {
        for spec_str in [
            "dist-ams",
            "comp-ams-topk:0.2",
            "comp-ams-blocksign:8",
            "qadam",
            "1bitadam:5",
            "dist-sgd",
        ] {
            assert_sharded_matches_unsharded(spec_str, 4, false);
            assert_sharded_matches_unsharded(spec_str, 4, true);
            assert_sharded_matches_unsharded(spec_str, 3, true); // 37 % 3 != 0
        }
    }

    #[test]
    fn robust_agg_shards_bitwise_like_mean() {
        // Median/trimmed are per-coordinate, so they must commute with
        // the contiguous partition exactly like the mean does.
        let dim = 23;
        let n = 5;
        let spec = AlgoSpec::parse("dist-ams").unwrap();
        for mode in [AggMode::Median, AggMode::Trimmed(1)] {
            for threaded in [false, true] {
                let run = |shards: Option<usize>| -> Vec<f32> {
                    let mut server: Box<dyn ServerAlgo> = match shards {
                        None => {
                            let (_, mut s) = spec.build(dim, n, 15);
                            s.set_agg_mode(mode).unwrap();
                            s
                        }
                        Some(s) => {
                            let mut srv =
                                ShardedServer::new(&spec, dim, 15, s, threaded).unwrap();
                            srv.set_agg_mode(mode).unwrap();
                            Box::new(srv)
                        }
                    };
                    let mut theta: Vec<f32> =
                        (0..dim).map(|i| (i as f32 * 0.41).sin()).collect();
                    for r in 0..15 {
                        let ctx = RoundCtx::sync(r, 0.02);
                        let msgs: Vec<Payload> = (0..n)
                            .map(|w| {
                                Payload::Dense(
                                    (0..dim)
                                        .map(|i| {
                                            ((r as usize * 31 + w * 7 + i) as f32 * 0.11)
                                                .cos()
                                        })
                                        .collect(),
                                )
                            })
                            .collect();
                        server.step(&mut theta, &as_views(&msgs), &ctx).unwrap();
                    }
                    theta
                };
                let a = run(None);
                let b = run(Some(4));
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{mode} threaded={threaded}: θ[{i}] {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn stats_track_bounds_bits_and_time() {
        let spec = AlgoSpec::parse("comp-ams-topk:0.5").unwrap();
        let (mut workers, _) = spec.build(16, 2, 10);
        let mut server = ShardedServer::new(&spec, 16, 10, 4, false).unwrap();
        assert_eq!(server.shards(), 4);
        assert!(!server.is_threaded());
        let mut theta = vec![0.1f32; 16];
        for r in 0..3 {
            let ctx = RoundCtx::sync(r, 0.01);
            let g = vec![1.0f32; 16];
            let msgs: Vec<Payload> =
                workers.iter_mut().map(|w| w.process(&g, &ctx).unwrap()).collect();
            server.step(&mut theta, &as_views(&msgs), &ctx).unwrap();
        }
        let stats = ServerAlgo::shard_stats(&server).unwrap();
        assert_eq!(stats.bounds, vec![0, 4, 8, 12, 16]);
        assert_eq!(stats.shards(), 4);
        assert!(stats.routed_bits.iter().all(|&b| b > 0));
        assert_eq!(stats.step_ms.len(), 4);
    }

    #[test]
    fn export_import_resumes_bitwise() {
        // Step 10 rounds, export, import into a fresh sharded server,
        // step 10 more; the trajectory must match an uninterrupted run.
        let dim = 19;
        let spec = AlgoSpec::parse("dist-ams").unwrap();
        let msgs_at = |r: u64| -> Vec<Payload> {
            (0..2usize)
                .map(|w| {
                    Payload::Dense(
                        (0..dim)
                            .map(|i| ((r as usize * 13 + w * 5 + i) as f32 * 0.17).sin())
                            .collect(),
                    )
                })
                .collect()
        };
        for threaded in [false, true] {
            let mut solo = ShardedServer::new(&spec, dim, 20, 3, threaded).unwrap();
            let mut t_solo: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.29).cos()).collect();
            let mut first = ShardedServer::new(&spec, dim, 20, 3, threaded).unwrap();
            let mut t_resume = t_solo.clone();
            for r in 0..10 {
                let ctx = RoundCtx::sync(r, 0.02);
                solo.step(&mut t_solo, &as_views(&msgs_at(r)), &ctx).unwrap();
                first.step(&mut t_resume, &as_views(&msgs_at(r)), &ctx).unwrap();
            }
            let blob = first.export_state().unwrap();
            drop(first);
            let mut second = ShardedServer::new(&spec, dim, 20, 3, threaded).unwrap();
            second.import_state(&blob).unwrap();
            for r in 10..20 {
                let ctx = RoundCtx::sync(r, 0.02);
                solo.step(&mut t_solo, &as_views(&msgs_at(r)), &ctx).unwrap();
                second.step(&mut t_resume, &as_views(&msgs_at(r)), &ctx).unwrap();
            }
            for (x, y) in t_solo.iter().zip(&t_resume) {
                assert_eq!(x.to_bits(), y.to_bits(), "threaded={threaded}");
            }
        }
    }

    #[test]
    fn wrong_theta_dim_is_rejected_and_poisons() {
        let spec = AlgoSpec::parse("dist-sgd").unwrap();
        let mut server = ShardedServer::new(&spec, 8, 10, 2, false).unwrap();
        let ctx = RoundCtx::sync(0, 0.01);
        let msgs = vec![Payload::Dense(vec![0.0; 8])];
        let mut theta = vec![0.0f32; 7];
        assert!(server.step(&mut theta, &as_views(&msgs), &ctx).is_err());
        // Any step error poisons the server: a partial threaded step
        // could have left shard replies queued, so later steps must
        // refuse instead of pairing them with fresh dispatches.
        let mut theta = vec![0.0f32; 8];
        let err = server.step(&mut theta, &as_views(&msgs), &ctx).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
    }
}
