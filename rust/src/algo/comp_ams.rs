//! COMP-AMS (paper Algorithm 2) — and, with the Identity compressor, the
//! full-precision Dist-AMS baseline.
//!
//! Worker i (lines 5-9):  ĝ_i = C(g_i + e_i);  e_i ← e_i + g_i − ĝ_i.
//! Server (lines 11-16):  ḡ = mean_i ĝ_i; AMSGrad(θ, ḡ) with m, v, v̂
//! held **only on the server**.
//!
//! The server update has two backends: the pure-Rust [`AmsGrad`] loop and
//! the AOT-compiled L1 Pallas fused kernel ([`OptimizerExe`]), selected
//! via [`CompAms::with_fused`]. Both are bit-compared in the integration
//! tests and raced in `bench_optim`.

use std::rc::Rc;

use anyhow::Result;

use crate::compress::{Compressor, CompressorSpec, ErrorFeedback, Payload};
use crate::optim::{AmsGrad, ServerOpt};
use crate::runtime::OptimizerExe;

use super::{average_payloads, Algorithm, RoundCtx};

pub struct CompAms {
    label: &'static str,
    compressors: Vec<Box<dyn Compressor>>,
    efs: Vec<ErrorFeedback>,
    opt: AmsGrad,
    fused: Option<Rc<OptimizerExe>>,
    avg: Vec<f32>,
}

impl CompAms {
    pub fn new(
        dim: usize,
        n: usize,
        compressor: CompressorSpec,
        error_feedback: bool,
        label: &'static str,
    ) -> Self {
        let compressors = (0..n)
            .map(|w| {
                // Give stateful compressors distinct streams per worker.
                match &compressor {
                    CompressorSpec::RandomK { ratio, seed } => CompressorSpec::RandomK {
                        ratio: *ratio,
                        seed: seed ^ (w as u64 + 1),
                    }
                    .build(),
                    CompressorSpec::Qsgd { levels, seed } => CompressorSpec::Qsgd {
                        levels: *levels,
                        seed: seed ^ (w as u64 + 1),
                    }
                    .build(),
                    c => c.build(),
                }
            })
            .collect();
        CompAms {
            label,
            compressors,
            efs: (0..n).map(|_| ErrorFeedback::new(dim, error_feedback)).collect(),
            opt: AmsGrad::default_hp(dim),
            fused: None,
            avg: Vec::new(),
        }
    }

    /// Route the server update through the Pallas fused-update artifact.
    pub fn with_fused(mut self, exe: Rc<OptimizerExe>) -> Self {
        assert_eq!(exe.p(), self.opt.dim());
        self.fused = Some(exe);
        self
    }

    /// Residual norms (diagnostics / tests).
    pub fn residual_norms(&self) -> Vec<f64> {
        self.efs.iter().map(|e| e.residual_norm()).collect()
    }
}

impl Algorithm for CompAms {
    fn name(&self) -> String {
        if self.label == "dist-ams" {
            "dist-ams".into()
        } else {
            format!("comp-ams[{}]", self.compressors[0].name())
        }
    }

    fn worker_msg(&mut self, wid: usize, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        self.efs[wid].compress(grad, self.compressors[wid].as_mut())
    }

    fn server_step(
        &mut self,
        theta: &mut [f32],
        msgs: &[Payload],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        average_payloads(msgs, theta.len(), &mut avg)?;
        match &self.fused {
            None => self.opt.step(theta, &avg, ctx.lr),
            Some(exe) => {
                let (t2, m2, v2, vh2) =
                    exe.run(theta, &self.opt.m, &self.opt.v, &self.opt.vhat, &avg, ctx.lr)?;
                theta.copy_from_slice(&t2);
                self.opt.m = m2;
                self.opt.v = v2;
                self.opt.vhat = vh2;
            }
        }
        self.avg = avg;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(round: u64) -> RoundCtx {
        RoundCtx { round, lr: 0.01 }
    }

    #[test]
    fn identity_variant_equals_sequential_amsgrad() {
        // Dist-AMS with n workers and identical gradients must match a
        // single-machine AMSGrad trace exactly.
        let dim = 16;
        let mut algo = CompAms::new(dim, 4, CompressorSpec::Identity, false, "dist-ams");
        let mut reference = AmsGrad::default_hp(dim);
        let mut theta_a = vec![0.3f32; dim];
        let mut theta_b = vec![0.3f32; dim];
        for r in 0..20 {
            let g: Vec<f32> = (0..dim).map(|i| ((r * i) as f32 * 0.1).sin()).collect();
            let msgs: Vec<Payload> = (0..4)
                .map(|w| algo.worker_msg(w, &g, &ctx(r as u64)).unwrap())
                .collect();
            algo.server_step(&mut theta_a, &msgs, &ctx(r as u64)).unwrap();
            reference.step(&mut theta_b, &g, 0.01);
            assert_eq!(theta_a, theta_b, "round {r}");
        }
    }

    #[test]
    fn compressed_single_worker_tracks_full_gradient_direction() {
        // With EF, the *sum* of transmitted messages telescopes to the sum
        // of true gradients minus the final residual (Alg. 2 invariant).
        let dim = 64;
        let mut algo =
            CompAms::new(dim, 1, CompressorSpec::TopK { ratio: 0.1 }, true, "comp-ams");
        let mut rng = crate::util::rng::Rng::seed(3);
        let mut sum_g = vec![0.0f32; dim];
        let mut sum_sent = vec![0.0f32; dim];
        for r in 0..30 {
            let g = rng.normal_vec(dim);
            crate::util::math::axpy(1.0, &g, &mut sum_g);
            let msg = algo.worker_msg(0, &g, &ctx(r)).unwrap();
            let dense = msg.to_dense(dim).unwrap();
            crate::util::math::axpy(1.0, &dense, &mut sum_sent);
        }
        let residual = algo.efs[0].residual();
        for i in 0..dim {
            assert!(
                (sum_g[i] - sum_sent[i] - residual[i]).abs() < 1e-3,
                "telescoping broken at {i}"
            );
        }
    }

    #[test]
    fn worker_messages_are_actually_compressed() {
        let dim = 10_000;
        let mut algo =
            CompAms::new(dim, 2, CompressorSpec::TopK { ratio: 0.01 }, true, "comp-ams");
        let g = vec![1.0f32; dim];
        let msg = algo.worker_msg(0, &g, &ctx(0)).unwrap();
        let dense_bits = Payload::Dense(g).wire_bits();
        assert!(msg.wire_bits() < dense_bits / 40);
    }
}
