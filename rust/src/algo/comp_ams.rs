//! COMP-AMS (paper Algorithm 2) — and, with the Identity compressor, the
//! full-precision Dist-AMS baseline.
//!
//! Worker i (lines 5-9, [`CompAmsWorker`]):  ĝ_i = C(g_i + e_i);
//! e_i ← e_i + g_i − ĝ_i. Each worker owns its compressor and EF
//! accumulator outright, so the whole stage runs on the worker thread.
//!
//! Server (lines 11-16, [`CompAmsServer`]):  ḡ = mean_i ĝ_i;
//! AMSGrad(θ, ḡ) with m, v, v̂ held **only on the server**.
//!
//! The server update has two backends: the pure-Rust [`AmsGrad`] loop in
//! [`CompAmsServer`] (which is `Send`, so the sharded server can move
//! per-shard instances onto leader-side threads) and the AOT-compiled L1
//! Pallas fused kernel ([`OptimizerExe`]) in [`FusedCompAmsServer`]
//! (which holds non-`Send` PJRT handles and stays pinned to the leader).
//! Both are bit-compared in the integration tests and raced in
//! `bench_optim`.

use std::rc::Rc;

use anyhow::Result;

use crate::compress::{Compressor, CompressorSpec, ErrorFeedback, Payload, PayloadView};
use crate::optim::{AmsGrad, ServerOpt};
use crate::runtime::OptimizerExe;

use super::{
    aggregate_payloads, per_worker_spec, AggMode, Protocol, RoundCtx, ServerAlgo, WorkerAlgo,
};

/// Worker half: compressor + error-feedback accumulator (no optimizer
/// state — the paper's §3.2 memory argument vs. QAdam/1BitAdam).
pub struct CompAmsWorker {
    compressor: Box<dyn Compressor>,
    ef: ErrorFeedback,
}

impl CompAmsWorker {
    pub fn new(dim: usize, compressor: Box<dyn Compressor>, error_feedback: bool) -> Self {
        CompAmsWorker { compressor, ef: ErrorFeedback::new(dim, error_feedback) }
    }

    /// This worker's EF residual (diagnostics / tests).
    pub fn residual(&self) -> &[f32] {
        self.ef.residual()
    }

    pub fn residual_norm(&self) -> f64 {
        self.ef.residual_norm()
    }
}

impl WorkerAlgo for CompAmsWorker {
    fn process(&mut self, grad: &[f32], _ctx: &RoundCtx) -> Result<Payload> {
        self.ef.compress(grad, self.compressor.as_mut())
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        crate::util::bytes::put_bytes(&mut out, &self.compressor.export_state());
        crate::util::bytes::put_bytes(&mut out, &self.ef.export_state());
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let comp = c.bytes()?.to_vec();
        let ef = c.bytes()?.to_vec();
        c.finish()?;
        self.compressor.import_state(&comp)?;
        self.ef.import_state(&ef)
    }
}

/// Server half: AMSGrad with all moment state on the leader. Pure-Rust
/// update loop; the state is strictly per-coordinate, so a `ShardedServer`
/// can run one instance per contiguous θ shard with trajectories bitwise
/// identical to the unsharded server.
pub struct CompAmsServer {
    label: &'static str,
    comp_name: String,
    opt: AmsGrad,
    avg: Vec<f32>,
    /// Batch estimator (`--robust-agg`): plain mean by default,
    /// coordinate-wise median / trimmed mean for byzantine tolerance.
    agg: AggMode,
}

impl CompAmsServer {
    pub fn new(dim: usize, comp_name: String, label: &'static str) -> Self {
        CompAmsServer {
            label,
            comp_name,
            opt: AmsGrad::default_hp(dim),
            avg: Vec::new(),
            agg: AggMode::Mean,
        }
    }

    /// Aggregate the round's payload views into the recycled `avg`
    /// buffer and hand it out; the caller returns it via `self.avg` when
    /// done. Shared by the pure-Rust and the fused-kernel step so the
    /// aggregation semantics cannot diverge between the two backends.
    fn averaged(&mut self, msgs: &[PayloadView<'_>], dim: usize) -> Result<Vec<f32>> {
        let mut avg = std::mem::take(&mut self.avg);
        aggregate_payloads(msgs, dim, &mut avg, self.agg)?;
        Ok(avg)
    }
}

impl ServerAlgo for CompAmsServer {
    fn name(&self) -> String {
        if self.label == "dist-ams" {
            "dist-ams".into()
        } else {
            format!("comp-ams[{}]", self.comp_name)
        }
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let avg = self.averaged(msgs, theta.len())?;
        self.opt.step(theta, &avg, ctx.lr);
        self.avg = avg;
        Ok(())
    }

    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        self.agg = mode;
        Ok(())
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        crate::util::bytes::put_f32s(&mut out, &self.opt.m);
        crate::util::bytes::put_f32s(&mut out, &self.opt.v);
        crate::util::bytes::put_f32s(&mut out, &self.opt.vhat);
        Ok(out)
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let m = c.f32s()?;
        let v = c.f32s()?;
        let vhat = c.f32s()?;
        c.finish()?;
        anyhow::ensure!(
            m.len() == self.opt.dim() && v.len() == self.opt.dim() && vhat.len() == self.opt.dim(),
            "amsgrad state dim mismatch: blob {} vs {}",
            m.len(),
            self.opt.dim()
        );
        self.opt.m = m;
        self.opt.v = v;
        self.opt.vhat = vhat;
        Ok(())
    }
}

/// [`CompAmsServer`] with the update routed through the Pallas
/// fused-update artifact. Holds non-`Send` PJRT handles, so it is pinned
/// to the leader thread and cannot be sharded (the fused executable is
/// AOT-compiled for the full θ dimension).
pub struct FusedCompAmsServer {
    inner: CompAmsServer,
    exe: Rc<OptimizerExe>,
}

impl FusedCompAmsServer {
    pub fn new(inner: CompAmsServer, exe: Rc<OptimizerExe>) -> Self {
        assert_eq!(exe.p(), inner.opt.dim());
        FusedCompAmsServer { inner, exe }
    }
}

impl ServerAlgo for FusedCompAmsServer {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let avg = self.inner.averaged(msgs, theta.len())?;
        let opt = &mut self.inner.opt;
        let (t2, m2, v2, vh2) =
            self.exe.run(theta, &opt.m, &opt.v, &opt.vhat, &avg, ctx.lr)?;
        theta.copy_from_slice(&t2);
        opt.m = m2;
        opt.v = v2;
        opt.vhat = vh2;
        self.inner.avg = avg;
        Ok(())
    }

    fn set_agg_mode(&mut self, mode: AggMode) -> Result<()> {
        // The fused kernel computes θ ← AMSGrad(θ, mean ĝ) as one AOT
        // artifact; robust estimators would change the math behind its
        // back. `TrainConfig::validate` rejects the combo up front.
        if mode == AggMode::Mean {
            Ok(())
        } else {
            anyhow::bail!(
                "fused-update server '{}' supports only mean aggregation \
                 (drop --fused-update to use --robust-agg {mode})",
                self.name()
            )
        }
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        self.inner.export_state()
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.inner.import_state(bytes)
    }
}

/// Build the full COMP-AMS protocol: n worker halves + the server half.
pub fn protocol(
    dim: usize,
    n: usize,
    compressor: CompressorSpec,
    error_feedback: bool,
    label: &'static str,
    fused: Option<Rc<OptimizerExe>>,
) -> Protocol {
    let comp_name = compressor.build().name();
    let workers: Vec<Box<dyn WorkerAlgo>> = (0..n)
        .map(|w| {
            Box::new(CompAmsWorker::new(
                dim,
                per_worker_spec(&compressor, w).build(),
                error_feedback,
            )) as Box<dyn WorkerAlgo>
        })
        .collect();
    let server = CompAmsServer::new(dim, comp_name, label);
    let server: Box<dyn ServerAlgo> = match fused {
        None => Box::new(server),
        Some(exe) => Box::new(FusedCompAmsServer::new(server, exe)),
    };
    (workers, server)
}

/// Build just the pure-Rust (`Send`) server half over a `dim`-slice of θ —
/// the per-shard constructor used by [`crate::algo::sharded::ShardedServer`].
pub fn server(dim: usize, compressor: &CompressorSpec, label: &'static str) -> CompAmsServer {
    CompAmsServer::new(dim, compressor.build().name(), label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::as_views;

    fn ctx(round: u64) -> RoundCtx {
        RoundCtx::sync(round, 0.01)
    }

    fn build(
        dim: usize,
        n: usize,
        spec: CompressorSpec,
        ef: bool,
    ) -> (Vec<CompAmsWorker>, CompAmsServer) {
        let comp_name = spec.build().name();
        let workers = (0..n)
            .map(|w| CompAmsWorker::new(dim, per_worker_spec(&spec, w).build(), ef))
            .collect();
        (workers, CompAmsServer::new(dim, comp_name, "comp-ams"))
    }

    #[test]
    fn identity_variant_equals_sequential_amsgrad() {
        // Dist-AMS with n workers and identical gradients must match a
        // single-machine AMSGrad trace exactly.
        let dim = 16;
        let (mut workers, mut server) = build(dim, 4, CompressorSpec::Identity, false);
        let mut reference = AmsGrad::default_hp(dim);
        let mut theta_a = vec![0.3f32; dim];
        let mut theta_b = vec![0.3f32; dim];
        for r in 0..20 {
            let g: Vec<f32> = (0..dim).map(|i| ((r * i) as f32 * 0.1).sin()).collect();
            let msgs: Vec<Payload> = workers
                .iter_mut()
                .map(|w| w.process(&g, &ctx(r as u64)).unwrap())
                .collect();
            server.step(&mut theta_a, &as_views(&msgs), &ctx(r as u64)).unwrap();
            reference.step(&mut theta_b, &g, 0.01);
            assert_eq!(theta_a, theta_b, "round {r}");
        }
    }

    #[test]
    fn robust_aggregation_suppresses_an_outlier_worker() {
        // 3 honest workers at g = 1 plus one adversary at g = -3: the
        // batch mean is exactly 0 (AMSGrad takes a null step), while
        // trimmed:1 drops the extremes and keeps the honest direction.
        let dim = 4;
        let honest = Payload::Dense(vec![1.0; dim]);
        let evil = Payload::Dense(vec![-3.0; dim]);
        let msgs = vec![honest.clone(), honest.clone(), honest, evil];

        let (_, mut mean_server) = build(dim, 4, CompressorSpec::Identity, false);
        let mut theta = vec![1.0f32; dim];
        mean_server.step(&mut theta, &as_views(&msgs), &ctx(0)).unwrap();
        assert_eq!(theta, vec![1.0; dim], "zero mean must take a null step");

        let (_, mut trimmed) = build(dim, 4, CompressorSpec::Identity, false);
        trimmed.set_agg_mode(AggMode::Trimmed(1)).unwrap();
        trimmed.step(&mut theta, &as_views(&msgs), &ctx(0)).unwrap();
        assert!(
            theta.iter().all(|&t| t < 1.0),
            "trimmed mean must keep the honest descent direction: {theta:?}"
        );
    }

    #[test]
    fn compressed_single_worker_tracks_full_gradient_direction() {
        // With EF, the *sum* of transmitted messages telescopes to the sum
        // of true gradients minus the final residual (Alg. 2 invariant).
        let dim = 64;
        let (mut workers, _) = build(dim, 1, CompressorSpec::TopK { ratio: 0.1 }, true);
        let mut rng = crate::util::rng::Rng::seed(3);
        let mut sum_g = vec![0.0f32; dim];
        let mut sum_sent = vec![0.0f32; dim];
        for r in 0..30 {
            let g = rng.normal_vec(dim);
            crate::util::math::axpy(1.0, &g, &mut sum_g);
            let msg = workers[0].process(&g, &ctx(r)).unwrap();
            let dense = msg.to_dense(dim).unwrap();
            crate::util::math::axpy(1.0, &dense, &mut sum_sent);
        }
        let residual = workers[0].residual();
        for i in 0..dim {
            assert!(
                (sum_g[i] - sum_sent[i] - residual[i]).abs() < 1e-3,
                "telescoping broken at {i}"
            );
        }
    }

    #[test]
    fn worker_messages_are_actually_compressed() {
        let dim = 10_000;
        let (mut workers, _) = build(dim, 2, CompressorSpec::TopK { ratio: 0.01 }, true);
        let g = vec![1.0f32; dim];
        let msg = workers[0].process(&g, &ctx(0)).unwrap();
        let dense_bits = Payload::Dense(g).wire_bits();
        assert!(msg.wire_bits() < dense_bits / 40);
    }

    #[test]
    fn workers_have_independent_residuals() {
        // Two workers fed different gradients accumulate different EF
        // residuals — per-worker state is genuinely per-instance now.
        let dim = 32;
        let (mut workers, _) = build(dim, 2, CompressorSpec::TopK { ratio: 0.1 }, true);
        let g0 = vec![1.0f32; dim];
        let mut g1 = vec![0.0f32; dim];
        g1[0] = 5.0;
        workers[0].process(&g0, &ctx(0)).unwrap();
        workers[1].process(&g1, &ctx(0)).unwrap();
        assert!(workers[1].residual_norm() < workers[0].residual_norm());
    }
}
