//! 1BitAdam baseline (Tang et al. 2021, as described in the paper §3.2).
//!
//! Phase 1 (warm-up, full precision): workers uplink dense gradients
//! ([`OneBitAdamWorker`] passes them through) and the server runs
//! standard Adam. At the end of warm-up the server freezes the second
//! moment v into the preconditioner 1/(√v̂+ε) ([`OneBitAdamServer`]).
//!
//! Phase 2 (compressed): each worker keeps a **local** momentum m_i,
//! updates m_i ← β1 m_i + (1−β1) g_i, and uplinks C(m_i) (1-bit
//! block-sign) with error feedback. The server averages the decoded
//! momenta and applies θ ← θ − lr · m̄ ⊙ precond — i.e. momentum SGD with
//! frozen coordinate-wise learning rates (the paper's §3.2 reading).
//!
//! Both halves carry the warm-up horizon so the phase switch needs no
//! cross-thread coordination: workers and server each read it off the
//! shared [`RoundCtx`] round counter.
//!
//! The paper's observed failure mode — sensitivity to warm-up quality,
//! especially on sparse text where v is unstable — emerges from exactly
//! this structure and is exercised in the Fig. 1 IMDB run.

use anyhow::Result;

use crate::compress::{BlockSign, ErrorFeedback, Payload, PayloadView};
use crate::optim::{Adam, ServerOpt, BETA1, EPS};

use super::{average_payloads, Protocol, RoundCtx, ServerAlgo, WorkerAlgo};

/// Worker half: local momentum + block-sign + EF, dense during warm-up.
pub struct OneBitAdamWorker {
    warmup_rounds: u64,
    /// Worker-local momentum (phase 2 state).
    m: Vec<f32>,
    compressor: BlockSign,
    ef: ErrorFeedback,
}

impl OneBitAdamWorker {
    pub fn new(dim: usize, warmup_rounds: u64, block: usize) -> Self {
        OneBitAdamWorker {
            warmup_rounds,
            m: vec![0.0; dim],
            compressor: BlockSign::new(block),
            ef: ErrorFeedback::new(dim, true),
        }
    }

    pub fn in_warmup(&self, round: u64) -> bool {
        round < self.warmup_rounds
    }
}

impl WorkerAlgo for OneBitAdamWorker {
    fn process(&mut self, grad: &[f32], ctx: &RoundCtx) -> Result<Payload> {
        if self.in_warmup(ctx.round) {
            return Ok(Payload::Dense(grad.to_vec()));
        }
        for i in 0..grad.len() {
            self.m[i] = BETA1 * self.m[i] + (1.0 - BETA1) * grad[i];
        }
        self.ef.compress(&self.m, &mut self.compressor)
    }

    fn state_bytes(&self) -> usize {
        // local momentum per worker (paper §3.2: "extra tensors for m").
        self.m.len() * std::mem::size_of::<f32>()
    }

    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        crate::util::bytes::put_f32s(&mut out, &self.m);
        crate::util::bytes::put_bytes(&mut out, &self.ef.export_state());
        out
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let m = c.f32s()?;
        let ef = c.bytes()?.to_vec();
        c.finish()?;
        anyhow::ensure!(
            m.len() == self.m.len(),
            "1bitadam momentum dim mismatch: blob {} vs {}",
            m.len(),
            self.m.len()
        );
        self.m = m;
        self.ef.import_state(&ef)
    }
}

/// Server half: Adam during warm-up, frozen-preconditioner momentum after.
/// Adam's moments and the frozen preconditioner are per-coordinate, and
/// the phase switch reads the shared round counter, so per-shard instances
/// under [`crate::algo::sharded::ShardedServer`] freeze at the same round
/// and reproduce the unsharded trajectory bitwise.
pub struct OneBitAdamServer {
    warmup_rounds: u64,
    adam: Adam,
    /// Frozen 1/(√v+ε) preconditioner (None during warm-up).
    precond: Option<Vec<f32>>,
    avg: Vec<f32>,
    /// Set at a tree-topology root ([`ServerAlgo::set_pre_aggregated`]):
    /// uplinks are sub-leaders' forwarded group means, where a *dense*
    /// payload is a legitimate identity-compressed aggregate of sign
    /// momenta — not a cross-phase straggler — so the dense-discard
    /// filter below must not run.
    pre_aggregated: bool,
}

impl OneBitAdamServer {
    pub fn new(dim: usize, warmup_rounds: u64) -> Self {
        OneBitAdamServer {
            warmup_rounds,
            adam: Adam::default_hp(dim),
            precond: None,
            avg: Vec::new(),
            pre_aggregated: false,
        }
    }

    pub fn in_warmup(&self, round: u64) -> bool {
        round < self.warmup_rounds
    }

    pub fn precond(&self) -> Option<&[f32]> {
        self.precond.as_deref()
    }

    fn freeze(&mut self) {
        let v = self.adam.freeze_v();
        self.precond = Some(v.iter().map(|&vi| 1.0 / (vi.sqrt() + EPS)).collect());
    }
}

impl ServerAlgo for OneBitAdamServer {
    fn name(&self) -> String {
        format!("1bitadam[warmup={}]", self.warmup_rounds)
    }

    fn step(
        &mut self,
        theta: &mut [f32],
        msgs: &[PayloadView<'_>],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        if self.in_warmup(ctx.round) {
            average_payloads(msgs, theta.len(), &mut avg)?;
            self.adam.step(theta, &avg, ctx.lr);
            if ctx.round + 1 == self.warmup_rounds {
                self.freeze();
            }
        } else {
            if self.precond.is_none() {
                // warmup_rounds == 0: freeze immediately (v = 0 ⇒ the
                // preconditioner degenerates to 1/ε-capped — the "bad
                // pre-conditioning" failure the paper warns about; kept
                // reachable on purpose for the ablation).
                self.freeze();
            }
            // Partial participation can land warm-up stragglers in a
            // compressed round (only when ctx.observed_round predates
            // the warm-up boundary): those are *raw dense gradients*,
            // and averaging one with (1-β1)-scaled sign momenta would
            // push it through the frozen-preconditioner momentum step at
            // the wrong scale. Post-warmup workers only ever uplink sign
            // payloads, so a dense message here is by construction a
            // cross-phase straggler — discard it. With full quorum the
            // batch is all-fresh and this filter never triggers (the
            // accumulate-then-scale below is then op-for-op identical to
            // average_payloads).
            avg.clear();
            avg.resize(theta.len(), 0.0);
            let mut kept = 0usize;
            for m in msgs {
                if !self.pre_aggregated && matches!(m, PayloadView::Dense(_)) {
                    continue;
                }
                m.add_into(&mut avg)?;
                kept += 1;
            }
            if kept > 0 {
                let inv = 1.0 / kept as f32;
                for a in avg.iter_mut() {
                    *a *= inv;
                }
                let pre = self.precond.as_ref().unwrap();
                for i in 0..theta.len() {
                    theta[i] -= ctx.lr * avg[i] * pre[i].min(1.0 / EPS);
                }
            }
        }
        self.avg = avg;
        Ok(())
    }

    fn set_pre_aggregated(&mut self, pre: bool) {
        self.pre_aggregated = pre;
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        use crate::util::bytes::{put_f32s, put_u32, put_u64};
        let mut out = Vec::new();
        put_f32s(&mut out, &self.adam.m);
        put_f32s(&mut out, &self.adam.v);
        put_u64(&mut out, self.adam.step_count());
        match &self.precond {
            Some(p) => {
                put_u32(&mut out, 1);
                put_f32s(&mut out, p);
            }
            None => put_u32(&mut out, 0),
        }
        Ok(out)
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let m = c.f32s()?;
        let v = c.f32s()?;
        let t = c.u64()?;
        let precond = match c.u32()? {
            0 => None,
            1 => Some(c.f32s()?),
            k => anyhow::bail!("bad 1bitadam precond flag {k}"),
        };
        c.finish()?;
        anyhow::ensure!(
            m.len() == self.adam.m.len() && v.len() == self.adam.v.len(),
            "1bitadam server state dim mismatch: blob {} vs {}",
            m.len(),
            self.adam.m.len()
        );
        self.adam.m = m;
        self.adam.v = v;
        self.adam.set_step_count(t);
        self.precond = precond;
        Ok(())
    }
}

/// Build the full 1BitAdam protocol: n worker halves + the server half.
pub fn protocol(dim: usize, n: usize, warmup_rounds: u64, block: usize) -> Protocol {
    let workers: Vec<Box<dyn WorkerAlgo>> = (0..n)
        .map(|_| {
            Box::new(OneBitAdamWorker::new(dim, warmup_rounds, block))
                as Box<dyn WorkerAlgo>
        })
        .collect();
    (workers, Box::new(OneBitAdamServer::new(dim, warmup_rounds)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::as_views;

    fn pair(dim: usize, warmup: u64, block: usize) -> (OneBitAdamWorker, OneBitAdamServer) {
        (OneBitAdamWorker::new(dim, warmup, block), OneBitAdamServer::new(dim, warmup))
    }

    #[test]
    fn warmup_messages_are_dense_then_compressed() {
        let (mut w, mut s) = pair(256, 3, 64);
        let g = vec![1.0f32; 256];
        for r in 0..6 {
            let ctx = RoundCtx::sync(r, 0.01);
            let msg = w.process(&g, &ctx).unwrap();
            let mut theta = vec![0.0f32; 256];
            let dense = matches!(msg, Payload::Dense(_));
            assert_eq!(dense, r < 3, "round {r}");
            s.step(&mut theta, &[msg.view()], &ctx).unwrap();
        }
    }

    #[test]
    fn freezes_preconditioner_at_warmup_boundary() {
        let (mut w, mut s) = pair(8, 2, 8);
        let mut theta = vec![1.0f32; 8];
        for r in 0..2 {
            let ctx = RoundCtx::sync(r, 0.01);
            let msg = w.process(&theta.clone(), &ctx).unwrap();
            s.step(&mut theta, &[msg.view()], &ctx).unwrap();
        }
        assert!(s.precond().is_some());
        let frozen = s.precond().unwrap().to_vec();
        // Further rounds must not change the preconditioner.
        for r in 2..10 {
            let ctx = RoundCtx::sync(r, 0.01);
            let msg = w.process(&theta.clone(), &ctx).unwrap();
            s.step(&mut theta, &[msg.view()], &ctx).unwrap();
        }
        assert_eq!(s.precond().unwrap(), &frozen[..]);
    }

    #[test]
    fn post_warmup_step_discards_cross_phase_dense_stragglers() {
        // Under --quorum K < n a warm-up straggler (raw dense gradient)
        // can arrive in a compressed round; it must not be averaged with
        // sign momenta. A batch of [signs, dense-straggler] must step θ
        // exactly like the batch [signs] alone.
        let dim = 8;
        let (mut w, mut s1) = pair(dim, 2, 8);
        let mut s2 = OneBitAdamServer::new(dim, 2);
        let g = vec![1.0f32; dim];
        // Drive both servers through warm-up identically.
        for r in 0..2 {
            let ctx = RoundCtx::sync(r, 0.01);
            let msg = w.process(&g, &ctx).unwrap();
            let mut t1 = vec![0.0f32; dim];
            s1.step(&mut t1, &[msg.view()], &ctx).unwrap();
            let mut t2 = vec![0.0f32; dim];
            s2.step(&mut t2, &[msg.view()], &ctx).unwrap();
        }
        // Round 2: compressed phase. s1 sees the sign payload alone; s2
        // additionally sees a dense warm-up straggler.
        let ctx = RoundCtx { round: 2, observed_round: 1, lr: 0.01 };
        let signs = w.process(&g, &ctx).unwrap();
        assert!(!matches!(signs, Payload::Dense(_)));
        let straggler = Payload::Dense(vec![100.0f32; dim]);
        let mut t1 = vec![0.5f32; dim];
        let mut t2 = vec![0.5f32; dim];
        s1.step(&mut t1, &[signs.view()], &ctx).unwrap();
        s2.step(&mut t2, &[signs.view(), straggler.view()], &ctx).unwrap();
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pre_aggregated_root_applies_dense_group_means() {
        // At a tree root every uplink is a sub-leader's forwarded group
        // mean; under the identity group compressor that payload is
        // *dense* and must be applied, not discarded as a straggler. A
        // pre-aggregated server fed the dense mean of sign payloads must
        // step θ exactly like a plain server fed the raw sign payloads.
        let dim = 8;
        let (mut w, mut plain) = pair(dim, 2, 8);
        let mut root = OneBitAdamServer::new(dim, 2);
        root.set_pre_aggregated(true);
        let g = vec![1.0f32; dim];
        for r in 0..2 {
            let ctx = RoundCtx::sync(r, 0.01);
            let msg = w.process(&g, &ctx).unwrap();
            let mut t = vec![0.0f32; dim];
            plain.step(&mut t, &[msg.view()], &ctx).unwrap();
            let mut t = vec![0.0f32; dim];
            root.step(&mut t, &[msg.view()], &ctx).unwrap();
        }
        let ctx = RoundCtx::sync(2, 0.01);
        let signs = w.process(&g, &ctx).unwrap();
        let mean = Payload::Dense(signs.to_dense(dim).unwrap());
        let mut t_plain = vec![0.5f32; dim];
        let mut t_root = vec![0.5f32; dim];
        plain.step(&mut t_plain, &[signs.view()], &ctx).unwrap();
        root.step(&mut t_root, &[mean.view()], &ctx).unwrap();
        for (a, b) in t_plain.iter().zip(&t_root) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Without the flag the same dense mean is discarded (θ frozen).
        let mut off = OneBitAdamServer::new(dim, 0);
        let before = vec![0.5f32; dim];
        let mut t = before.clone();
        off.step(&mut t, &[mean.view()], &RoundCtx::sync(0, 0.01)).unwrap();
        assert_eq!(t, before);
    }

    #[test]
    fn descends_quadratic_with_reasonable_warmup() {
        let (mut workers, mut server) = protocol(16, 2, 20, 16);
        let mut theta = vec![2.0f32; 16];
        for r in 0..400 {
            let ctx = RoundCtx::sync(r, 0.02);
            let g = theta.clone();
            let msgs: Vec<Payload> = workers
                .iter_mut()
                .map(|w| w.process(&g, &ctx).unwrap())
                .collect();
            server.step(&mut theta, &as_views(&msgs), &ctx).unwrap();
        }
        assert!(
            crate::util::math::norm2(&theta) < 0.5,
            "{}",
            crate::util::math::norm2(&theta)
        );
    }
}
