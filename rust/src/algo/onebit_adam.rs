//! 1BitAdam baseline (Tang et al. 2021, as described in the paper §3.2).
//!
//! Phase 1 (warm-up, full precision): workers uplink dense gradients and
//! the server runs standard Adam. At the end of warm-up the server
//! freezes the second moment v and broadcasts the preconditioner
//! 1/(√v̂+ε).
//!
//! Phase 2 (compressed): each worker keeps a **local** momentum m_i,
//! updates m_i ← β1 m_i + (1−β1) g_i, and uplinks C(m_i) (1-bit
//! block-sign) with error feedback. The server averages the decoded
//! momenta and applies θ ← θ − lr · m̄ ⊙ precond — i.e. momentum SGD with
//! frozen coordinate-wise learning rates (the paper's §3.2 reading).
//!
//! The paper's observed failure mode — sensitivity to warm-up quality,
//! especially on sparse text where v is unstable — emerges from exactly
//! this structure and is exercised in the Fig. 1 IMDB run.

use anyhow::Result;

use crate::compress::{BlockSign, ErrorFeedback, Payload};
use crate::optim::{Adam, ServerOpt, BETA1, EPS};

use super::{average_payloads, Algorithm, RoundCtx};

pub struct OneBitAdam {
    warmup_rounds: u64,
    adam: Adam,
    /// Frozen 1/(√v+ε) preconditioner (None during warm-up).
    precond: Option<Vec<f32>>,
    /// Worker-local momenta (phase 2 state).
    m: Vec<Vec<f32>>,
    compressors: Vec<BlockSign>,
    efs: Vec<ErrorFeedback>,
    avg: Vec<f32>,
}

impl OneBitAdam {
    pub fn new(dim: usize, n: usize, warmup_rounds: u64, block: usize) -> Self {
        OneBitAdam {
            warmup_rounds,
            adam: Adam::default_hp(dim),
            precond: None,
            m: vec![vec![0.0; dim]; n],
            compressors: (0..n).map(|_| BlockSign::new(block)).collect(),
            efs: (0..n).map(|_| ErrorFeedback::new(dim, true)).collect(),
            avg: Vec::new(),
        }
    }

    pub fn in_warmup(&self, round: u64) -> bool {
        round < self.warmup_rounds
    }

    fn freeze(&mut self) {
        let v = self.adam.freeze_v();
        self.precond = Some(v.iter().map(|&vi| 1.0 / (vi.sqrt() + EPS)).collect());
    }
}

impl Algorithm for OneBitAdam {
    fn name(&self) -> String {
        format!("1bitadam[warmup={}]", self.warmup_rounds)
    }

    fn worker_msg(&mut self, wid: usize, grad: &[f32], ctx: &RoundCtx) -> Result<Payload> {
        if self.in_warmup(ctx.round) {
            return Ok(Payload::Dense(grad.to_vec()));
        }
        let m = &mut self.m[wid];
        for i in 0..grad.len() {
            m[i] = BETA1 * m[i] + (1.0 - BETA1) * grad[i];
        }
        let m_snapshot = m.clone();
        self.efs[wid].compress(&m_snapshot, &mut self.compressors[wid])
    }

    fn server_step(
        &mut self,
        theta: &mut [f32],
        msgs: &[Payload],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let mut avg = std::mem::take(&mut self.avg);
        average_payloads(msgs, theta.len(), &mut avg)?;
        if self.in_warmup(ctx.round) {
            self.adam.step(theta, &avg, ctx.lr);
            if ctx.round + 1 == self.warmup_rounds {
                self.freeze();
            }
        } else {
            if self.precond.is_none() {
                // warmup_rounds == 0: freeze immediately (v = 0 ⇒ the
                // preconditioner degenerates to 1/ε-capped — the "bad
                // pre-conditioning" failure the paper warns about; kept
                // reachable on purpose for the ablation).
                self.freeze();
            }
            let pre = self.precond.as_ref().unwrap();
            for i in 0..theta.len() {
                theta[i] -= ctx.lr * avg[i] * pre[i].min(1.0 / EPS);
            }
        }
        self.avg = avg;
        Ok(())
    }

    fn worker_state_bytes(&self) -> usize {
        // local momentum per worker (paper §3.2: "extra tensors for m").
        self.m[0].len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_messages_are_dense_then_compressed() {
        let mut a = OneBitAdam::new(256, 1, 3, 64);
        let g = vec![1.0f32; 256];
        for r in 0..6 {
            let ctx = RoundCtx { round: r, lr: 0.01 };
            let msg = a.worker_msg(0, &g, &ctx).unwrap();
            let mut theta = vec![0.0f32; 256];
            let dense = matches!(msg, Payload::Dense(_));
            assert_eq!(dense, r < 3, "round {r}");
            a.server_step(&mut theta, &[msg], &ctx).unwrap();
        }
    }

    #[test]
    fn freezes_preconditioner_at_warmup_boundary() {
        let mut a = OneBitAdam::new(8, 1, 2, 8);
        let mut theta = vec![1.0f32; 8];
        for r in 0..2 {
            let ctx = RoundCtx { round: r, lr: 0.01 };
            let msg = a.worker_msg(0, &theta.clone(), &ctx).unwrap();
            a.server_step(&mut theta, &[msg], &ctx).unwrap();
        }
        assert!(a.precond.is_some());
        let frozen = a.precond.clone().unwrap();
        // Further rounds must not change the preconditioner.
        for r in 2..10 {
            let ctx = RoundCtx { round: r, lr: 0.01 };
            let msg = a.worker_msg(0, &theta.clone(), &ctx).unwrap();
            a.server_step(&mut theta, &[msg], &ctx).unwrap();
        }
        assert_eq!(a.precond.unwrap(), frozen);
    }

    #[test]
    fn descends_quadratic_with_reasonable_warmup() {
        let mut a = OneBitAdam::new(16, 2, 20, 16);
        let mut theta = vec![2.0f32; 16];
        for r in 0..400 {
            let ctx = RoundCtx { round: r, lr: 0.02 };
            let msgs: Vec<Payload> = (0..2)
                .map(|w| a.worker_msg(w, &theta.clone(), &ctx).unwrap())
                .collect();
            a.server_step(&mut theta, &msgs, &ctx).unwrap();
        }
        assert!(crate::util::math::norm2(&theta) < 0.5, "{}", crate::util::math::norm2(&theta));
    }
}
