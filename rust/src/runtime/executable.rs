//! Typed wrappers over compiled PJRT executables.

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::client::Runtime;
use super::manifest::{ModelEntry, XDtype};
use super::xla;

/// One training batch in host memory.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub x: BatchData,
    pub y: Vec<i32>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Batch {
    fn x_literal(&self, entry: &ModelEntry) -> Result<xla::Literal> {
        let dims = entry.x_dims();
        let lit = match (&self.x, &entry.x_dtype) {
            (BatchData::F32(v), XDtype::F32) => {
                anyhow::ensure!(v.len() == entry.x_len(), "x len mismatch");
                xla::Literal::vec1(v)
            }
            (BatchData::I32(v), XDtype::I32) => {
                anyhow::ensure!(v.len() == entry.x_len(), "x len mismatch");
                xla::Literal::vec1(v)
            }
            _ => anyhow::bail!("batch dtype does not match model '{}'", entry.name),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn y_literal(&self, entry: &ModelEntry) -> Result<xla::Literal> {
        anyhow::ensure!(self.y.len() == entry.y_len(), "y len mismatch");
        Ok(xla::Literal::vec1(&self.y).reshape(&entry.y_dims())?)
    }
}

fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: output is always one tuple.
    Ok(result.to_tuple()?)
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// `(θ f32[P], x, y, seed i32[]) → (loss f32[], grad f32[P])`
pub struct GradExe {
    exe: xla::PjRtLoadedExecutable,
    entry: ModelEntry,
}

impl GradExe {
    pub fn load(rt: &Rc<Runtime>, path: &Path, entry: &ModelEntry) -> Result<GradExe> {
        Ok(GradExe { exe: rt.compile_hlo_text(path)?, entry: entry.clone() })
    }

    pub fn run(&self, theta: &[f32], batch: &Batch, seed: i32) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(theta.len() == self.entry.p, "theta dim mismatch");
        let inputs = [
            xla::Literal::vec1(theta),
            batch.x_literal(&self.entry)?,
            batch.y_literal(&self.entry)?,
            xla::Literal::scalar(seed),
        ];
        let out = run_tuple(&self.exe, &inputs).context("grad exe")?;
        anyhow::ensure!(out.len() == 2, "grad exe returned {} outputs", out.len());
        let loss = scalar_f32(&out[0])?;
        let grad = out[1].to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == self.entry.p, "grad dim mismatch");
        Ok((loss, grad))
    }
}

/// `(θ, x, y) → (loss f32[], correct i32[])`
pub struct EvalExe {
    exe: xla::PjRtLoadedExecutable,
    entry: ModelEntry,
}

impl EvalExe {
    pub fn load(rt: &Rc<Runtime>, path: &Path, entry: &ModelEntry) -> Result<EvalExe> {
        Ok(EvalExe { exe: rt.compile_hlo_text(path)?, entry: entry.clone() })
    }

    pub fn run(&self, theta: &[f32], batch: &Batch) -> Result<(f32, u32)> {
        let inputs = [
            xla::Literal::vec1(theta),
            batch.x_literal(&self.entry)?,
            batch.y_literal(&self.entry)?,
        ];
        let out = run_tuple(&self.exe, &inputs).context("eval exe")?;
        anyhow::ensure!(out.len() == 2, "eval exe returned {} outputs", out.len());
        let loss = scalar_f32(&out[0])?;
        let correct = out[1].get_first_element::<i32>()?;
        Ok((loss, correct.max(0) as u32))
    }
}

/// The L1 Pallas fused AMSGrad update:
/// `(θ, m, v, v̂, ĝ, lr) → (θ', m', v', v̂')`.
pub struct OptimizerExe {
    exe: xla::PjRtLoadedExecutable,
    p: usize,
}

impl OptimizerExe {
    pub fn load(rt: &Rc<Runtime>, path: &Path, p: usize) -> Result<OptimizerExe> {
        Ok(OptimizerExe { exe: rt.compile_hlo_text(path)?, p })
    }

    pub fn p(&self) -> usize {
        self.p
    }

    #[allow(clippy::type_complexity)]
    pub fn run(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        vhat: &[f32],
        g: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        for (nm, s) in [("theta", theta), ("m", m), ("v", v), ("vhat", vhat), ("g", g)] {
            anyhow::ensure!(s.len() == self.p, "{nm} dim {} != {}", s.len(), self.p);
        }
        let inputs = [
            xla::Literal::vec1(theta),
            xla::Literal::vec1(m),
            xla::Literal::vec1(v),
            xla::Literal::vec1(vhat),
            xla::Literal::vec1(g),
            xla::Literal::scalar(lr),
        ];
        let out = run_tuple(&self.exe, &inputs).context("amsgrad exe")?;
        anyhow::ensure!(out.len() == 4, "amsgrad exe returned {} outputs", out.len());
        Ok((
            out[0].to_vec::<f32>()?,
            out[1].to_vec::<f32>()?,
            out[2].to_vec::<f32>()?,
            out[3].to_vec::<f32>()?,
        ))
    }
}
