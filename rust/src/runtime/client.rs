//! PJRT CPU client wrapper: one per process, shared by all executables.

use std::path::Path;

use anyhow::{Context, Result};

use super::xla;

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into a loaded executable.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}
