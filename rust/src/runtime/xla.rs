//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The real PJRT backend links `xla_extension` (a multi-GB C++ bundle)
//! through the `xla` crate, which is not in the offline registry. This
//! module mirrors the exact API surface `client.rs` / `executable.rs`
//! use, so the crate always compiles; every entry point that would need
//! the native runtime returns [`Error`] instead. The analytic substrates
//! (`quadratic`, `logistic`) — everything the test suite exercises — never
//! touch this module's fallible paths, and the PJRT integration tests
//! self-skip when `artifacts/` is absent.
//!
//! Swapping the real backend back in is a one-line change: replace
//! `use super::xla;` with an external `xla` crate dependency.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the native xla backend \
     (offline build; analytic substrates remain fully functional)";

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(Error::unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> XlaResult<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn get_first_element<T>(&self) -> XlaResult<T> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("PJRT runtime unavailable"));
    }
}
