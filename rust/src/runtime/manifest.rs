//! Artifact manifest: the contract written by `python/compile/aot.py`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct Files {
    pub grad: String,
    pub eval: String,
    pub amsgrad: String,
    pub init: String,
}

#[derive(Clone, Debug, PartialEq)]
pub enum XDtype {
    F32,
    I32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    /// Flat parameter count.
    pub p: usize,
    pub batch: usize,
    /// Per-example input shape (without batch dim).
    pub x_shape: Vec<usize>,
    pub x_dtype: XDtype,
    /// Per-example label shape (empty = scalar label).
    pub y_shape: Vec<usize>,
    pub classes: usize,
    /// LM-style per-token labels: accuracy denominators count tokens.
    pub token_level: bool,
    pub files: Files,
}

impl ModelEntry {
    /// Number of x elements per batch.
    pub fn x_len(&self) -> usize {
        self.batch * self.x_shape.iter().product::<usize>()
    }

    /// Number of y elements per batch.
    pub fn y_len(&self) -> usize {
        self.batch * self.y_shape.iter().product::<usize>().max(1)
    }

    /// Labels per batch for accuracy denominators (tokens for LM).
    pub fn labels_per_batch(&self) -> usize {
        self.y_len()
    }

    pub fn x_dims(&self) -> Vec<i64> {
        std::iter::once(self.batch as i64)
            .chain(self.x_shape.iter().map(|&d| d as i64))
            .collect()
    }

    pub fn y_dims(&self) -> Vec<i64> {
        std::iter::once(self.batch as i64)
            .chain(self.y_shape.iter().map(|&d| d as i64))
            .collect()
    }
}

#[derive(Clone, Debug)]
pub struct OptimizerHp {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

#[derive(Debug)]
pub struct Manifest {
    pub optimizer: OptimizerHp,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text)?;
        let version = j.req("version")?.as_usize()?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let opt = j.req("optimizer")?;
        let optimizer = OptimizerHp {
            beta1: opt.req("beta1")?.as_f64()? as f32,
            beta2: opt.req("beta2")?.as_f64()? as f32,
            eps: opt.req("eps")?.as_f64()? as f32,
        };
        let models = j
            .req("models")?
            .as_arr()?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { optimizer, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                let names: Vec<_> = self.models.iter().map(|m| m.name.as_str()).collect();
                anyhow!("model '{name}' not in manifest (have: {})", names.join(", "))
            })
    }
}

fn parse_entry(j: &Json) -> Result<ModelEntry> {
    let files = j.req("files")?;
    Ok(ModelEntry {
        name: j.req("name")?.as_str()?.to_string(),
        p: j.req("p")?.as_usize()?,
        batch: j.req("batch")?.as_usize()?,
        x_shape: j.req("x_shape")?.usize_arr()?,
        x_dtype: match j.req("x_dtype")?.as_str()? {
            "f32" => XDtype::F32,
            "i32" => XDtype::I32,
            other => anyhow::bail!("bad x_dtype '{other}'"),
        },
        y_shape: j.req("y_shape")?.usize_arr()?,
        classes: j.req("classes")?.as_usize()?,
        token_level: j.req("token_level")?.as_bool()?,
        files: Files {
            grad: files.req("grad")?.as_str()?.to_string(),
            eval: files.req("eval")?.as_str()?.to_string(),
            amsgrad: files.req("amsgrad")?.as_str()?.to_string(),
            init: files.req("init")?.as_str()?.to_string(),
        },
    })
}

/// Read a little-endian f32 flat parameter dump.
pub fn read_init_bin(path: &Path) -> Result<Vec<f32>> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading init bin {}", path.display()))?;
    anyhow::ensure!(raw.len() % 4 == 0, "init.bin length not a multiple of 4");
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "optimizer": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-08},
      "models": [{
        "name": "toy", "p": 100, "batch": 4,
        "x_shape": [8, 8, 1], "x_dtype": "f32",
        "y_shape": [], "classes": 10, "token_level": false,
        "files": {"grad": "g", "eval": "e", "amsgrad": "a", "init": "i"}
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.optimizer.beta1, 0.9);
        let e = m.model("toy").unwrap();
        assert_eq!(e.p, 100);
        assert_eq!(e.x_len(), 4 * 64);
        assert_eq!(e.y_len(), 4);
        assert_eq!(e.x_dims(), vec![4, 8, 8, 1]);
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn token_level_y_len_counts_tokens() {
        let text = SAMPLE
            .replace("\"y_shape\": []", "\"y_shape\": [16]")
            .replace("\"token_level\": false", "\"token_level\": true");
        let m = Manifest::parse(&text).unwrap();
        let e = m.model("toy").unwrap();
        assert_eq!(e.y_len(), 64);
        assert_eq!(e.y_dims(), vec![4, 16]);
    }

    #[test]
    fn rejects_bad_version_and_dtype() {
        assert!(Manifest::parse(&SAMPLE.replace("\"version\": 1", "\"version\": 2")).is_err());
        assert!(Manifest::parse(&SAMPLE.replace("\"f32\"", "\"f64\"")).is_err());
    }
}
