//! PJRT runtime: load and execute the AOT artifacts from the Rust hot path.
//!
//! `python/compile/aot.py` lowers each model to HLO **text** (the
//! xla_extension-0.5.1-safe interchange format); this module compiles the
//! text once per process on the PJRT CPU client and exposes typed
//! wrappers:
//!
//! - [`GradExe`]   — `(θ, x, y, seed) → (loss, ∇θ)`
//! - [`EvalExe`]   — `(θ, x, y) → (loss, #correct)`
//! - [`OptimizerExe`] — the L1 Pallas fused AMSGrad update
//! - [`ModelBundle`]  — all three plus the manifest entry + initial θ.
//!
//! Python never runs here: after `make artifacts` these files are plain
//! inputs.

pub mod client;
pub mod executable;
pub mod manifest;
pub mod xla;

pub use client::Runtime;
pub use executable::{EvalExe, GradExe, OptimizerExe};
pub use manifest::{Manifest, ModelEntry};

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

/// Everything the coordinator needs to train one model via PJRT.
pub struct ModelBundle {
    pub entry: ModelEntry,
    pub init_theta: Vec<f32>,
    pub grad: GradExe,
    pub eval: EvalExe,
    /// Shared so the server optimizer can hold it independently.
    pub amsgrad: Rc<OptimizerExe>,
}

impl ModelBundle {
    /// Load a model by name from an artifacts directory. The `Runtime` is
    /// shared (one PJRT client per process).
    pub fn load(rt: &Rc<Runtime>, artifacts: &Path, name: &str) -> Result<ModelBundle> {
        let manifest = Manifest::load(&artifacts.join("manifest.json"))?;
        let entry = manifest.model(name)?.clone();
        let init_theta = manifest::read_init_bin(&artifacts.join(&entry.files.init))?;
        anyhow::ensure!(
            init_theta.len() == entry.p,
            "init.bin has {} params, manifest says {}",
            init_theta.len(),
            entry.p
        );
        let grad = GradExe::load(rt, &artifacts.join(&entry.files.grad), &entry)?;
        let eval = EvalExe::load(rt, &artifacts.join(&entry.files.eval), &entry)?;
        let amsgrad =
            Rc::new(OptimizerExe::load(rt, &artifacts.join(&entry.files.amsgrad), entry.p)?);
        Ok(ModelBundle { entry, init_theta, grad, eval, amsgrad })
    }
}
