//! Ablations for the design choices DESIGN.md §7 calls out:
//!
//! 1. Error feedback on/off (the paper's §2.1 motivation for EF).
//! 2. Compression ratio sweep k/d (Remark 1: q² = 1 − k/d).
//! 3. iid vs Dirichlet non-iid shards (Theorem 1's σ_g term).
//!
//! Output: `ablation.csv`.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::exp::common::{self, ExpOpts};
use crate::util::csv::CsvWriter;

pub fn run(opts: &ExpOpts) -> Result<()> {
    eprintln!("=== ablation: EF on/off, ratio sweep, iid vs non-iid ===");
    let mut w = CsvWriter::create(
        &opts.results_dir.join("ablation.csv"),
        &["study", "setting", "final_loss", "accuracy", "uplink_mb"],
    )?;
    let rounds = opts.scale_rounds(800, 80);

    // (1) EF on/off at aggressive compression.
    for (label, algo) in [
        ("ef_on", "comp-ams-topk:0.01"),
        ("ef_off", "comp-ams-topk:0.01:noef"),
        ("ef_on_bs", "comp-ams-blocksign:64"),
        ("ef_off_bs", "comp-ams-blocksign:64:noef"),
    ] {
        let mut cfg = TrainConfig::preset("logistic", algo);
        opts.apply(&mut cfg);
        cfg.rounds = rounds;
        cfg.eval_every = 0;
        let run = common::run_one(&cfg)?;
        w.row(&[
            "error_feedback".into(),
            label.into(),
            format!("{:.4}", run.final_train_loss(20)),
            format!("{:.4}", run.final_eval.accuracy),
            format!("{:.3}", run.uplink_bits() as f64 / 8e6),
        ])?;
    }

    // (2) Ratio sweep.
    for ratio in ["0.001", "0.01", "0.1", "1.0"] {
        let mut cfg =
            TrainConfig::preset("logistic", &format!("comp-ams-topk:{ratio}"));
        opts.apply(&mut cfg);
        cfg.rounds = rounds;
        cfg.eval_every = 0;
        let run = common::run_one(&cfg)?;
        w.row(&[
            "topk_ratio".into(),
            ratio.into(),
            format!("{:.4}", run.final_train_loss(20)),
            format!("{:.4}", run.final_eval.accuracy),
            format!("{:.3}", run.uplink_bits() as f64 / 8e6),
        ])?;
    }

    // (2b) Compressor family shoot-out at matched sparsity/precision:
    // f32 vs f16 Top-k values, Random-k, and unbiased QSGD quantization.
    for comp in ["topk:0.01", "topk16:0.01", "randomk:0.01", "qsgd:4"] {
        let mut cfg = TrainConfig::preset("logistic", &format!("comp-ams-{comp}"));
        opts.apply(&mut cfg);
        cfg.rounds = rounds;
        cfg.eval_every = 0;
        let run = common::run_one(&cfg)?;
        w.row(&[
            "compressor_family".into(),
            comp.into(),
            format!("{:.4}", run.final_train_loss(20)),
            format!("{:.4}", run.final_eval.accuracy),
            format!("{:.3}", run.uplink_bits() as f64 / 8e6),
        ])?;
    }

    // (3) iid vs non-iid — on the quadratic, whose sharding knob maps to
    // an exact σ_g (Assumption 4(ii); the logistic substrate ignores
    // sharding, and the PJRT image models take Dirichlet label weights —
    // see coordinator::trainer::build_workload).
    for sharding in ["iid", "dirichlet:0.5", "dirichlet:0.1"] {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.05");
        opts.apply(&mut cfg);
        cfg.rounds = rounds;
        cfg.lr = 0.02;
        cfg.sharding = sharding.into();
        cfg.eval_every = 0;
        let run = common::run_one(&cfg)?;
        w.row(&[
            "sharding".into(),
            sharding.into(),
            format!("{:.4}", run.final_train_loss(20)),
            format!("{:.4}", run.final_eval.accuracy),
            format!("{:.3}", run.uplink_bits() as f64 / 8e6),
        ])?;
    }

    // (4) Server-update backend (pure Rust vs Pallas fused artifact) on
    // the PJRT smoke model.
    for fused in [false, true] {
        let mut cfg = TrainConfig::preset("logreg", "comp-ams-topk:0.1");
        opts.apply(&mut cfg);
        cfg.workers = 4;
        cfg.rounds = opts.scale_rounds(60, 10);
        cfg.fused_update = fused;
        cfg.eval_every = 0;
        let run = common::run_one(&cfg)?;
        w.row(&[
            "server_backend".into(),
            if fused { "pallas_fused" } else { "pure_rust" }.into(),
            format!("{:.4}", run.final_train_loss(10)),
            format!("{:.4}", run.final_eval.accuracy),
            format!("{:.3}", run.uplink_bits() as f64 / 8e6),
        ])?;
    }

    w.flush()?;
    eprintln!("  wrote {}", opts.results_dir.join("ablation.csv").display());
    Ok(())
}
