//! Shared experiment plumbing: run sets of configs, dump metric CSVs,
//! print aligned summary tables.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::metrics::RunResult;
use crate::coordinator::trainer::train;
use crate::util::csv::CsvWriter;

#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Shrink round budgets for smoke runs.
    pub fast: bool,
    pub artifacts: PathBuf,
    pub results_dir: PathBuf,
    pub seed: u64,
    /// Per-round console logging.
    pub verbose: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            fast: false,
            artifacts: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            seed: 42,
            verbose: false,
        }
    }
}

impl ExpOpts {
    pub fn scale_rounds(&self, full: u64, fast: u64) -> u64 {
        if self.fast {
            fast
        } else {
            full
        }
    }

    pub fn apply(&self, cfg: &mut TrainConfig) {
        cfg.artifacts = self.artifacts.clone();
        cfg.seed = self.seed;
        if self.verbose {
            cfg.log_every = 10;
        }
    }
}

/// Train one config, echoing a one-line summary.
pub fn run_one(cfg: &TrainConfig) -> Result<RunResult> {
    let run = train(cfg)?;
    eprintln!(
        "  {:<36} loss {:.4}  acc {:>6}  uplink {:>9.2} MB  {:>8.1} ms",
        format!("{}/{}", run.model, run.algo),
        run.final_train_loss(10),
        if run.final_eval.accuracy.is_nan() {
            "-".to_string()
        } else {
            format!("{:.4}", run.final_eval.accuracy)
        },
        run.uplink_bits() as f64 / 8e6,
        run.total_wall_ms,
    );
    Ok(run)
}

/// Dump per-round metrics for a set of labelled runs into one CSV with
/// the standard schema (the input every figure is re-plotted from).
pub fn write_curves_csv(
    path: &PathBuf,
    runs: &[(String, &RunResult)],
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "task", "algo", "workers", "round", "epoch", "train_loss",
            "test_loss", "test_acc", "uplink_bits", "downlink_bits", "lr",
        ],
    )?;
    for (task, run) in runs {
        for m in &run.metrics {
            let (tl, ta) = match m.eval {
                Some(e) => (format!("{:.6}", e.loss), format!("{:.6}", e.accuracy)),
                None => (String::new(), String::new()),
            };
            w.row(&[
                task.clone(),
                run.algo.clone(),
                run.workers.to_string(),
                m.round.to_string(),
                format!("{:.4}", m.epoch),
                format!("{:.6}", m.train_loss),
                tl,
                ta,
                m.uplink_bits.to_string(),
                m.downlink_bits.to_string(),
                format!("{:.6e}", m.lr),
            ])?;
        }
    }
    w.flush()?;
    eprintln!("  wrote {}", path.display());
    Ok(())
}

/// The paper's five Fig. 1 methods (§5.1).
pub fn paper_methods() -> Vec<&'static str> {
    vec![
        "dist-ams",
        "comp-ams-topk:0.01",
        "comp-ams-blocksign:4096",
        "qadam",
        "1bitadam",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_rounds_honors_fast() {
        let mut o = ExpOpts::default();
        assert_eq!(o.scale_rounds(1000, 10), 1000);
        o.fast = true;
        assert_eq!(o.scale_rounds(1000, 10), 10);
    }
}
