//! Experiment drivers: one per table/figure in the paper's evaluation
//! (DESIGN.md §6 index), each emitting a CSV under `results/` plus a
//! console summary with the paper-vs-measured comparison hooks used by
//! EXPERIMENTS.md.
//!
//! Every driver accepts a `fast` flag (CLI `--fast`) that shrinks round
//! budgets for smoke runs; the full budgets are what EXPERIMENTS.md
//! records.

pub mod ablation;
pub mod common;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod table1;

pub use common::ExpOpts;

use anyhow::Result;

/// Dispatch an experiment by name (the `comp-ams exp <name>` CLI).
pub fn run(name: &str, opts: &ExpOpts) -> Result<()> {
    match name {
        "fig1" => fig1::run(opts, false),
        // Figure 2 is the same runs as Figure 1 plotted against uplink
        // bits; the driver emits both CSVs in one pass.
        "fig2" => fig1::run(opts, true),
        "fig3" => fig3::run(opts),
        "fig4" => fig4::run(opts),
        "table1" => table1::run(opts),
        "ablation" => ablation::run(opts),
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig1|fig2|fig3|fig4|table1|ablation)"
        ),
    }
}
