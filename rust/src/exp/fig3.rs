//! Figure 3: linear speedup — training loss vs. iterations for
//! n ∈ {1, 2, 4, 8, 16} with lr = η₀·√n (Corollary 2).
//!
//! Paper setup: MNIST + Block-Sign (CNN) and CIFAR-10 + Top-k(1%)
//! (LeNet), lr = 5e-4·√n. On this 1-core box a full 5-curve PJRT sweep is
//! run with a reduced round budget; the driver *additionally* runs the
//! analytic logistic substrate for thousands of rounds, where the
//! rounds-to-target scaling can be measured cleanly (DESIGN.md §4).
//! Output: `fig3.csv` (curves) + `fig3_speedup.csv` (rounds-to-target
//! table — the linearity check).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::metrics::RunResult;
use crate::exp::common::{self, ExpOpts};
use crate::util::csv::CsvWriter;

const NS: &[usize] = &[1, 2, 4, 8, 16];

pub fn run(opts: &ExpOpts) -> Result<()> {
    eprintln!("=== fig3: linear speedup, n in {{1,2,4,8,16}}, lr = lr0*sqrt(n) ===");
    let mut curve_runs: Vec<(String, RunResult)> = Vec::new();
    let mut speedup = CsvWriter::create(
        &opts.results_dir.join("fig3_speedup.csv"),
        &["task", "algo", "workers", "target_loss", "rounds_to_target", "ideal_rounds"],
    )?;

    // (1) Analytic substrate: clean scaling measurement over many rounds.
    // lr0 = 0.005 keeps the transient long enough that the target sits in
    // the noise-limited regime where worker averaging actually pays
    // (Corollary 2's 1/√(nT) term).
    {
        let base_lr = 0.005f32;
        let target = 0.25f32; // from ~2.3 at init; n=1 needs ~1500 rounds
        let mut base_rounds = None;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &n in NS {
            let mut cfg = TrainConfig::preset("logistic", "comp-ams-topk:0.05");
            opts.apply(&mut cfg);
            cfg.workers = n;
            cfg.lr = base_lr * (n as f32).sqrt();
            cfg.rounds = opts.scale_rounds(4000, 400);
            cfg.eval_every = 0;
            let run = common::run_one(&cfg)?;
            let hit = run.rounds_to_loss(target, 25);
            if let Some(r) = hit {
                xs.push((n as f64).log2());
                ys.push((r.max(1) as f64).log2());
            }
            let ideal = base_rounds
                .get_or_insert_with(|| hit.unwrap_or(cfg.rounds))
                .div_euclid(n as u64)
                .max(1);
            speedup.row(&[
                "logistic".into(),
                run.algo.clone(),
                n.to_string(),
                target.to_string(),
                hit.map(|r| r.to_string()).unwrap_or_default(),
                ideal.to_string(),
            ])?;
            curve_runs.push(("logistic".into(), run));
        }
        if xs.len() >= 2 {
            let (slope, _, r2) = crate::util::stats::linreg(&xs, &ys);
            eprintln!(
                "  speedup fit: log2(rounds) vs log2(n) slope {slope:.2} \
                 (ideal -1.00), R^2 {r2:.3}"
            );
        }
    }

    // (2) Paper workloads (shorter budget on 1 core).
    let paper: &[(&str, &str, f32)] = &[
        ("mnist_cnn", "comp-ams-blocksign:4096", 5e-4),
        ("cifar_lenet", "comp-ams-topk:0.01", 5e-4),
    ];
    for &(model, algo, lr0) in paper {
        let mut base_rounds = None;
        for &n in NS {
            let mut cfg = TrainConfig::preset(model, algo);
            opts.apply(&mut cfg);
            cfg.workers = n;
            cfg.lr = lr0 * (n as f32).sqrt();
            cfg.rounds = opts.scale_rounds(96, 8);
            cfg.eval_every = 0;
            let run = common::run_one(&cfg)?;
            // Mid-descent target: half the initial loss (≈1.15 nats from
            // ln(10)=2.30), deep enough to sit past the transient.
            let target = run.metrics[0].train_loss * 0.5;
            let hit = run.rounds_to_loss(target, 5);
            let ideal = base_rounds
                .get_or_insert_with(|| hit.unwrap_or(cfg.rounds))
                .div_euclid(n as u64)
                .max(1);
            speedup.row(&[
                model.into(),
                run.algo.clone(),
                n.to_string(),
                format!("{target:.4}"),
                hit.map(|r| r.to_string()).unwrap_or_default(),
                ideal.to_string(),
            ])?;
            curve_runs.push((model.into(), run));
        }
    }
    speedup.flush()?;

    let refs: Vec<(String, &RunResult)> =
        curve_runs.iter().map(|(t, r)| (t.clone(), r)).collect();
    common::write_curves_csv(&opts.results_dir.join("fig3.csv"), &refs)?;
    eprintln!("  wrote {}", opts.results_dir.join("fig3_speedup.csv").display());
    Ok(())
}
