//! Figure 4 (appendix): CIFAR-10 + ResNet — the deeper-model check, with
//! distributed SGD added as the reference the paper includes there.
//! Uses `cifar_resnet` (the 3-stage mini-ResNet; DESIGN.md §4) and the
//! paper's step-decay schedule (lr/10 at 40% and 80% of training).

use anyhow::Result;

use crate::config::{LrSchedule, TrainConfig};
use crate::coordinator::metrics::RunResult;
use crate::exp::common::{self, ExpOpts};

pub fn run(opts: &ExpOpts) -> Result<()> {
    eprintln!("=== fig4: CIFAR + mini-ResNet, 5 methods + dist-sgd ===");
    // n=8 (not the paper's 16) and 100 rounds: the mini-ResNet costs
    // ~0.24 s/worker-round on this 1-core box; the method ordering is
    // unaffected (see EXPERIMENTS.md).
    let rounds = opts.scale_rounds(80, 10);
    let workers = if opts.fast { 16 } else { 8 };
    let mut methods = common::paper_methods();
    methods.push("dist-sgd");
    let mut runs: Vec<(String, RunResult)> = Vec::new();
    for algo in methods {
        let algo_s = if algo == "1bitadam" {
            format!("1bitadam:{}", (rounds / 5).max(2))
        } else {
            algo.to_string()
        };
        let mut cfg = TrainConfig::preset("cifar_resnet", &algo_s);
        opts.apply(&mut cfg);
        cfg.workers = workers;
        cfg.rounds = rounds;
        cfg.lr = match algo {
            "dist-sgd" => 5e-2,
            "1bitadam" => 3e-4,
            _ => 1e-3,
        };
        cfg.schedule = LrSchedule::StepDecay {
            at: vec![rounds * 2 / 5, rounds * 4 / 5],
            factor: 10.0,
        };
        cfg.eval_every = (rounds / 6).max(1);
        cfg.eval_batches = if opts.fast { 2 } else { 8 };
        let run = common::run_one(&cfg)?;
        runs.push(("cifar_resnet".into(), run));
    }
    let refs: Vec<(String, &RunResult)> = runs.iter().map(|(t, r)| (t.clone(), r)).collect();
    common::write_curves_csv(&opts.results_dir.join("fig4.csv"), &refs)?;
    Ok(())
}
