//! Table 1: learning-rate grid search per method.
//!
//! The paper reports the search grids and states every result uses the
//! best grid point averaged over 3 seeds. This driver reproduces that
//! machinery: it sweeps each method's grid on a workload, reports the
//! best lr and its accuracy, and writes `table1.csv`. (On the fast
//! analytic substrate by default — the sweep is 4 methods × ~10 grid
//! points × 3 seeds; PJRT workloads would take hours on 1 core.)

use anyhow::Result;

use crate::config::TrainConfig;
use crate::exp::common::{self, ExpOpts};
use crate::util::csv::CsvWriter;

/// The paper's Table 1 grids (Appendix A).
pub fn grid_for(algo: &str) -> Vec<f32> {
    let standard = vec![
        0.00001, 0.00003, 0.00005, 0.0001, 0.0003, 0.0005, 0.001, 0.003, 0.005, 0.01,
    ];
    let qadam = vec![
        0.0001, 0.0003, 0.0005, 0.001, 0.003, 0.005, 0.01, 0.03, 0.05, 0.1, 0.3, 0.5,
    ];
    if algo.starts_with("qadam") {
        qadam
    } else {
        standard
    }
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    eprintln!("=== table1: lr grid search, best-of-grid over 3 seeds ===");
    let mut w = CsvWriter::create(
        &opts.results_dir.join("table1.csv"),
        &["algo", "lr", "mean_final_loss", "mean_acc", "is_best"],
    )?;
    let rounds = opts.scale_rounds(400, 60);
    let seeds = if opts.fast { 1 } else { 3 };
    for algo in ["dist-ams", "comp-ams-topk:0.01", "comp-ams-blocksign:64", "qadam", "1bitadam"] {
        // The analytic workload saturates at tiny lrs from the paper's
        // grids; scale the grid up by 10x to put the optimum mid-grid
        // (the *structure* — per-method grids, QAdam needing larger lr —
        // is what Table 1 documents).
        let grid: Vec<f32> = grid_for(algo).iter().map(|&lr| lr * 10.0).collect();
        let mut rows: Vec<(f32, f32, f32)> = Vec::new();
        for &lr in &grid {
            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            for s in 0..seeds {
                let mut cfg = TrainConfig::preset("logistic", algo);
                opts.apply(&mut cfg);
                cfg.workers = 8;
                cfg.rounds = rounds;
                cfg.lr = lr;
                cfg.seed = opts.seed + s as u64;
                cfg.eval_every = 0;
                let run = common::run_one(&cfg)?;
                loss_sum += run.final_train_loss(20);
                acc_sum += run.final_eval.accuracy;
            }
            rows.push((lr, loss_sum / seeds as f32, acc_sum / seeds as f32));
        }
        let best = rows
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .2.partial_cmp(&b.1 .2).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        for (i, (lr, loss, acc)) in rows.iter().enumerate() {
            w.row(&[
                algo.to_string(),
                format!("{lr:.5}"),
                format!("{loss:.4}"),
                format!("{acc:.4}"),
                (i == best).to_string(),
            ])?;
        }
        eprintln!(
            "  {:<28} best lr {:.5} acc {:.4}",
            algo, rows[best].0, rows[best].2
        );
    }
    w.flush()?;
    Ok(())
}
