//! Figures 1 & 2: train loss / test accuracy vs. epochs (Fig. 1) and vs.
//! bits uplinked (Fig. 2) on the three paper workloads with n=16 workers.
//!
//! Paper setup (§5.1): MNIST+CNN (b=32), CIFAR-10+LeNet (b=32),
//! IMDB+LSTM (b=16); methods Dist-AMS, COMP-AMS Top-k(1%),
//! COMP-AMS Block-Sign, QAdam, 1BitAdam; β=(0.9, 0.999), ε=1e-8.
//! Both figures come from the same runs, so this driver emits
//! `fig1.csv` (curves keyed by epoch) and `fig2.csv` (keyed by bits).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::exp::common::{self, ExpOpts};

struct Task {
    model: &'static str,
    lr: f32,
    rounds_full: u64,
    rounds_fast: u64,
}

// Round budgets sized for the 1-core testbed (~0.55 synthetic epochs at
// the paper's n=16 batch geometry); the paper trains ~100 epochs on a
// V100 cluster. Method *ordering* stabilizes within this budget; heavy
// compressors are still mid-transient on CIFAR (EXPERIMENTS.md §FIG1).
const TASKS: &[Task] = &[
    Task { model: "mnist_cnn", lr: 1e-3, rounds_full: 64, rounds_fast: 12 },
    Task { model: "cifar_lenet", lr: 1e-3, rounds_full: 64, rounds_fast: 12 },
    Task { model: "imdb_lstm", lr: 3e-3, rounds_full: 64, rounds_fast: 12 },
];

pub fn run(opts: &ExpOpts, as_fig2: bool) -> Result<()> {
    let label = if as_fig2 { "fig2" } else { "fig1" };
    eprintln!("=== {label}: loss/accuracy curves, n=16, 5 methods, 3 workloads ===");
    let mut all: Vec<(String, crate::coordinator::metrics::RunResult)> = Vec::new();
    for task in TASKS {
        eprintln!("[{label}] task {}", task.model);
        for algo in common::paper_methods() {
            let rounds = opts.scale_rounds(task.rounds_full, task.rounds_fast);
            // Per-method tuning, as the paper does over Table 1's grids:
            // 1BitAdam needs a longer warm-up than total/20 at this round
            // budget plus a smaller lr or its frozen preconditioner
            // diverges (the §5.4 sensitivity; see exp::ablation).
            let algo_s = if algo == "1bitadam" {
                format!("1bitadam:{}", (rounds / 5).max(2))
            } else {
                algo.to_string()
            };
            let mut cfg = TrainConfig::preset(task.model, &algo_s);
            opts.apply(&mut cfg);
            cfg.workers = 16;
            cfg.lr = if algo == "1bitadam" { task.lr / 3.0 } else { task.lr };
            cfg.rounds = rounds;
            cfg.eval_every = (cfg.rounds / 8).max(1);
            cfg.eval_batches = if opts.fast { 2 } else { 4 };
            let run = common::run_one(&cfg)?;
            all.push((task.model.to_string(), run));
        }
    }
    let refs: Vec<(String, &crate::coordinator::metrics::RunResult)> =
        all.iter().map(|(t, r)| (t.clone(), r)).collect();
    common::write_curves_csv(&opts.results_dir.join("fig1.csv"), &refs)?;
    common::write_curves_csv(&opts.results_dir.join("fig2.csv"), &refs)?;

    // Console summary: the paper's headline comparisons.
    eprintln!("\n{label} summary (final train loss / test acc / uplink MB):");
    for (task, run) in &all {
        eprintln!(
            "  {:<12} {:<28} {:>8.4} {:>8.4} {:>10.2}",
            task,
            run.algo,
            run.final_train_loss(10),
            run.final_eval.accuracy,
            run.uplink_bits() as f64 / 8e6
        );
    }
    Ok(())
}
