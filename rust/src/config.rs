//! Experiment configuration.
//!
//! A [`TrainConfig`] fully determines a run (all randomness flows from
//! `seed`). Configs are built from presets + CLI flags by the launcher,
//! or parsed from JSON files (`--config run.json`) for scripted sweeps.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Learning-rate schedule. The paper uses constant lr except CIFAR where
/// lr is divided by 10 at epochs 40 and 80 (§5.2).
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Const,
    /// Divide lr by `factor` at each round in `at`.
    StepDecay { at: Vec<u64>, factor: f32 },
}

impl LrSchedule {
    pub fn lr_at(&self, base: f32, round: u64) -> f32 {
        match self {
            LrSchedule::Const => base,
            LrSchedule::StepDecay { at, factor } => {
                let hits = at.iter().filter(|&&r| round >= r).count() as i32;
                base / factor.powi(hits)
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model/workload: a manifest model name (`mnist_cnn`, `cifar_lenet`,
    /// `cifar_resnet`, `imdb_lstm`, `lm_small`, `logreg`) or an analytic
    /// substrate (`quadratic`, `logistic`).
    pub model: String,
    /// Protocol spec, e.g. `comp-ams-topk:0.01` (see [`crate::algo::AlgoSpec`]).
    pub algo: String,
    pub workers: usize,
    pub rounds: u64,
    pub lr: f32,
    pub schedule: LrSchedule,
    pub seed: u64,
    /// `iid` or `dirichlet:<alpha>`.
    pub sharding: String,
    /// Evaluate every k rounds (0 = only at the end).
    pub eval_every: u64,
    /// Held-out batches per evaluation.
    pub eval_batches: usize,
    pub artifacts: PathBuf,
    /// Run workers on threads (analytic substrates only; PJRT models run
    /// sequentially on this 1-core box — trajectories are identical, see
    /// coordinator tests).
    pub threaded: bool,
    /// Route the AMSGrad server update through the Pallas fused artifact.
    /// Incompatible with `server_shards > 1` (the artifact is compiled
    /// for full-θ shapes).
    pub fused_update: bool,
    /// Split the server update across this many contiguous θ shards, one
    /// `ServerAlgo` per shard (1 = single unsharded server). Trajectories
    /// are bitwise identical for any shard count; see
    /// [`crate::algo::sharded`].
    pub server_shards: usize,
    /// Run the shard updates on persistent leader-side shard threads
    /// instead of sequentially (only meaningful with `server_shards > 1`).
    pub server_threaded: bool,
    /// Leader↔worker transport: `inproc` (in-process channels),
    /// `loopback` (every message round-trips the byte-level `Envelope`
    /// framing — bitwise-identical trajectories, proves process-boundary
    /// readiness), or `tcp[:port]` (real worker processes over localhost
    /// sockets; port 0/omitted = ephemeral). See
    /// [`crate::coordinator::transport`] and [`crate::coordinator::net`].
    pub transport: String,
    /// With `tcp` transport: spawn the worker daemons as child processes
    /// of this leader (`comp-ams worker` via `current_exe`) instead of
    /// waiting for externally launched workers. See
    /// [`crate::coordinator::supervisor`].
    pub spawn_workers: bool,
    /// Partial-participation quorum K: the server steps once K on-time
    /// uplinks arrive; 0 (default) means full participation (K = n,
    /// bitwise identical to the lockstep rounds). See
    /// [`crate::coordinator::runtime`].
    pub quorum: usize,
    /// Straggler uplinks older than this many rounds are dropped instead
    /// of applied as stale gradients (only meaningful with `quorum` < n).
    pub max_staleness: u64,
    /// Seed for the network simulator's per-link delay/drop streams
    /// (`--transport sim:<inner>` only). Runs with the same `sim_seed`
    /// and `sim_profile` are bit-for-bit reproducible.
    pub sim_seed: u64,
    /// Simulator impairment profile: `ideal | lan | wan | lossy-wan`
    /// (see [`crate::coordinator::sim::SimProfile`]).
    pub sim_profile: String,
    /// Adversarial worker modes: comma-separated `wid:mode` entries
    /// (`0:scale:-3`, `1:signflip`, `2:stale`; empty = all honest). See
    /// [`crate::algo::byzantine`].
    pub byzantine: String,
    /// Server batch-aggregation estimator: `mean` (the paper's average),
    /// or the byzantine-tolerant `median` / `trimmed:<k>` (see
    /// [`crate::algo::AggMode`]).
    pub robust_agg: String,
    /// Aggregation topology: `flat` (single-leader star) or
    /// `tree:<degree>[:<group-compressor>]` — sub-leaders own contiguous
    /// groups of `degree` workers, aggregate each group's uplinks, and
    /// forward one (optionally re-compressed) uplink to the root. See
    /// [`crate::coordinator::tree`].
    pub topology: String,
    /// Compress the root's θ broadcast as a θ-delta payload (tree
    /// topology only): any [`crate::compress::CompressorSpec`] string,
    /// e.g. `topk:0.1`. Empty = dense θ downlinks.
    pub downlink_compress: String,
    /// Fault injection (tree topology only): `gid:round` kills sub-leader
    /// `gid` right before its round-`round` dispatch, degrading the run
    /// to the surviving groups. Empty = no kill.
    pub tree_kill: String,
    /// Console metric cadence (0 = silent).
    pub log_every: u64,
    /// Rounds per "epoch" for reporting (dataset_size / (batch * workers)).
    pub rounds_per_epoch: u64,
}

impl TrainConfig {
    pub fn preset(model: &str, algo: &str) -> TrainConfig {
        let mut cfg = TrainConfig {
            model: model.to_string(),
            algo: algo.to_string(),
            workers: 16,
            rounds: 200,
            lr: 1e-3,
            schedule: LrSchedule::Const,
            seed: 42,
            sharding: "iid".into(),
            eval_every: 20,
            eval_batches: 8,
            artifacts: PathBuf::from("artifacts"),
            threaded: false,
            fused_update: false,
            server_shards: 1,
            server_threaded: false,
            transport: "inproc".into(),
            spawn_workers: false,
            quorum: 0,
            max_staleness: 2,
            sim_seed: 0,
            sim_profile: "ideal".into(),
            byzantine: String::new(),
            robust_agg: "mean".into(),
            topology: "flat".into(),
            downlink_compress: String::new(),
            tree_kill: String::new(),
            log_every: 0,
            rounds_per_epoch: 100,
        };
        match model {
            // Paper-shaped presets (batch sizes from §5.1; rounds_per_epoch
            // = 60000/(32·16) MNIST-style, 50000/(32·16) CIFAR-style).
            "mnist_cnn" => {
                cfg.rounds_per_epoch = 117;
                cfg.lr = 1e-3;
            }
            "cifar_lenet" | "cifar_resnet" => {
                cfg.rounds_per_epoch = 97;
                cfg.lr = 1e-3;
            }
            "imdb_lstm" => {
                cfg.rounds_per_epoch = 97; // 25000/(16·16)
                cfg.lr = 3e-3;
            }
            "lm_small" => {
                cfg.workers = 4;
                cfg.lr = 3e-4;
                cfg.rounds_per_epoch = 100;
            }
            "quadratic" | "logistic" | "logreg" => {
                cfg.workers = 8;
                cfg.lr = 0.05;
                cfg.eval_every = 50;
                cfg.rounds = 500;
                cfg.rounds_per_epoch = 100;
            }
            _ => {}
        }
        cfg
    }

    pub fn is_analytic(&self) -> bool {
        matches!(self.model.as_str(), "quadratic" | "logistic")
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.rounds == 0 {
            bail!("rounds must be >= 1");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be positive");
        }
        if self.threaded && !self.is_analytic() {
            bail!(
                "threaded workers require an analytic substrate \
                 (PJRT executables are pinned to the main thread)"
            );
        }
        if self.server_shards == 0 {
            bail!("server_shards must be >= 1");
        }
        if self.fused_update && self.server_shards > 1 {
            bail!(
                "fused_update routes the full-θ Pallas artifact and cannot \
                 be combined with server_shards > 1"
            );
        }
        if self.quorum > self.workers {
            bail!(
                "quorum {} exceeds worker count {} (0 = full participation)",
                self.quorum,
                self.workers
            );
        }
        let tspec = crate::coordinator::transport::TransportSpec::parse(&self.transport)?;
        if self.spawn_workers && !tspec.is_multiprocess() {
            bail!(
                "--spawn-workers spawns worker processes and requires --transport \
                 tcp[:port] (got '{}'; valid transports: {})",
                self.transport,
                crate::coordinator::transport::TRANSPORT_CHOICES
            );
        }
        if tspec.is_multiprocess() && !self.is_analytic() {
            bail!(
                "--transport tcp workers rebuild their data shard from the config \
                 and support the analytic substrates (quadratic | logistic), \
                 not '{}'",
                self.model
            );
        }
        if tspec.is_multiprocess() && self.threaded {
            bail!(
                "--threaded runs workers on leader-side threads; with --transport \
                 tcp workers are separate processes — drop one of the two"
            );
        }
        // Simulator knobs: the profile string must parse even when the
        // transport is not sim:<inner> (a typo'd profile should fail fast,
        // not silently ride along unused). sim-wrapping-tcp is rejected by
        // TransportSpec::parse above.
        crate::coordinator::sim::SimProfile::parse(&self.sim_profile)?;
        let topo = crate::coordinator::tree::Topology::parse(&self.topology)?;
        if let Some(groups) = topo.group_count(self.workers) {
            if self.fused_update {
                bail!(
                    "--topology tree feeds the root forwarded group aggregates \
                     and cannot be combined with --fused-update (the Pallas \
                     artifact is a flat-star full-θ step)"
                );
            }
            if tspec.is_multiprocess() {
                bail!(
                    "--topology tree runs sub-leaders inside the leader process \
                     and supports inproc | loopback | sim:inproc | sim:loopback, \
                     not '{}'",
                    self.transport
                );
            }
            if self.quorum > groups {
                bail!(
                    "quorum {} exceeds the tree's {groups} sub-leader groups \
                     (with --topology {} the root collects one uplink per \
                     group; 0 = full participation)",
                    self.quorum,
                    self.topology
                );
            }
            if !self.downlink_compress.is_empty() {
                crate::compress::CompressorSpec::parse(&self.downlink_compress)?;
            }
            if let Some((gid, _)) =
                crate::coordinator::tree::parse_tree_kill(&self.tree_kill)?
            {
                if gid >= groups {
                    bail!(
                        "tree-kill group id {gid} is out of range for {groups} \
                         groups (valid ids: 0..{groups})"
                    );
                }
            }
        } else {
            if !self.downlink_compress.is_empty() {
                bail!(
                    "--downlink-compress shapes the tree root's broadcast; with \
                     --topology flat the downlink is the dense θ (accepted \
                     topologies: {})",
                    crate::coordinator::tree::TOPOLOGY_CHOICES
                );
            }
            if !self.tree_kill.is_empty() {
                bail!(
                    "--tree-kill injects a sub-leader death and needs --topology \
                     tree:<degree> (accepted topologies: {})",
                    crate::coordinator::tree::TOPOLOGY_CHOICES
                );
            }
        }
        let byz = crate::algo::parse_byzantine(&self.byzantine)?;
        for spec in &byz {
            if spec.wid >= self.workers {
                bail!(
                    "byzantine worker id {} is out of range for {} workers \
                     (valid ids: 0..{}; accepted forms: {})",
                    spec.wid,
                    self.workers,
                    self.workers,
                    crate::algo::byzantine::BYZANTINE_CHOICES
                );
            }
        }
        let algo_spec = crate::algo::AlgoSpec::parse(&self.algo)?;
        let agg = crate::algo::AggMode::parse(&self.robust_agg)?;
        if agg != crate::algo::AggMode::Mean {
            if matches!(algo_spec, crate::algo::AlgoSpec::OneBitAdam { .. }) {
                bail!(
                    "robust-agg '{}' is incompatible with 1bitadam: its \
                     post-warmup server merges frozen-preconditioner momentum, \
                     not a pluggable batch aggregate (accepted for 1bitadam: mean)",
                    self.robust_agg
                );
            }
            if self.fused_update {
                bail!(
                    "robust-agg '{}' is incompatible with --fused-update: the \
                     Pallas artifact compiles mean-aggregation into the fused \
                     step (drop --fused-update, or use robust-agg mean)",
                    self.robust_agg
                );
            }
            if let crate::algo::AggMode::Trimmed(k) = agg {
                // Smallest batch the estimator will see: the (quorum-capped)
                // root batch in the flat star; in a tree, also the smallest
                // group a sub-leader aggregates (the last group can run
                // short when degree does not divide n).
                let batch = match &topo {
                    crate::coordinator::tree::Topology::Flat => {
                        if self.quorum == 0 { self.workers } else { self.quorum }
                    }
                    crate::coordinator::tree::Topology::Tree { degree, .. } => {
                        let groups = topo.group_count(self.workers).unwrap();
                        let root_batch =
                            if self.quorum == 0 { groups } else { self.quorum };
                        let min_group = self.workers - (groups - 1) * degree;
                        root_batch.min(min_group)
                    }
                };
                if 2 * k >= batch {
                    bail!(
                        "trimmed:{k} discards {} of every {batch}-message batch \
                         (quorum {} of {} workers) — need 2k < batch size \
                         (accepted forms: {})",
                        2 * k,
                        self.quorum,
                        self.workers,
                        crate::algo::AGG_CHOICES
                    );
                }
            }
        }
        crate::data::shard::Sharding::parse(&self.sharding)?;
        Ok(())
    }

    // ---- JSON round-trip (scripted sweeps) --------------------------------

    pub fn to_json(&self) -> Json {
        let sched = match &self.schedule {
            LrSchedule::Const => Json::str("const"),
            LrSchedule::StepDecay { at, factor } => Json::obj(vec![
                ("at", Json::Arr(at.iter().map(|&r| Json::num(r as f64)).collect())),
                ("factor", Json::num(*factor as f64)),
            ]),
        };
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("algo", Json::str(&self.algo)),
            ("workers", Json::num(self.workers as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("schedule", sched),
            ("seed", Json::num(self.seed as f64)),
            ("sharding", Json::str(&self.sharding)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("artifacts", Json::str(&self.artifacts.to_string_lossy())),
            ("threaded", Json::Bool(self.threaded)),
            ("fused_update", Json::Bool(self.fused_update)),
            ("server_shards", Json::num(self.server_shards as f64)),
            ("server_threaded", Json::Bool(self.server_threaded)),
            ("transport", Json::str(&self.transport)),
            ("spawn_workers", Json::Bool(self.spawn_workers)),
            ("quorum", Json::num(self.quorum as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("sim_seed", Json::num(self.sim_seed as f64)),
            ("sim_profile", Json::str(&self.sim_profile)),
            ("byzantine", Json::str(&self.byzantine)),
            ("robust_agg", Json::str(&self.robust_agg)),
            ("topology", Json::str(&self.topology)),
            ("downlink_compress", Json::str(&self.downlink_compress)),
            ("tree_kill", Json::str(&self.tree_kill)),
            ("log_every", Json::num(self.log_every as f64)),
            ("rounds_per_epoch", Json::num(self.rounds_per_epoch as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        let mut cfg = TrainConfig::preset(
            j.req("model")?.as_str()?,
            j.req("algo")?.as_str()?,
        );
        if let Some(v) = j.get("workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = j.get("rounds") {
            cfg.rounds = v.as_usize()? as u64;
        }
        if let Some(v) = j.get("lr") {
            cfg.lr = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("schedule") {
            cfg.schedule = match v {
                Json::Str(s) if s == "const" => LrSchedule::Const,
                obj => LrSchedule::StepDecay {
                    at: obj
                        .req("at")?
                        .usize_arr()?
                        .into_iter()
                        .map(|r| r as u64)
                        .collect(),
                    factor: obj.req("factor")?.as_f64()? as f32,
                },
            };
        }
        if let Some(v) = j.get("seed") {
            cfg.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.get("sharding") {
            cfg.sharding = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("eval_every") {
            cfg.eval_every = v.as_usize()? as u64;
        }
        if let Some(v) = j.get("eval_batches") {
            cfg.eval_batches = v.as_usize()?;
        }
        if let Some(v) = j.get("artifacts") {
            cfg.artifacts = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = j.get("threaded") {
            cfg.threaded = v.as_bool()?;
        }
        if let Some(v) = j.get("fused_update") {
            cfg.fused_update = v.as_bool()?;
        }
        if let Some(v) = j.get("server_shards") {
            cfg.server_shards = v.as_usize()?;
        }
        if let Some(v) = j.get("server_threaded") {
            cfg.server_threaded = v.as_bool()?;
        }
        if let Some(v) = j.get("transport") {
            cfg.transport = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("spawn_workers") {
            cfg.spawn_workers = v.as_bool()?;
        }
        if let Some(v) = j.get("quorum") {
            cfg.quorum = v.as_usize()?;
        }
        if let Some(v) = j.get("max_staleness") {
            cfg.max_staleness = v.as_usize()? as u64;
        }
        if let Some(v) = j.get("sim_seed") {
            cfg.sim_seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.get("sim_profile") {
            cfg.sim_profile = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("byzantine") {
            cfg.byzantine = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("robust_agg") {
            cfg.robust_agg = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("topology") {
            cfg.topology = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("downlink_compress") {
            cfg.downlink_compress = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("tree_kill") {
            cfg.tree_kill = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("log_every") {
            cfg.log_every = v.as_usize()? as u64;
        }
        if let Some(v) = j.get("rounds_per_epoch") {
            cfg.rounds_per_epoch = v.as_usize()? as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_step_decay() {
        let s = LrSchedule::StepDecay { at: vec![40, 80], factor: 10.0 };
        assert_eq!(s.lr_at(1.0, 0), 1.0);
        assert_eq!(s.lr_at(1.0, 40), 0.1);
        assert!((s.lr_at(1.0, 85) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_mistakes() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.validate().unwrap();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::preset("mnist_cnn", "comp-ams-topk:0.01");
        cfg.threaded = true;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::preset("quadratic", "bogus-algo");
        cfg.threaded = false;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_server_sharding() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.server_shards = 4;
        cfg.server_threaded = true;
        cfg.validate().unwrap();
        cfg.server_shards = 0;
        assert!(cfg.validate().is_err());
        // The fused Pallas artifact walks the full θ: no sharding.
        cfg.server_shards = 2;
        cfg.fused_update = true;
        assert!(cfg.validate().is_err());
        cfg.server_shards = 1;
        cfg.validate().unwrap();
    }

    #[test]
    fn validate_quorum_and_transport() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 8;
        cfg.quorum = 0; // full participation sentinel
        cfg.validate().unwrap();
        cfg.quorum = 8;
        cfg.validate().unwrap();
        cfg.quorum = 5;
        cfg.max_staleness = 0;
        cfg.validate().unwrap();
        cfg.quorum = 9;
        assert!(cfg.validate().is_err());
        cfg.quorum = 4;
        cfg.transport = "loopback".into();
        cfg.validate().unwrap();
        cfg.transport = "tcp".into();
        cfg.validate().unwrap();
        cfg.transport = "tcp:9000".into();
        cfg.validate().unwrap();
        cfg.transport = "carrier-pigeon".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("inproc | loopback | tcp[:port]"), "{err}");
    }

    #[test]
    fn validate_multiprocess_combinations() {
        // --spawn-workers needs a process-boundary transport.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.spawn_workers = true;
        for t in ["inproc", "loopback"] {
            cfg.transport = t.into();
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("tcp"), "{t}: {err}");
        }
        cfg.transport = "tcp".into();
        cfg.validate().unwrap();
        // tcp workers rebuild their shard from the config: analytic only.
        let mut cfg = TrainConfig::preset("mnist_cnn", "comp-ams-topk:0.01");
        cfg.transport = "tcp".into();
        assert!(cfg.validate().is_err());
        // threaded (in-process) workers contradict process workers.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.transport = "tcp".into();
        cfg.threaded = true;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_sim_combinations() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.transport = "sim:inproc".into();
        cfg.sim_profile = "lossy-wan".into();
        cfg.sim_seed = 7;
        cfg.validate().unwrap();
        cfg.transport = "sim:loopback".into();
        cfg.validate().unwrap();
        // sim cannot wrap a real multi-process transport.
        cfg.transport = "sim:tcp".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("sim cannot wrap tcp"), "{err}");
        assert!(
            err.contains(crate::coordinator::transport::TRANSPORT_CHOICES),
            "{err}"
        );
        // A typo'd profile fails fast even without a sim transport.
        cfg.transport = "inproc".into();
        cfg.sim_profile = "dsl".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("ideal | lan | wan | lossy-wan"), "{err}");
    }

    #[test]
    fn validate_byzantine_ids_and_forms() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 4;
        cfg.byzantine = "3:scale:-3,0:stale".into();
        cfg.validate().unwrap();
        // wid >= n is nonsense: there is no worker 4 in a 4-worker fleet.
        cfg.byzantine = "4:signflip".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(err.contains("0..4"), "{err}");
        // Malformed entries enumerate the accepted forms.
        cfg.byzantine = "0:flip".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("scale") && err.contains("signflip"), "{err}");
    }

    #[test]
    fn validate_robust_agg_combinations() {
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 4;
        cfg.robust_agg = "median".into();
        cfg.validate().unwrap();
        cfg.robust_agg = "trimmed:1".into();
        cfg.validate().unwrap();
        // trimmed:k must leave something in the quorum batch: 2k < batch.
        cfg.robust_agg = "trimmed:2".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("trimmed:2") && err.contains("batch"), "{err}");
        // With quorum 3, trimmed:1 keeps one message; trimmed:2 would not.
        cfg.quorum = 3;
        cfg.robust_agg = "trimmed:1".into();
        cfg.validate().unwrap();
        cfg.quorum = 2;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("2-message batch"), "{err}");
        // 1bitadam's post-warmup merge is not a pluggable aggregate.
        let mut cfg = TrainConfig::preset("quadratic", "1bitadam:10");
        cfg.robust_agg = "median".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("1bitadam"), "{err}");
        cfg.robust_agg = "mean".into();
        cfg.validate().unwrap();
        // The fused Pallas step bakes in mean aggregation.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.fused_update = true;
        cfg.robust_agg = "median".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("fused"), "{err}");
        // Unknown estimators enumerate the accepted forms.
        cfg.fused_update = false;
        cfg.robust_agg = "krum".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("mean | median | trimmed:<k>"), "{err}");
    }

    #[test]
    fn validate_tree_combinations() {
        let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk:0.05");
        cfg.workers = 8;
        cfg.topology = "tree:2".into();
        cfg.validate().unwrap();
        cfg.topology = "tree:4:topk:0.1".into();
        cfg.downlink_compress = "topk:0.1".into();
        cfg.tree_kill = "1:40".into();
        cfg.validate().unwrap();
        // Bad topology strings enumerate the accepted forms.
        cfg.topology = "ring".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("flat | tree:<degree>"), "{err}");
        // The fused artifact is a flat-star full-θ step.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.topology = "tree:4".into();
        cfg.fused_update = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("fused"), "{err}");
        // Sub-leaders live in the leader process: no tcp.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.topology = "tree:4".into();
        cfg.transport = "tcp".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("sub-leaders"), "{err}");
        cfg.transport = "sim:loopback".into();
        cfg.validate().unwrap();
        // Root quorum counts sub-leader groups, not workers: 8 workers at
        // degree 4 is 2 groups.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 8;
        cfg.topology = "tree:4".into();
        cfg.quorum = 2;
        cfg.validate().unwrap();
        cfg.quorum = 3;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("sub-leader groups"), "{err}");
        // Downlink compression / tree-kill without a tree are nonsense.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.downlink_compress = "topk:0.1".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("flat | tree:<degree>"), "{err}");
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.tree_kill = "0:10".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("flat | tree:<degree>"), "{err}");
        // Kill target must name an existing group (8 workers / degree 4).
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 8;
        cfg.topology = "tree:4".into();
        cfg.tree_kill = "2:10".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // A bad downlink compressor spec fails fast.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.topology = "tree:4".into();
        cfg.downlink_compress = "gzip".into();
        assert!(cfg.validate().is_err());
        // trimmed:k must fit the smallest batch anywhere in the tree: 5
        // workers at degree 2 leave a 1-worker last group, which trimmed:1
        // would empty; 9 workers at degree 3 give 3-message batches at
        // both levels, which it survives.
        let mut cfg = TrainConfig::preset("quadratic", "dist-ams");
        cfg.workers = 5;
        cfg.topology = "tree:2".into();
        cfg.robust_agg = "trimmed:1".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("batch"), "{err}");
        cfg.workers = 9;
        cfg.topology = "tree:3".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = TrainConfig::preset("cifar_lenet", "comp-ams-blocksign:4096");
        cfg.schedule = LrSchedule::StepDecay { at: vec![3880, 7760], factor: 10.0 };
        cfg.workers = 4;
        cfg.seed = 7;
        cfg.server_shards = 4;
        cfg.server_threaded = true;
        cfg.transport = "loopback".into();
        cfg.spawn_workers = true;
        cfg.quorum = 3;
        cfg.max_staleness = 5;
        cfg.sim_seed = 99;
        cfg.sim_profile = "lossy-wan".into();
        cfg.byzantine = "1:scale:-3".into();
        cfg.robust_agg = "trimmed:1".into();
        cfg.topology = "tree:2:blocksign:64".into();
        cfg.downlink_compress = "topk:0.25".into();
        cfg.tree_kill = "1:30".into();
        let j = cfg.to_json();
        let back = TrainConfig::from_json(&crate::util::json::parse(
            &j.to_string_pretty(),
        ).unwrap())
        .unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.workers, 4);
        assert_eq!(back.seed, 7);
        assert_eq!(back.schedule, cfg.schedule);
        assert_eq!(back.server_shards, 4);
        assert!(back.server_threaded);
        assert_eq!(back.transport, "loopback");
        assert!(back.spawn_workers);
        assert_eq!(back.quorum, 3);
        assert_eq!(back.max_staleness, 5);
        assert_eq!(back.sim_seed, 99);
        assert_eq!(back.sim_profile, "lossy-wan");
        assert_eq!(back.byzantine, "1:scale:-3");
        assert_eq!(back.robust_agg, "trimmed:1");
        assert_eq!(back.topology, "tree:2:blocksign:64");
        assert_eq!(back.downlink_compress, "topk:0.25");
        assert_eq!(back.tree_kill, "1:30");
    }
}
