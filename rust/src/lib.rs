//! # COMP-AMS: distributed adaptive optimization with gradient compression
//!
//! Production-grade reproduction of *"On Distributed Adaptive Optimization
//! with Gradient Compression"* (Li, Karimi, Li — ICLR 2022): a synchronous
//! data-parallel training framework where each worker compresses its
//! stochastic gradient (Top-k / Block-Sign) with error feedback, and a
//! central leader averages the decoded gradients and applies an AMSGrad
//! update whose moment state lives **only on the leader**.
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L3 (this crate)**: the coordinator — leader/worker round scheduler,
//!   compression codecs + exact wire-format bit ledger, error feedback,
//!   server optimizers, synthetic data substrates, experiment drivers.
//! - **L2 (python/compile, build time)**: JAX models AOT-lowered to HLO
//!   text, executed here through PJRT ([`runtime`]).
//! - **L1 (python/compile/kernels, build time)**: Pallas kernels (fused
//!   AMSGrad update, tiled matmul, block-sign codec) embedded in the HLO.
//!
//! Python never runs on the training path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quick start
//! ```no_run
//! use comp_ams::config::TrainConfig;
//! use comp_ams::coordinator::trainer::train;
//!
//! let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk");
//! cfg.workers = 8;
//! cfg.rounds = 200;
//! let run = train(&cfg).unwrap();
//! println!("final loss {:.4}", run.metrics.last().unwrap().train_loss);
//! ```

pub mod algo;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod grad;
pub mod optim;
pub mod runtime;
pub mod testing;
pub mod util;

pub use config::TrainConfig;
