//! # COMP-AMS: distributed adaptive optimization with gradient compression
//!
//! Production-grade reproduction of *"On Distributed Adaptive Optimization
//! with Gradient Compression"* (Li, Karimi, Li — ICLR 2022): a synchronous
//! data-parallel training framework where each worker compresses its
//! stochastic gradient (Top-k / Block-Sign) with error feedback, and a
//! central leader averages the decoded gradients and applies an AMSGrad
//! update whose moment state lives **only on the leader**.
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L3 (this crate)**: the coordinator — leader/worker round scheduler,
//!   compression codecs + exact wire-format bit ledger, error feedback,
//!   server optimizers, synthetic data substrates, experiment drivers.
//! - **L2 (python/compile, build time)**: JAX models AOT-lowered to HLO
//!   text, executed here through PJRT ([`runtime`]).
//! - **L1 (python/compile/kernels, build time)**: Pallas kernels (fused
//!   AMSGrad update, tiled matmul, block-sign codec) embedded in the HLO.
//!
//! Python never runs on the training path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Module map
//!
//! | module          | what lives there                                                    |
//! |-----------------|---------------------------------------------------------------------|
//! | [`algo`]        | the two-sided protocols ([`algo::WorkerAlgo`] / [`algo::ServerAlgo`]), [`algo::AlgoSpec`] parsing, and the sharded server ([`algo::sharded`]) |
//! | [`compress`]    | Top-k / Random-k / Block-Sign / QSGD compressors, error feedback, and the exact wire codec ([`compress::wire`]) |
//! | [`config`]      | [`TrainConfig`]: presets, validation, JSON round-trip               |
//! | [`coordinator`] | event-driven cluster runtime ([`coordinator::runtime`]), transports ([`coordinator::transport`], TCP sockets in [`coordinator::net`]), worker daemon ([`coordinator::worker`]) + process supervisor ([`coordinator::supervisor`]), worker pool backends, trainer + job checkpoints ([`coordinator::checkpoint`]), the resident multi-job scheduler ([`coordinator::scheduler`]), communication ledger, run metrics |
//! | [`data`]        | synthetic datasets + label-skew sharding (Dirichlet)                |
//! | [`exp`]         | drivers regenerating the paper's figures and tables                 |
//! | [`grad`]        | gradient sources: analytic substrates + the PJRT model path         |
//! | [`optim`]       | server optimizers: AMSGrad, Adam, (momentum) SGD                    |
//! | [`runtime`]     | PJRT client/executable wrappers around the AOT artifacts            |
//! | [`testing`]     | in-tree property-test and micro-bench harnesses                     |
//! | [`util`]        | rng, math, timers, CSV/JSON, CLI parsing                            |
//!
//! Execution is parallel on both sides of the wire while staying
//! bit-deterministic: worker pipelines run on per-worker threads
//! ([`coordinator::cluster::WorkerPool`]) or in separate worker
//! *processes* over TCP (`--transport tcp --spawn-workers`,
//! [`coordinator::net`]), the server update can be
//! partitioned across θ shards ([`algo::sharded::ShardedServer`]), and
//! the leader drives rounds as an event loop over a message transport
//! ([`coordinator::runtime::ClusterRuntime`]) — with optional partial
//! participation (`--quorum K`) where stragglers land as stale
//! gradients instead of blocking the round, and a crashed worker
//! process becomes a permanent straggler instead of killing the run.
//!
//! ## Quick start
//! ```no_run
//! use comp_ams::config::TrainConfig;
//! use comp_ams::coordinator::trainer::train;
//!
//! let mut cfg = TrainConfig::preset("quadratic", "comp-ams-topk");
//! cfg.workers = 8;
//! cfg.rounds = 200;
//! let run = train(&cfg).unwrap();
//! println!("final loss {:.4}", run.metrics.last().unwrap().train_loss);
//! ```

pub mod algo;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod grad;
pub mod optim;
pub mod runtime;
pub mod testing;
pub mod util;

pub use config::TrainConfig;
