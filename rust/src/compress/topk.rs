//! Top-k compressor (paper Definition 1): keep the k coordinates of
//! largest magnitude, zero the rest. Deterministic, biased, q-deviate with
//! q^2 = 1 - k/d (paper Remark 1).
//!
//! Selection is O(d) via `select_nth_unstable` on magnitudes (no full
//! sort); the selected indices are re-sorted ascending so the wire image
//! is canonical (and decode-side cache behaviour is sequential).

use super::wire::Payload;
use super::Compressor;

pub struct TopK {
    ratio: f32,
    /// Transmit half-precision values (48 bits/coord instead of 64 —
    /// the variant that reaches the paper's ~100x at 1% sparsity).
    fp16: bool,
    /// Scratch index buffer reused across calls (hot-path allocation
    /// avoidance; see EXPERIMENTS.md §Perf).
    scratch: Vec<u32>,
}

impl TopK {
    pub fn new(ratio: f32) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0, "topk ratio must be in (0,1]");
        TopK { ratio, fp16: false, scratch: Vec::new() }
    }

    pub fn new_fp16(ratio: f32) -> Self {
        let mut t = Self::new(ratio);
        t.fp16 = true;
        t
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.ratio * d as f32).round() as usize).clamp(1, d)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        if self.fp16 {
            format!("topk16({})", self.ratio)
        } else {
            format!("topk({})", self.ratio)
        }
    }

    fn compress(&mut self, x: &[f32]) -> Payload {
        let d = x.len();
        let k = self.k_for(d);
        self.scratch.clear();
        self.scratch.extend(0..d as u32);
        if k < d {
            // Partition so the k largest-|x| indices occupy the prefix.
            // `total_cmp` + index tie-break make the comparator a total
            // order, so the selected *set* is exactly the first k of the
            // fully sorted (|x| desc, index asc) order — canonical even
            // with duplicated magnitudes or NaNs, where a partial_cmp
            // fallback would let the pivot choice pick the tied winners.
            self.scratch.select_nth_unstable_by(k - 1, |&a, &b| {
                let ma = x[a as usize].abs();
                let mb = x[b as usize].abs();
                mb.total_cmp(&ma).then_with(|| a.cmp(&b))
            });
        }
        let mut idx: Vec<u32> = self.scratch[..k].to_vec();
        idx.sort_unstable();
        if self.fp16 {
            let val: Vec<u16> = idx
                .iter()
                .map(|&i| super::wire::f32_to_f16(x[i as usize]))
                .collect();
            return Payload::SparseF16 { dim: d as u32, idx, val };
        }
        let val: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        Payload::Sparse { dim: d as u32, idx, val }
    }

    fn q(&self, d: usize) -> f32 {
        (1.0 - self.k_for(d) as f32 / d as f32).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2_sq;
    use crate::util::rng::Rng;

    #[test]
    fn selects_largest_magnitudes() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 0.0];
        let p = TopK::new(0.34).compress(&x); // k = round(2.04) = 2
        match &p {
            Payload::Sparse { idx, val, .. } => {
                assert_eq!(idx, &vec![1, 3]);
                assert_eq!(val, &vec![-5.0, 3.0]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn k_at_least_one_and_at_most_d() {
        let t = TopK::new(0.0001);
        assert_eq!(t.k_for(10), 1);
        let t = TopK::new(1.0);
        assert_eq!(t.k_for(10), 10);
    }

    #[test]
    fn full_ratio_is_lossless() {
        let x = vec![3.0f32, -1.0, 2.0];
        let p = TopK::new(1.0).compress(&x);
        assert_eq!(p.to_dense(3).unwrap(), x);
    }

    #[test]
    fn q_deviate_bound_holds() {
        // ||C(x)-x||^2 <= (1 - k/d) ||x||^2 must hold for ANY x (topk is
        // the best k-sparse approximation, so it beats the uniform bound).
        let mut rng = Rng::seed(5);
        for &ratio in &[0.01f32, 0.1, 0.5] {
            let mut c = TopK::new(ratio);
            for trial in 0..20 {
                let d = 50 + trial * 37;
                let x = rng.normal_vec(d);
                let p = c.compress(&x);
                let dense = p.to_dense(d).unwrap();
                let err: f64 = x
                    .iter()
                    .zip(&dense)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                let q2 = (c.q(d) as f64).powi(2);
                assert!(
                    err <= q2 * norm2_sq(&x) + 1e-6,
                    "ratio={ratio} d={d} err={err}"
                );
            }
        }
    }

    #[test]
    fn tied_magnitudes_select_lowest_indices() {
        // Four coordinates share |x| = 2.0; k = 3 must keep the two
        // strictly larger ones plus the lowest-indexed tie.
        let x = vec![2.0f32, -3.0, -2.0, 2.0, 5.0, -2.0];
        let p = TopK::new(0.5).compress(&x);
        match &p {
            Payload::Sparse { idx, val, .. } => {
                assert_eq!(idx, &vec![0, 1, 4]);
                assert_eq!(val, &vec![2.0, -3.0, 5.0]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn deterministic() {
        let mut c = TopK::new(0.1);
        let x: Vec<f32> = (0..100).map(|i| ((i * 37) % 19) as f32 - 9.0).collect();
        assert_eq!(c.compress(&x), c.compress(&x));
    }

    #[test]
    fn compression_ratio_on_wire() {
        // topk(0.01) on d=100_000: 1000 * (idx+val) = ~8KB vs 400KB dense.
        let x = vec![1.0f32; 100_000];
        let p = TopK::new(0.01).compress(&x);
        let dense_bits = Payload::Dense(x).wire_bits();
        assert!(p.wire_bits() * 48 < dense_bits, "{} vs {}", p.wire_bits(), dense_bits);
    }
}
