//! Block-Sign compressor (paper Definition 2): per block B_i, transmit
//! sign(x_{B_i}) and the scale ||x_{B_i}||_1 / |B_i| (the block's mean
//! absolute value). 1 bit/coordinate + one f32 per block on the wire.
//!
//! Two block layouts:
//! - uniform `block`-sized blocks (the generic constructor), and
//! - explicit per-layer blocks ([`BlockSign::with_layout`]) matching the
//!   paper's "blocks are usually set as the distinct network layers".
//!
//! q^2 = 1 - min_i (1/d_i) by Cauchy-Schwarz (paper Remark 1).

use super::wire::{pack_signs, Payload};
use super::Compressor;

pub struct BlockSign {
    /// Uniform block size; ignored when `layout` is set.
    block: usize,
    /// Optional explicit block sizes (summing to d), e.g. layer sizes.
    layout: Option<Vec<usize>>,
}

impl BlockSign {
    pub fn new(block: usize) -> Self {
        assert!(block > 0);
        BlockSign { block, layout: None }
    }

    /// Per-layer blocks: `sizes` must sum to the gradient dimension.
    pub fn with_layout(sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty() && sizes.iter().all(|&s| s > 0));
        BlockSign { block: 0, layout: Some(sizes) }
    }
}

impl Compressor for BlockSign {
    fn name(&self) -> String {
        match &self.layout {
            None => format!("blocksign({})", self.block),
            Some(s) => format!("blocksign(layers={})", s.len()),
        }
    }

    fn compress(&mut self, x: &[f32]) -> Payload {
        match &self.layout {
            None => {
                let b = self.block.min(x.len().max(1));
                let scales = x
                    .chunks(b)
                    .map(|c| c.iter().map(|v| v.abs()).sum::<f32>() / c.len() as f32)
                    .collect();
                Payload::Signs {
                    dim: x.len() as u32,
                    block: b as u32,
                    scales,
                    bits: pack_signs(x),
                }
            }
            Some(sizes) => {
                // Variable-size layer blocks: the wire carries the layout
                // (one u32 per layer), one f32 scale per layer, and the
                // sign bitmap — the exact per-layer semantics of Def. 2.
                let mut scales = Vec::with_capacity(sizes.len());
                let mut off = 0;
                for &s in sizes {
                    let c = &x[off..off + s];
                    scales.push(c.iter().map(|v| v.abs()).sum::<f32>() / s as f32);
                    off += s;
                }
                Payload::LayeredSigns {
                    dim: x.len() as u32,
                    sizes: sizes.iter().map(|&s| s as u32).collect(),
                    scales,
                    bits: pack_signs(x),
                }
            }
        }
    }

    fn q(&self, d: usize) -> f32 {
        let max_block = match &self.layout {
            None => self.block.min(d),
            Some(sizes) => sizes.iter().copied().max().unwrap_or(d),
        };
        (1.0 - 1.0 / max_block as f32).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2_sq;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_is_sign_times_block_mean() {
        let x = vec![1.0f32, -3.0, 2.0, -2.0]; // blocks of 2: scales 2.0, 2.0
        let p = BlockSign::new(2).compress(&x);
        assert_eq!(p.to_dense(4).unwrap(), vec![2.0, -2.0, 2.0, -2.0]);
    }

    #[test]
    fn tail_block_smaller_than_block_size() {
        let x = vec![4.0f32, -4.0, 8.0]; // block 2: [4,-4] scale 4; [8] scale 8
        let p = BlockSign::new(2).compress(&x);
        assert_eq!(p.to_dense(3).unwrap(), vec![4.0, -4.0, 8.0]);
    }

    #[test]
    fn layered_layout_reconstruction() {
        let x = vec![1.0f32, -1.0, 10.0, -10.0, 10.0];
        let mut c = BlockSign::with_layout(vec![2, 3]);
        let p = c.compress(&x);
        assert_eq!(p.to_dense(5).unwrap(), vec![1.0, -1.0, 10.0, -10.0, 10.0]);
    }

    #[test]
    fn q_deviate_bound_holds() {
        let mut rng = Rng::seed(9);
        for &block in &[4usize, 64, 1024] {
            let mut c = BlockSign::new(block);
            for trial in 0..10 {
                let d = block * (trial + 1) + trial; // include ragged tails
                let x = rng.normal_vec(d);
                let p = c.compress(&x);
                let dense = p.to_dense(d).unwrap();
                let err: f64 = x
                    .iter()
                    .zip(&dense)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                let q2 = (c.q(d) as f64).powi(2);
                assert!(err <= q2 * norm2_sq(&x) + 1e-6, "block={block} d={d}");
            }
        }
    }

    #[test]
    fn wire_cost_about_one_bit_per_coord() {
        let x = vec![1.0f32; 32_768];
        let p = BlockSign::new(4096).compress(&x);
        // 1 bit/coord + 8 scales * 32 + header: ~32x less than dense.
        let dense_bits = Payload::Dense(x).wire_bits();
        assert!(p.wire_bits() * 28 < dense_bits);
        assert!(p.wire_bits() > 32_768);
    }
}
