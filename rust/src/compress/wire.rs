//! Wire formats for gradient messages + the exact bit ledger.
//!
//! Figure 2 of the paper plots loss/accuracy against *bits transmitted to
//! the central server*; this module defines precisely what those bits are.
//!
//! ## Byte layout
//!
//! Every payload serializes to a deterministic **little-endian** byte
//! stream opening with a 5-byte header: `tag u8 | dim u32`, where `dim`
//! is the dense dimension the payload decodes to. The bodies are:
//!
//! | variant                | body after the header                                        |
//! |------------------------|--------------------------------------------------------------|
//! | [`Payload::Dense`]     | `d × f32`                                                    |
//! | [`Payload::Sparse`]    | `k u32 \| k × u32 idx \| k × f32 val` (Top-k / Random-k)     |
//! | [`Payload::Signs`]     | `block u32 \| nb u32 \| nb × f32 scales \| ceil(d/8) bytes`  |
//! | [`Payload::LayeredSigns`] | `nb u32 \| nb × u32 sizes \| nb × f32 scales \| ceil(d/8) bytes` |
//! | [`Payload::Quantized`] | `norm f32 \| levels u8 \| d × i8`                            |
//! | [`Payload::SparseF16`] | `k u32 \| k × u32 idx \| k × u16 (IEEE half) val`            |
//!
//! Sign bitmaps store one bit per coordinate, little-endian within each
//! byte (coordinate `i` is bit `i & 7` of byte `i >> 3`); a **set** bit
//! means negative ([`pack_signs`]).
//!
//! ## Bit-accounting rules
//!
//! [`Payload::wire_bits`] is the ledger's source of truth and obeys two
//! invariants, both asserted by the tests here and re-checked by the
//! `uplink_bits` assertions in the coordinator tests:
//!
//! 1. `wire_bits() == 8 * encode().len()` exactly — the ledger counts
//!    real bytes-on-wire, never an estimate;
//! 2. bits are charged **where the payload is produced** (the worker
//!    thread in the threaded backend), so the accounting is identical
//!    across execution backends.
//!
//! Transport framing is layered *on top* of this codec: the event-driven
//! runtime wraps each message in an
//! [`Envelope`](crate::coordinator::transport::Envelope) (worker id +
//! round tag + loss, a fixed 16-byte header ahead of these payload
//! bytes). The envelope header is surfaced via `Envelope::wire_bits` but
//! deliberately excluded from the uplink ledger, so the bit accounting
//! is invariant across transports.
//!
//! ## Shard slicing
//!
//! [`Payload::slice_range`] restricts a payload to a contiguous
//! coordinate range without decoding it, which is how the sharded server
//! ([`crate::algo::sharded`]) routes one uplink message to S per-shard
//! optimizers. Decoding a slice is bitwise identical to slicing the full
//! decode (the slicing property test), so sharded and unsharded servers
//! produce identical trajectories.
//!
//! ## Zero-copy path
//!
//! [`Payload::encode_into`] **appends** the exact [`Payload::encode`]
//! bytes to a caller-owned scratch buffer. The ownership contract for
//! pooled scratch buffers is: the link owns the buffer, the caller
//! `clear()`s it at the start of each frame (capacity is retained, so
//! steady-state encoding never allocates), and the buffer's contents are
//! only valid until the next `clear()`.
//!
//! [`PayloadView::parse`] is the borrowed inverse: it runs exactly the
//! validations of [`Payload::decode`] but keeps every index/value field
//! as a [`Scalars`] view over the frame bytes, decoding little-endian
//! scalars on demand (`chunks_exact` + `from_le_bytes` — no unsafe, no
//! alignment requirements). The lifetime contract: a `PayloadView<'a>`
//! borrows the frame buffer it was parsed from (or the owned payload it
//! was taken from via [`Payload::view`]); it is `Copy`, must not outlive
//! that buffer, and [`PayloadView::to_owned`] rematerializes an owned
//! [`Payload`]. Every consumer hot path (`to_dense`, `add_into`,
//! `slice_range`, `slice_into_shards`, the server aggregation loops)
//! runs off the view; the owned `Payload` methods delegate through
//! [`Payload::view`], so both representations walk the same loops and
//! stay bitwise identical by construction.

use anyhow::{bail, Result};

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_SIGNS: u8 = 3;
const TAG_LAYERED: u8 = 4;
const TAG_QUANTIZED: u8 = 5;
const TAG_SPARSE16: u8 = 6;

#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Dense(Vec<f32>),
    Sparse { dim: u32, idx: Vec<u32>, val: Vec<f32> },
    Signs { dim: u32, block: u32, scales: Vec<f32>, bits: Vec<u8> },
    /// Block-Sign with explicit per-layer block sizes (paper Def. 2 with
    /// blocks = network layers): header | nb u32 | nb*u32 sizes |
    /// nb*f32 scales | ceil(d/8) sign bytes.
    LayeredSigns { dim: u32, sizes: Vec<u32>, scales: Vec<f32>, bits: Vec<u8> },
    /// QSGD stochastic quantization: per-coordinate signed level in
    /// [-levels, levels], reconstructed as q/levels · ‖x‖₂.
    Quantized { dim: u32, norm: f32, levels: u8, q: Vec<i8> },
    /// Top-k with half-precision values (48 bits/coordinate instead of
    /// 64 — the encoding that reaches the paper's ~100× at k/d = 1%).
    SparseF16 { dim: u32, idx: Vec<u32>, val: Vec<u16> },
}

/// f32 -> IEEE 754 half (round-to-nearest-even), software conversion.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf/NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half.
        let half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        // round-to-nearest-even on the truncated 13 bits
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        let out = (half_exp << 10) + half_mant; // mant carry bumps exp
        return sign | out as u16;
    }
    if unbiased >= -24 {
        // Subnormal half: value = half_mant * 2^-24, so
        // half_mant = full_mant * 2^(unbiased + 1) = full >> (-unbiased - 1).
        let shift = (-unbiased - 1) as u32; // 14..=23
        let full = mant | 0x80_0000;
        let mut half_mant = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    sign // underflow -> ±0
}

/// IEEE 754 half -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal half: value = m * 2^-24 (exact in f32)
            let v = m as f32 * (1.0 / (1 << 24) as f32);
            return if sign != 0 { -v } else { v };
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// A scalar that can be read from / written to the little-endian wire.
pub trait WireScalar: Copy {
    /// Encoded size in bytes.
    const SIZE: usize;
    /// Decode one scalar from exactly `SIZE` little-endian bytes.
    fn from_le(bytes: &[u8]) -> Self;
    /// Append this scalar's little-endian bytes.
    fn put_le(self, out: &mut Vec<u8>);
}

impl WireScalar for f32 {
    const SIZE: usize = 4;
    fn from_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl WireScalar for u32 {
    const SIZE: usize = 4;
    fn from_le(bytes: &[u8]) -> u32 {
        u32::from_le_bytes(bytes.try_into().unwrap())
    }
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl WireScalar for u16 {
    const SIZE: usize = 2;
    fn from_le(bytes: &[u8]) -> u16 {
        u16::from_le_bytes(bytes.try_into().unwrap())
    }
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl WireScalar for i8 {
    const SIZE: usize = 1;
    fn from_le(bytes: &[u8]) -> i8 {
        bytes[0] as i8
    }
    fn put_le(self, out: &mut Vec<u8>) {
        out.push(self as u8);
    }
}

/// A borrowed scalar sequence with two representations: a typed slice
/// (when viewing an owned [`Payload`]) or raw little-endian wire bytes
/// (when viewing a received frame via [`PayloadView::parse`]). Hot loops
/// match on the representation once and run a tight loop per arm, so the
/// wire representation never materializes an owned `Vec`.
#[derive(Clone, Copy, Debug)]
pub enum Scalars<'a, T: WireScalar> {
    Slice(&'a [T]),
    Wire(&'a [u8]),
}

impl<'a, T: WireScalar> Scalars<'a, T> {
    pub fn len(&self) -> usize {
        match *self {
            Scalars::Slice(s) => s.len(),
            Scalars::Wire(b) => b.len() / T::SIZE,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode the `i`-th scalar (random access; panics out of range).
    pub fn get(&self, i: usize) -> T {
        match *self {
            Scalars::Slice(s) => s[i],
            Scalars::Wire(b) => T::from_le(&b[i * T::SIZE..(i + 1) * T::SIZE]),
        }
    }

    pub fn iter(&self) -> ScalarsIter<'a, T> {
        match *self {
            Scalars::Slice(s) => ScalarsIter::Slice(s.iter()),
            Scalars::Wire(b) => ScalarsIter::Wire(b.chunks_exact(T::SIZE)),
        }
    }

    pub fn to_vec(self) -> Vec<T> {
        self.iter().collect()
    }

    /// Decode the subrange `[start, end)` into an owned `Vec`.
    pub fn slice_to_vec(self, start: usize, end: usize) -> Vec<T> {
        match self {
            Scalars::Slice(s) => s[start..end].to_vec(),
            Scalars::Wire(b) => b[start * T::SIZE..end * T::SIZE]
                .chunks_exact(T::SIZE)
                .map(T::from_le)
                .collect(),
        }
    }

    /// Append this sequence's wire bytes (memcpy for the wire repr).
    pub fn encode_into(self, out: &mut Vec<u8>) {
        match self {
            Scalars::Slice(s) => {
                out.reserve(s.len() * T::SIZE);
                for &x in s {
                    x.put_le(out);
                }
            }
            Scalars::Wire(b) => out.extend_from_slice(b),
        }
    }
}

pub enum ScalarsIter<'a, T: WireScalar> {
    Slice(std::slice::Iter<'a, T>),
    Wire(std::slice::ChunksExact<'a, u8>),
}

impl<T: WireScalar> Iterator for ScalarsIter<'_, T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        match self {
            ScalarsIter::Slice(it) => it.next().copied(),
            ScalarsIter::Wire(it) => it.next().map(T::from_le),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            ScalarsIter::Slice(it) => it.size_hint(),
            ScalarsIter::Wire(it) => it.size_hint(),
        }
    }
}

impl<T: WireScalar> ExactSizeIterator for ScalarsIter<'_, T> {}

/// Borrowed decode of a [`Payload`]: same variants, but index/value
/// fields are [`Scalars`] views over the source bytes (or owned slices,
/// via [`Payload::view`]). See the module docs for the lifetime
/// contract. All the owned `Payload` consumer methods delegate here, so
/// view and owned paths are the same code.
#[derive(Clone, Copy, Debug)]
pub enum PayloadView<'a> {
    Dense(Scalars<'a, f32>),
    Sparse { dim: u32, idx: Scalars<'a, u32>, val: Scalars<'a, f32> },
    Signs { dim: u32, block: u32, scales: Scalars<'a, f32>, bits: &'a [u8] },
    LayeredSigns {
        dim: u32,
        sizes: Scalars<'a, u32>,
        scales: Scalars<'a, f32>,
        bits: &'a [u8],
    },
    Quantized { dim: u32, norm: f32, levels: u8, q: Scalars<'a, i8> },
    SparseF16 { dim: u32, idx: Scalars<'a, u32>, val: Scalars<'a, u16> },
}

/// Borrow every payload as a [`PayloadView`] (the shape
/// [`crate::algo::ServerAlgo::step`] consumes; test/bench convenience).
pub fn as_views(msgs: &[Payload]) -> Vec<PayloadView<'_>> {
    msgs.iter().map(|m| m.view()).collect()
}

impl<'a> PayloadView<'a> {
    /// Parse a payload without copying its body: runs exactly the
    /// validations of [`Payload::decode`] (tag, length, index-range,
    /// block/size consistency, trailing bytes) but keeps every field as
    /// a view over `buf`.
    pub fn parse(buf: &'a [u8]) -> Result<PayloadView<'a>> {
        let mut r = Reader { b: buf, i: 0 };
        let tag = r.u8()?;
        let dim = r.u32()?;
        let p = match tag {
            TAG_DENSE => PayloadView::Dense(Scalars::Wire(r.take(4 * dim as usize)?)),
            TAG_SPARSE => {
                let k = r.u32()? as usize;
                if k > dim as usize {
                    bail!("sparse k {k} > dim {dim}");
                }
                let idx: Scalars<'a, u32> = Scalars::Wire(r.take(4 * k)?);
                if idx.iter().any(|i| i >= dim) {
                    bail!("sparse index out of range");
                }
                let val = Scalars::Wire(r.take(4 * k)?);
                PayloadView::Sparse { dim, idx, val }
            }
            TAG_SIGNS => {
                let block = r.u32()?;
                if block == 0 {
                    bail!("signs block=0");
                }
                let nb = r.u32()? as usize;
                let expect_nb = (dim as usize).div_ceil(block as usize);
                if nb != expect_nb {
                    bail!("signs nb {nb} != ceil(d/b) {expect_nb}");
                }
                let scales = Scalars::Wire(r.take(4 * nb)?);
                let bits = r.take((dim as usize).div_ceil(8))?;
                PayloadView::Signs { dim, block, scales, bits }
            }
            TAG_LAYERED => {
                let nb = r.u32()? as usize;
                let sizes: Scalars<'a, u32> = Scalars::Wire(r.take(4 * nb)?);
                if sizes.iter().map(|s| s as u64).sum::<u64>() != dim as u64 {
                    bail!("layered sizes do not sum to dim");
                }
                let scales = Scalars::Wire(r.take(4 * nb)?);
                let bits = r.take((dim as usize).div_ceil(8))?;
                PayloadView::LayeredSigns { dim, sizes, scales, bits }
            }
            TAG_QUANTIZED => {
                let norm = f32::from_le_bytes(r.take(4)?.try_into().unwrap());
                let levels = r.u8()?;
                if levels == 0 {
                    bail!("quantized levels=0");
                }
                let q = Scalars::Wire(r.take(dim as usize)?);
                PayloadView::Quantized { dim, norm, levels, q }
            }
            TAG_SPARSE16 => {
                let k = r.u32()? as usize;
                if k > dim as usize {
                    bail!("sparse16 k {k} > dim {dim}");
                }
                let idx: Scalars<'a, u32> = Scalars::Wire(r.take(4 * k)?);
                if idx.iter().any(|i| i >= dim) {
                    bail!("sparse16 index out of range");
                }
                let val = Scalars::Wire(r.take(2 * k)?);
                PayloadView::SparseF16 { dim, idx, val }
            }
            t => bail!("bad payload tag {t}"),
        };
        if r.i != buf.len() {
            bail!("trailing bytes in payload");
        }
        Ok(p)
    }

    /// Rematerialize an owned [`Payload`] (the thin `decode` layer).
    pub fn to_owned(self) -> Payload {
        match self {
            PayloadView::Dense(v) => Payload::Dense(v.to_vec()),
            PayloadView::Sparse { dim, idx, val } => {
                Payload::Sparse { dim, idx: idx.to_vec(), val: val.to_vec() }
            }
            PayloadView::Signs { dim, block, scales, bits } => Payload::Signs {
                dim,
                block,
                scales: scales.to_vec(),
                bits: bits.to_vec(),
            },
            PayloadView::LayeredSigns { dim, sizes, scales, bits } => {
                Payload::LayeredSigns {
                    dim,
                    sizes: sizes.to_vec(),
                    scales: scales.to_vec(),
                    bits: bits.to_vec(),
                }
            }
            PayloadView::Quantized { dim, norm, levels, q } => {
                Payload::Quantized { dim, norm, levels, q: q.to_vec() }
            }
            PayloadView::SparseF16 { dim, idx, val } => {
                Payload::SparseF16 { dim, idx: idx.to_vec(), val: val.to_vec() }
            }
        }
    }

    pub fn dim(&self) -> usize {
        match *self {
            PayloadView::Dense(v) => v.len(),
            PayloadView::Sparse { dim, .. } => dim as usize,
            PayloadView::Signs { dim, .. } => dim as usize,
            PayloadView::LayeredSigns { dim, .. } => dim as usize,
            PayloadView::Quantized { dim, .. } => dim as usize,
            PayloadView::SparseF16 { dim, .. } => dim as usize,
        }
    }

    /// Exact message size in bits (same formulas as
    /// [`Payload::wire_bits`]; `wire_bits() == 8 * encode().len()`).
    pub fn wire_bits(&self) -> u64 {
        let body = match *self {
            PayloadView::Dense(v) => 4 * v.len(),
            PayloadView::Sparse { idx, val, .. } => 4 + 4 * idx.len() + 4 * val.len(),
            PayloadView::Signs { scales, bits, .. } => {
                4 + 4 + 4 * scales.len() + bits.len()
            }
            PayloadView::LayeredSigns { sizes, scales, bits, .. } => {
                4 + 4 * sizes.len() + 4 * scales.len() + bits.len()
            }
            PayloadView::Quantized { q, .. } => 4 + 1 + q.len(),
            PayloadView::SparseF16 { idx, val, .. } => {
                4 + 4 * idx.len() + 2 * val.len()
            }
        };
        ((5 + body) as u64) * 8
    }

    /// Append this payload's exact `encode()` bytes (header + body) to
    /// `out`. Wire-backed views memcpy their body.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match *self {
            PayloadView::Dense(v) => {
                out.push(TAG_DENSE);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                v.encode_into(out);
            }
            PayloadView::Sparse { dim, idx, val } => {
                out.push(TAG_SPARSE);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                idx.encode_into(out);
                val.encode_into(out);
            }
            PayloadView::Signs { dim, block, scales, bits } => {
                out.push(TAG_SIGNS);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
                out.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                scales.encode_into(out);
                out.extend_from_slice(bits);
            }
            PayloadView::LayeredSigns { dim, sizes, scales, bits } => {
                out.push(TAG_LAYERED);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(sizes.len() as u32).to_le_bytes());
                sizes.encode_into(out);
                scales.encode_into(out);
                out.extend_from_slice(bits);
            }
            PayloadView::Quantized { dim, norm, levels, q } => {
                out.push(TAG_QUANTIZED);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&norm.to_le_bytes());
                out.push(levels);
                q.encode_into(out);
            }
            PayloadView::SparseF16 { dim, idx, val } => {
                out.push(TAG_SPARSE16);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                idx.encode_into(out);
                val.encode_into(out);
            }
        }
    }

    /// Dense reconstruction (see [`Payload::to_dense`]).
    pub fn to_dense(&self, d: usize) -> Result<Vec<f32>> {
        if self.dim() != d {
            bail!("payload dim {} != expected {d}", self.dim());
        }
        Ok(match *self {
            PayloadView::Dense(v) => match v {
                Scalars::Slice(s) => s.to_vec(),
                Scalars::Wire(b) => b
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            PayloadView::Sparse { idx, val, .. } => {
                let mut out = vec![0.0f32; d];
                for (i, v) in idx.iter().zip(val.iter()) {
                    out[i as usize] = v;
                }
                out
            }
            PayloadView::Signs { block, scales, bits, .. } => {
                let mut out = vec![0.0f32; d];
                decode_signs_into(&mut out, block as usize, scales, bits);
                out
            }
            PayloadView::LayeredSigns { sizes, scales, bits, .. } => {
                let mut out = vec![0.0f32; d];
                let mut off = 0usize;
                for (sz, scale) in sizes.iter().zip(scales.iter()) {
                    let end = off + sz as usize;
                    write_signs_range(&mut out[off..end], off, scale, bits);
                    off = end;
                }
                out
            }
            PayloadView::Quantized { norm, levels, q, .. } => {
                let scale = norm / levels as f32;
                match q {
                    Scalars::Slice(s) => s.iter().map(|&qi| qi as f32 * scale).collect(),
                    Scalars::Wire(bytes) => {
                        bytes.iter().map(|&b| (b as i8) as f32 * scale).collect()
                    }
                }
            }
            PayloadView::SparseF16 { idx, val, .. } => {
                let mut out = vec![0.0f32; d];
                for (i, v) in idx.iter().zip(val.iter()) {
                    out[i as usize] = f16_to_f32(v);
                }
                out
            }
        })
    }

    /// Accumulate decode into `acc` (see [`Payload::add_into`]).
    pub fn add_into(&self, acc: &mut [f32]) -> Result<()> {
        if self.dim() != acc.len() {
            bail!("payload dim {} != acc {}", self.dim(), acc.len());
        }
        match *self {
            PayloadView::Dense(v) => match v {
                Scalars::Slice(s) => {
                    for (a, &x) in acc.iter_mut().zip(s) {
                        *a += x;
                    }
                }
                Scalars::Wire(b) => {
                    for (a, c) in acc.iter_mut().zip(b.chunks_exact(4)) {
                        *a += f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
            },
            PayloadView::Sparse { idx, val, .. } => {
                for (i, v) in idx.iter().zip(val.iter()) {
                    acc[i as usize] += v;
                }
            }
            PayloadView::Signs { block, scales, bits, .. } => {
                let b = block as usize;
                for (bi, scale) in scales.iter().enumerate() {
                    let start = bi * b;
                    let end = (start + b).min(acc.len());
                    add_signs_range(&mut acc[start..end], start, scale, bits);
                }
            }
            PayloadView::LayeredSigns { sizes, scales, bits, .. } => {
                let mut off = 0usize;
                for (sz, scale) in sizes.iter().zip(scales.iter()) {
                    let end = off + sz as usize;
                    add_signs_range(&mut acc[off..end], off, scale, bits);
                    off = end;
                }
            }
            PayloadView::Quantized { norm, levels, q, .. } => {
                let scale = norm / levels as f32;
                match q {
                    Scalars::Slice(s) => {
                        for (a, &qi) in acc.iter_mut().zip(s) {
                            *a += qi as f32 * scale;
                        }
                    }
                    Scalars::Wire(bytes) => {
                        for (a, &b) in acc.iter_mut().zip(bytes) {
                            *a += (b as i8) as f32 * scale;
                        }
                    }
                }
            }
            PayloadView::SparseF16 { idx, val, .. } => {
                for (i, v) in idx.iter().zip(val.iter()) {
                    acc[i as usize] += f16_to_f32(v);
                }
            }
        }
        Ok(())
    }

    /// Restrict to `[start, end)` without materializing the full decode
    /// (see [`Payload::slice_range`] for the exact semantics).
    pub fn slice_range(&self, start: usize, end: usize) -> Result<Payload> {
        let d = self.dim();
        if start >= end || end > d {
            bail!("bad payload slice [{start}, {end}) of dim {d}");
        }
        let len = (end - start) as u32;
        Ok(match *self {
            PayloadView::Dense(v) => Payload::Dense(v.slice_to_vec(start, end)),
            PayloadView::Sparse { idx, val, .. } => {
                let (si, sv) = slice_sparse(idx, val, start, end);
                Payload::Sparse { dim: len, idx: si, val: sv }
            }
            PayloadView::SparseF16 { idx, val, .. } => {
                let (si, sv) = slice_sparse(idx, val, start, end);
                Payload::SparseF16 { dim: len, idx: si, val: sv }
            }
            PayloadView::Signs { block, scales, bits, .. } => {
                let b = block as usize;
                let mut sizes = Vec::new();
                let mut ss = Vec::new();
                for bi in start / b..=(end - 1) / b {
                    let lo = (bi * b).max(start);
                    let hi = ((bi + 1) * b).min(end);
                    sizes.push((hi - lo) as u32);
                    ss.push(scales.get(bi));
                }
                Payload::LayeredSigns {
                    dim: len,
                    sizes,
                    scales: ss,
                    bits: slice_sign_bits(bits, start, end - start),
                }
            }
            PayloadView::LayeredSigns { sizes, scales, bits, .. } => {
                let mut out_sizes = Vec::new();
                let mut out_scales = Vec::new();
                let mut off = 0usize;
                for (sz, sc) in sizes.iter().zip(scales.iter()) {
                    let seg_end = off + sz as usize;
                    let lo = off.max(start);
                    let hi = seg_end.min(end);
                    if lo < hi {
                        out_sizes.push((hi - lo) as u32);
                        out_scales.push(sc);
                    }
                    off = seg_end;
                }
                Payload::LayeredSigns {
                    dim: len,
                    sizes: out_sizes,
                    scales: out_scales,
                    bits: slice_sign_bits(bits, start, end - start),
                }
            }
            PayloadView::Quantized { norm, levels, q, .. } => Payload::Quantized {
                dim: len,
                norm,
                levels,
                q: q.slice_to_vec(start, end),
            },
        })
    }

    /// One-pass split across `bounds` (see [`Payload::slice_into_shards`]).
    pub fn slice_into_shards(&self, bounds: &[usize]) -> Result<Vec<Payload>> {
        let d = self.dim();
        if bounds.len() < 2
            || bounds.windows(2).any(|w| w[0] >= w[1])
            || *bounds.last().unwrap() > d
        {
            bail!("bad shard bounds {bounds:?} for payload dim {d}");
        }
        match *self {
            PayloadView::Sparse { idx, val, .. } if is_strictly_ascending(idx) => {
                Ok(split_sorted_sparse(idx, val, bounds)
                    .into_iter()
                    .zip(bounds.windows(2))
                    .map(|((si, sv), w)| Payload::Sparse {
                        dim: (w[1] - w[0]) as u32,
                        idx: si,
                        val: sv,
                    })
                    .collect())
            }
            PayloadView::SparseF16 { idx, val, .. } if is_strictly_ascending(idx) => {
                Ok(split_sorted_sparse(idx, val, bounds)
                    .into_iter()
                    .zip(bounds.windows(2))
                    .map(|((si, sv), w)| Payload::SparseF16 {
                        dim: (w[1] - w[0]) as u32,
                        idx: si,
                        val: sv,
                    })
                    .collect())
            }
            // Dense/sign/quantized slices each copy only their own range
            // (already O(d) total across shards); unsorted sparse falls
            // back to the rescan.
            _ => bounds
                .windows(2)
                .map(|w| self.slice_range(w[0], w[1]))
                .collect(),
        }
    }
}

impl Payload {
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { dim, .. } => *dim as usize,
            Payload::Signs { dim, .. } => *dim as usize,
            Payload::LayeredSigns { dim, .. } => *dim as usize,
            Payload::Quantized { dim, .. } => *dim as usize,
            Payload::SparseF16 { dim, .. } => *dim as usize,
        }
    }

    /// Borrow this payload as a [`PayloadView`] (slice-backed). All
    /// consumer methods below delegate through this, so owned and
    /// frame-backed payloads run identical loops.
    pub fn view(&self) -> PayloadView<'_> {
        match self {
            Payload::Dense(v) => PayloadView::Dense(Scalars::Slice(v)),
            Payload::Sparse { dim, idx, val } => PayloadView::Sparse {
                dim: *dim,
                idx: Scalars::Slice(idx),
                val: Scalars::Slice(val),
            },
            Payload::Signs { dim, block, scales, bits } => PayloadView::Signs {
                dim: *dim,
                block: *block,
                scales: Scalars::Slice(scales),
                bits,
            },
            Payload::LayeredSigns { dim, sizes, scales, bits } => {
                PayloadView::LayeredSigns {
                    dim: *dim,
                    sizes: Scalars::Slice(sizes),
                    scales: Scalars::Slice(scales),
                    bits,
                }
            }
            Payload::Quantized { dim, norm, levels, q } => PayloadView::Quantized {
                dim: *dim,
                norm: *norm,
                levels: *levels,
                q: Scalars::Slice(q),
            },
            Payload::SparseF16 { dim, idx, val } => PayloadView::SparseF16 {
                dim: *dim,
                idx: Scalars::Slice(idx),
                val: Scalars::Slice(val),
            },
        }
    }

    /// Dense reconstruction (the server-side decode).
    pub fn to_dense(&self, d: usize) -> Result<Vec<f32>> {
        self.view().to_dense(d)
    }

    /// Accumulate decode into `acc` (server averaging hot path — avoids
    /// allocating a dense temp per worker).
    pub fn add_into(&self, acc: &mut [f32]) -> Result<()> {
        self.view().add_into(acc)
    }

    /// Restrict this payload to the contiguous coordinate range
    /// `[start, end)` without decoding it, yielding a payload over
    /// `end - start` local coordinates (index 0 = global `start`).
    ///
    /// Decoding the slice is **bitwise identical** to slicing the full
    /// decode: sparse indices are filtered and rebased, sign bitmaps are
    /// repacked from bit `start`, and per-block/per-layer scales keep
    /// their original f32 values (a [`Payload::Signs`] slice becomes a
    /// [`Payload::LayeredSigns`] whose segments are the block overlaps,
    /// so a range may start or end mid-block). `Quantized` keeps the
    /// *full-vector* norm so the reconstruction scale is unchanged.
    ///
    /// This is the routing primitive of the sharded server
    /// ([`crate::algo::sharded::ShardedServer`]): each worker uplink is
    /// sliced once per shard and handed to that shard's optimizer.
    pub fn slice_range(&self, start: usize, end: usize) -> Result<Payload> {
        self.view().slice_range(start, end)
    }

    /// Split this payload across the contiguous partition described by
    /// `bounds` (S + 1 strictly ascending fenceposts, `bounds[s]..
    /// bounds[s+1]` per shard; `bounds.last()` ≤ dim) — the sharded
    /// server's per-uplink routing step, done in **one pass**.
    ///
    /// Equivalent to calling [`Payload::slice_range`] once per shard
    /// (bitwise — asserted by the slicing property test), but sparse
    /// payloads walk their k indices once for all S shards instead of
    /// rescanning per shard (the O(S·k) routing cost this replaces). The
    /// single pass needs ascending indices, which Top-k/Random-k emit by
    /// construction; a guarded sortedness check routes hand-built
    /// unsorted `Sparse` payloads through the per-shard fallback.
    pub fn slice_into_shards(&self, bounds: &[usize]) -> Result<Vec<Payload>> {
        self.view().slice_into_shards(bounds)
    }

    /// Exact message size in bits (== 8 * encode().len()).
    pub fn wire_bits(&self) -> u64 {
        let body = match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::Sparse { idx, val, .. } => 4 + 4 * idx.len() + 4 * val.len(),
            Payload::Signs { scales, bits, .. } => 4 + 4 + 4 * scales.len() + bits.len(),
            Payload::LayeredSigns { sizes, scales, bits, .. } => {
                4 + 4 * sizes.len() + 4 * scales.len() + bits.len()
            }
            Payload::Quantized { q, .. } => 4 + 1 + q.len(),
            Payload::SparseF16 { idx, val, .. } => 4 + 4 * idx.len() + 2 * val.len(),
        };
        ((5 + body) as u64) * 8
    }

    // ---- byte codec --------------------------------------------------------

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bits() as usize / 8);
        self.encode_into(&mut out);
        out
    }

    /// Append the exact [`Payload::encode`] bytes to a caller-owned
    /// scratch buffer (see the module docs for the buffer-reuse
    /// contract). This is the allocation-free encode: with a warm
    /// buffer, no heap traffic happens at all.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.view().encode_into(out);
    }

    /// Owned decode: [`PayloadView::parse`] + [`PayloadView::to_owned`]
    /// (all validation lives in the borrowed parse).
    pub fn decode(buf: &[u8]) -> Result<Payload> {
        Ok(PayloadView::parse(buf)?.to_owned())
    }
}

fn decode_signs_into(out: &mut [f32], block: usize, scales: Scalars<'_, f32>, bits: &[u8]) {
    for (bi, scale) in scales.iter().enumerate() {
        let start = bi * block;
        let end = (start + block).min(out.len());
        write_signs_range(&mut out[start..end], start, scale, bits);
    }
}

/// `acc[j] += ±scale` for the sign bits of global coordinates
/// `[global_start, global_start + acc.len())`. Branchless: the sign bit
/// from the bitmap is OR-ed straight into the f32 sign position (scales
/// are non-negative by construction), which is ~15x faster than the
/// naive branch per coordinate (EXPERIMENTS.md §Perf, L3 iteration 1).
/// Word-at-a-time: after a scalar head reaches byte alignment, one
/// bitmap byte load feeds 8 outputs (and LLVM unrolls the inner
/// fixed-trip loop), instead of one byte load + shift per coordinate.
#[inline]
fn add_signs_range(acc: &mut [f32], global_start: usize, scale: f32, bits: &[u8]) {
    let sbits = scale.to_bits();
    // Scalar head until the global coordinate is byte-aligned.
    let head = ((8 - (global_start & 7)) & 7).min(acc.len());
    for (j, a) in acc[..head].iter_mut().enumerate() {
        let i = global_start + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *a += f32::from_bits(sbits | (bit << 31));
    }
    // Byte-at-a-time body: bitmap byte `base + k` feeds outputs
    // `head + 8k ..= head + 8k + 7`.
    let base = (global_start + head) >> 3;
    let done = head + (acc.len() - head) / 8 * 8;
    let mut chunks = acc[head..].chunks_exact_mut(8);
    for (k, chunk) in (&mut chunks).enumerate() {
        let byte = bits[base + k];
        for (j, a) in chunk.iter_mut().enumerate() {
            let bit = ((byte >> j) & 1) as u32;
            *a += f32::from_bits(sbits | (bit << 31));
        }
    }
    // Scalar tail (fewer than 8 coordinates left).
    for (j, a) in chunks.into_remainder().iter_mut().enumerate() {
        let i = global_start + done + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *a += f32::from_bits(sbits | (bit << 31));
    }
}

/// `out[j] = ±scale` variant of [`add_signs_range`] (same word-at-a-time
/// structure — this is the sign-unpack kernel behind `decode_signs_into`).
#[inline]
fn write_signs_range(out: &mut [f32], global_start: usize, scale: f32, bits: &[u8]) {
    let sbits = scale.to_bits();
    let head = ((8 - (global_start & 7)) & 7).min(out.len());
    for (j, o) in out[..head].iter_mut().enumerate() {
        let i = global_start + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *o = f32::from_bits(sbits | (bit << 31));
    }
    let base = (global_start + head) >> 3;
    let done = head + (out.len() - head) / 8 * 8;
    let mut chunks = out[head..].chunks_exact_mut(8);
    for (k, chunk) in (&mut chunks).enumerate() {
        let byte = bits[base + k];
        for (j, o) in chunk.iter_mut().enumerate() {
            let bit = ((byte >> j) & 1) as u32;
            *o = f32::from_bits(sbits | (bit << 31));
        }
    }
    for (j, o) in chunks.into_remainder().iter_mut().enumerate() {
        let i = global_start + done + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *o = f32::from_bits(sbits | (bit << 31));
    }
}

/// Strictly ascending (therefore duplicate-free) index stream? The
/// sortedness guard for the binary-search/single-pass sparse slicing
/// paths — Top-k and Random-k emit ascending indices by construction,
/// but hand-built `Sparse` payloads are not required to.
fn is_strictly_ascending(idx: Scalars<'_, u32>) -> bool {
    let mut it = idx.iter();
    let Some(mut prev) = it.next() else {
        return true;
    };
    for x in it {
        if prev >= x {
            return false;
        }
        prev = x;
    }
    true
}

/// First position in `idx[from..]` whose index is >= `bound` (the
/// `partition_point` equivalent over a [`Scalars`] stream, which has no
/// slice to binary-search directly).
fn lower_bound(idx: Scalars<'_, u32>, from: usize, bound: usize) -> usize {
    let (mut lo, mut hi) = (from, idx.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if (idx.get(mid) as usize) < bound {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Restrict a sparse (index, value) stream to `[start, end)`, rebasing
/// indices. Ascending streams locate the kept run with two binary
/// searches and copy it; unsorted streams fall back to the full scan.
fn slice_sparse<V: WireScalar>(
    idx: Scalars<'_, u32>,
    val: Scalars<'_, V>,
    start: usize,
    end: usize,
) -> (Vec<u32>, Vec<V>) {
    if is_strictly_ascending(idx) {
        let lo = lower_bound(idx, 0, start);
        let hi = lower_bound(idx, lo, end);
        let mut si = Vec::with_capacity(hi - lo);
        let mut sv = Vec::with_capacity(hi - lo);
        for j in lo..hi {
            si.push((idx.get(j) as usize - start) as u32);
            sv.push(val.get(j));
        }
        (si, sv)
    } else {
        let mut si = Vec::new();
        let mut sv = Vec::new();
        for (i, v) in idx.iter().zip(val.iter()) {
            let i = i as usize;
            if (start..end).contains(&i) {
                si.push((i - start) as u32);
                sv.push(v);
            }
        }
        (si, sv)
    }
}

/// One-pass split of an **ascending** sparse stream across the partition
/// `bounds`: each index is visited exactly once, the shard cursor only
/// moves forward. Returns one rebased (idx, val) pair per shard.
fn split_sorted_sparse<V: WireScalar>(
    idx: Scalars<'_, u32>,
    val: Scalars<'_, V>,
    bounds: &[usize],
) -> Vec<(Vec<u32>, Vec<V>)> {
    let shards = bounds.len() - 1;
    let mut out: Vec<(Vec<u32>, Vec<V>)> =
        (0..shards).map(|_| (Vec::new(), Vec::new())).collect();
    let mut s = 0usize;
    for (i, v) in idx.iter().zip(val.iter()) {
        let i = i as usize;
        if i < bounds[0] {
            continue;
        }
        while s < shards && i >= bounds[s + 1] {
            s += 1;
        }
        if s == shards {
            break; // past the last fencepost (ascending: nothing left)
        }
        out[s].0.push((i - bounds[s]) as u32);
        out[s].1.push(v);
    }
    out
}

/// Repack the sign bits of global coordinates `[start, start + len)`
/// into a fresh bitmap whose bit 0 is global coordinate `start` (the
/// [`Payload::slice_range`] helper for the sign-based payloads).
/// Byte-aligned starts are a straight `copy_from_slice`; misaligned
/// starts shift-merge two adjacent source bytes per output byte. Either
/// way the tail byte is masked to `len` bits, so stray source bits past
/// the range never leak into the slice.
fn slice_sign_bits(bits: &[u8], start: usize, len: usize) -> Vec<u8> {
    let nb = len.div_ceil(8);
    let mut out = vec![0u8; nb];
    let base = start >> 3;
    let r = start & 7;
    if r == 0 {
        out.copy_from_slice(&bits[base..base + nb]);
    } else {
        for (k, o) in out.iter_mut().enumerate() {
            let lo = bits[base + k] >> r;
            let hi = bits.get(base + k + 1).map_or(0, |&b| b << (8 - r));
            *o = lo | hi;
        }
    }
    if len & 7 != 0 {
        out[nb - 1] &= (1u8 << (len & 7)) - 1;
    }
    out
}

/// Pack sign bits: bit set == negative. `sign(0) := +1` (bit clear), the
/// convention the Pallas blocksign kernel and the paper's Definition 2
/// use — note this is the `v < 0.0` comparison, NOT the raw IEEE sign
/// bit, so `-0.0` (and negative NaN) pack as positive. Word-at-a-time:
/// 8 floats fold branchlessly into one byte.
pub fn pack_signs(x: &[f32]) -> Vec<u8> {
    let mut bits = vec![0u8; x.len().div_ceil(8)];
    let chunks = x.chunks_exact(8);
    let rem = chunks.remainder();
    for (b, chunk) in bits.iter_mut().zip(chunks) {
        let mut byte = 0u8;
        for (j, &v) in chunk.iter().enumerate() {
            byte |= u8::from(v < 0.0) << j;
        }
        *b = byte;
    }
    if !rem.is_empty() {
        let mut byte = 0u8;
        for (j, &v) in rem.iter().enumerate() {
            byte |= u8::from(v < 0.0) << j;
        }
        *bits.last_mut().unwrap() = byte;
    }
    bits
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("payload truncated");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: &Payload) {
        let buf = p.encode();
        assert_eq!(buf.len() as u64 * 8, p.wire_bits(), "ledger must match bytes");
        let q = Payload::decode(&buf).unwrap();
        assert_eq!(&q, p);
    }

    #[test]
    fn dense_roundtrip() {
        roundtrip(&Payload::Dense(vec![1.5, -2.0, 0.0, f32::MIN_POSITIVE]));
    }

    #[test]
    fn sparse_roundtrip_and_decode() {
        let p = Payload::Sparse { dim: 10, idx: vec![1, 7], val: vec![0.5, -3.0] };
        roundtrip(&p);
        let d = p.to_dense(10).unwrap();
        assert_eq!(d[1], 0.5);
        assert_eq!(d[7], -3.0);
        assert_eq!(d.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn signs_roundtrip_and_decode() {
        let x = vec![1.0f32, -1.0, 2.0, -0.5, 0.0];
        let p = Payload::Signs {
            dim: 5,
            block: 3,
            scales: vec![2.0, 0.25],
            bits: pack_signs(&x),
        };
        roundtrip(&p);
        let d = p.to_dense(5).unwrap();
        assert_eq!(d, vec![2.0, -2.0, 2.0, -0.25, 0.25]); // sign(0) = +1
    }

    #[test]
    fn add_into_matches_to_dense() {
        let ps = [
            Payload::Dense(vec![1.0, 2.0, 3.0, 4.0, 5.0]),
            Payload::Sparse { dim: 5, idx: vec![0, 4], val: vec![-1.0, 2.0] },
            Payload::Signs {
                dim: 5,
                block: 2,
                scales: vec![1.0, 2.0, 3.0],
                bits: pack_signs(&[1.0, -1.0, 1.0, 1.0, -1.0]),
            },
        ];
        for p in &ps {
            let mut acc = vec![0.5f32; 5];
            p.add_into(&mut acc).unwrap();
            let want: Vec<f32> = p
                .to_dense(5)
                .unwrap()
                .iter()
                .map(|&x| x + 0.5)
                .collect();
            assert_eq!(acc, want);
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let p = Payload::Sparse { dim: 8, idx: vec![3], val: vec![1.0] };
        let mut buf = p.encode();
        buf[0] = 99; // bad tag
        assert!(Payload::decode(&buf).is_err());
        let buf = p.encode();
        assert!(Payload::decode(&buf[..buf.len() - 1]).is_err()); // truncated
        let mut buf = p.encode();
        buf.push(0); // trailing
        assert!(Payload::decode(&buf).is_err());
        // out-of-range index
        let bad = Payload::Sparse { dim: 4, idx: vec![9], val: vec![1.0] };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    #[test]
    fn wire_bits_formulas() {
        // Dense d floats: 5 + 4d bytes.
        assert_eq!(Payload::Dense(vec![0.0; 100]).wire_bits(), (5 + 400) * 8);
        // Sparse k of d: 5 + 4 + 8k bytes.
        let p = Payload::Sparse { dim: 1000, idx: vec![0; 10], val: vec![0.0; 10] };
        assert_eq!(p.wire_bits(), (5 + 4 + 80) * 8);
        // Signs: 5 + 8 + 4*nb + ceil(d/8) bytes.
        let p = Payload::Signs {
            dim: 64,
            block: 16,
            scales: vec![0.0; 4],
            bits: vec![0; 8],
        };
        assert_eq!(p.wire_bits(), (5 + 8 + 16 + 8) * 8);
    }

    #[test]
    fn layered_roundtrip_and_decode() {
        let x = vec![1.0f32, -1.0, 5.0, -5.0, 5.0];
        let p = Payload::LayeredSigns {
            dim: 5,
            sizes: vec![2, 3],
            scales: vec![1.0, 5.0],
            bits: pack_signs(&x),
        };
        roundtrip(&p);
        assert_eq!(p.to_dense(5).unwrap(), x);
        let mut acc = vec![1.0f32; 5];
        p.add_into(&mut acc).unwrap();
        assert_eq!(acc, vec![2.0, 0.0, 6.0, -4.0, 6.0]);
        // corrupted sizes rejected
        let bad = Payload::LayeredSigns {
            dim: 5,
            sizes: vec![2, 2],
            scales: vec![1.0, 5.0],
            bits: pack_signs(&x),
        };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    #[test]
    fn f16_conversion_roundtrips_representable_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.5e-5] {
            let h = f32_to_f16(x);
            let back = f16_to_f32(h);
            // 2e-3 relative: subnormal halves (the 1.5e-5 case) quantize
            // at absolute 2^-24.
            assert!(
                (back - x).abs() <= x.abs() * 2e-3 + 1e-7,
                "{x} -> {h:#x} -> {back}"
            );
        }
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // overflow saturates to inf, underflow to zero
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0);
    }

    #[test]
    fn f16_relative_error_bounded_over_random_values() {
        let mut rng = crate::util::rng::Rng::seed(5);
        for _ in 0..5000 {
            let x = rng.normal() * 100.0;
            let back = f16_to_f32(f32_to_f16(x));
            assert!(
                (back - x).abs() <= x.abs() * 1e-3,
                "{x} -> {back}"
            );
        }
    }

    #[test]
    fn quantized_roundtrip_and_decode() {
        let p = Payload::Quantized {
            dim: 4,
            norm: 8.0,
            levels: 4,
            q: vec![-4, 0, 2, 4],
        };
        roundtrip(&p);
        assert_eq!(p.to_dense(4).unwrap(), vec![-8.0, 0.0, 4.0, 8.0]);
        let mut acc = vec![1.0f32; 4];
        p.add_into(&mut acc).unwrap();
        assert_eq!(acc, vec![-7.0, 1.0, 5.0, 9.0]);
        // corrupted levels rejected
        let mut buf = p.encode();
        buf[9] = 0; // levels byte
        assert!(Payload::decode(&buf).is_err());
    }

    #[test]
    fn sparse16_roundtrip_and_decode() {
        let p = Payload::SparseF16 {
            dim: 6,
            idx: vec![1, 5],
            val: vec![f32_to_f16(0.5), f32_to_f16(-3.0)],
        };
        roundtrip(&p);
        let d = p.to_dense(6).unwrap();
        assert_eq!(d[1], 0.5);
        assert_eq!(d[5], -3.0);
        // 48 bits per kept coordinate + 9-byte header + k field
        assert_eq!(p.wire_bits(), (5 + 4 + 2 * 6) as u64 * 8);
        // out-of-range index rejected
        let bad = Payload::SparseF16 { dim: 2, idx: vec![7], val: vec![0] };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    /// Slice `p` at `bounds` fenceposts and check every slice decodes to
    /// exactly the corresponding range of the full decode (bitwise), for
    /// both `to_dense` and `add_into`, and still round-trips the codec.
    fn assert_slices_match(p: &Payload, bounds: &[usize]) {
        let d = p.dim();
        let full = p.to_dense(d).unwrap();
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let s = p.slice_range(lo, hi).unwrap();
            assert_eq!(s.dim(), hi - lo);
            roundtrip(&s);
            let dec = s.to_dense(hi - lo).unwrap();
            for (j, &x) in dec.iter().enumerate() {
                assert_eq!(
                    x.to_bits(),
                    full[lo + j].to_bits(),
                    "coord {} of [{lo}, {hi})",
                    lo + j
                );
            }
            let mut acc = vec![0.25f32; hi - lo];
            s.add_into(&mut acc).unwrap();
            for (j, &x) in acc.iter().enumerate() {
                assert_eq!(x.to_bits(), (full[lo + j] + 0.25).to_bits());
            }
        }
    }

    #[test]
    fn slice_range_all_kinds_uneven_partition() {
        // d = 11 over 3 shards: 4 | 4 | 3 (d % S != 0), and sign blocks of
        // 4 so shard boundaries fall mid-block.
        let bounds = [0usize, 4, 8, 11];
        let x: Vec<f32> = (0..11).map(|i| (i as f32 - 5.0) * 0.5).collect();
        let ps = [
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 11, idx: vec![0, 3, 4, 10], val: vec![1.0, -2.0, 3.5, 0.25] },
            Payload::SparseF16 {
                dim: 11,
                idx: vec![2, 7, 8],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0), f32_to_f16(1.25)],
            },
            Payload::Signs {
                dim: 11,
                block: 4,
                scales: vec![2.0, 0.5, 1.5],
                bits: pack_signs(&x),
            },
            Payload::LayeredSigns {
                dim: 11,
                sizes: vec![3, 6, 2],
                scales: vec![1.0, 0.75, 4.0],
                bits: pack_signs(&x),
            },
            Payload::Quantized {
                dim: 11,
                norm: 8.0,
                levels: 4,
                q: vec![-4, -3, -2, -1, 0, 1, 2, 3, 4, 0, -4],
            },
        ];
        for p in &ps {
            assert_slices_match(p, &bounds);
        }
    }

    #[test]
    fn slice_range_single_coordinate_and_full_range() {
        let p = Payload::Signs {
            dim: 5,
            block: 3,
            scales: vec![2.0, 0.25],
            bits: pack_signs(&[1.0, -1.0, 2.0, -0.5, 0.0]),
        };
        // Whole range: slice is equivalent to the original decode.
        assert_slices_match(&p, &[0, 5]);
        // Every single-coordinate slice.
        assert_slices_match(&p, &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn slice_range_rejects_bad_ranges() {
        let p = Payload::Dense(vec![1.0, 2.0, 3.0]);
        assert!(p.slice_range(1, 1).is_err()); // empty
        assert!(p.slice_range(2, 1).is_err()); // inverted
        assert!(p.slice_range(0, 4).is_err()); // past the end
    }

    #[test]
    fn sparse_slice_filters_and_rebases_indices() {
        let p = Payload::Sparse { dim: 10, idx: vec![1, 4, 7], val: vec![0.5, -3.0, 2.0] };
        let s = p.slice_range(4, 8).unwrap();
        assert_eq!(
            s,
            Payload::Sparse { dim: 4, idx: vec![0, 3], val: vec![-3.0, 2.0] }
        );
        // A range with no surviving indices decodes to zeros.
        let empty = p.slice_range(8, 10).unwrap();
        assert_eq!(empty, Payload::Sparse { dim: 2, idx: vec![], val: vec![] });
        assert_eq!(empty.to_dense(2).unwrap(), vec![0.0, 0.0]);
    }

    #[test]
    fn unsorted_sparse_slices_via_fallback_identically() {
        // Hand-built Sparse payloads need not be sorted; the guarded
        // sortedness check must route them through the rescan and still
        // produce exactly the filtered+rebased result.
        let p = Payload::Sparse {
            dim: 10,
            idx: vec![7, 1, 4],
            val: vec![2.0, 0.5, -3.0],
        };
        let s = p.slice_range(4, 8).unwrap();
        assert_eq!(s, Payload::Sparse { dim: 4, idx: vec![3, 0], val: vec![2.0, -3.0] });
        // slice_into_shards falls back per shard, so concatenated decodes
        // still reproduce the full decode.
        let full = p.to_dense(10).unwrap();
        let mut rebuilt = Vec::new();
        for sh in p.slice_into_shards(&[0, 4, 8, 10]).unwrap() {
            let dim = sh.dim();
            rebuilt.extend(sh.to_dense(dim).unwrap());
        }
        assert_eq!(rebuilt, full);
    }

    #[test]
    fn slice_into_shards_matches_per_shard_slice_range() {
        // The one-pass split must agree payload-for-payload with the S
        // independent slice_range calls (sorted sparse takes the fast
        // path; everything else delegates).
        let bounds = [0usize, 4, 8, 11];
        let x: Vec<f32> = (0..11).map(|i| (i as f32 - 5.0) * 0.5).collect();
        let ps = [
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 11, idx: vec![0, 3, 4, 10], val: vec![1.0, -2.0, 3.5, 0.25] },
            Payload::SparseF16 {
                dim: 11,
                idx: vec![2, 7, 8],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0), f32_to_f16(1.25)],
            },
            Payload::Signs { dim: 11, block: 4, scales: vec![2.0, 0.5, 1.5], bits: pack_signs(&x) },
            Payload::Quantized {
                dim: 11,
                norm: 8.0,
                levels: 4,
                q: vec![-4, -3, -2, -1, 0, 1, 2, 3, 4, 0, -4],
            },
        ];
        for p in &ps {
            let split = p.slice_into_shards(&bounds).unwrap();
            assert_eq!(split.len(), bounds.len() - 1);
            for (k, w) in bounds.windows(2).enumerate() {
                assert_eq!(split[k], p.slice_range(w[0], w[1]).unwrap(), "{p:?} shard {k}");
            }
        }
        // A sparse stream with indices entirely inside one shard.
        let p = Payload::Sparse { dim: 11, idx: vec![5, 6], val: vec![1.0, 2.0] };
        let split = p.slice_into_shards(&bounds).unwrap();
        assert_eq!(split[0], Payload::Sparse { dim: 4, idx: vec![], val: vec![] });
        assert_eq!(split[1], Payload::Sparse { dim: 4, idx: vec![1, 2], val: vec![1.0, 2.0] });
        assert_eq!(split[2], Payload::Sparse { dim: 3, idx: vec![], val: vec![] });
        // Bad bounds are rejected.
        assert!(p.slice_into_shards(&[0]).is_err());
        assert!(p.slice_into_shards(&[0, 4, 4, 11]).is_err());
        assert!(p.slice_into_shards(&[0, 4, 12]).is_err());
    }

    #[test]
    fn pack_signs_zero_is_positive() {
        let bits = pack_signs(&[0.0, -0.0, -1.0]);
        assert_eq!(bits[0] & 1, 0); // +0 -> positive
        // note: -0.0 < 0.0 is false in IEEE, so -0.0 also encodes positive.
        assert_eq!(bits[0] >> 1 & 1, 0);
        assert_eq!(bits[0] >> 2 & 1, 1);
    }

    #[test]
    fn pack_signs_word_path_matches_naive_per_bit() {
        // Cover every length mod 8 (head/body/tail of the word-at-a-time
        // loop) and the edge values whose sign convention is subtle.
        for d in 0..40usize {
            let x: Vec<f32> = (0..d)
                .map(|i| match i % 5 {
                    0 => (i as f32 - 7.5) * 0.3,
                    1 => -0.0,
                    2 => 0.0,
                    3 => f32::NAN,
                    _ => -(i as f32) - 0.25,
                })
                .collect();
            let fast = pack_signs(&x);
            let mut naive = vec![0u8; d.div_ceil(8)];
            for (i, &v) in x.iter().enumerate() {
                if v < 0.0 {
                    naive[i >> 3] |= 1 << (i & 7);
                }
            }
            assert_eq!(fast, naive, "d={d}");
        }
    }

    fn naive_slice_sign_bits(bits: &[u8], start: usize, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len.div_ceil(8)];
        for j in 0..len {
            let i = start + j;
            if (bits[i >> 3] >> (i & 7)) & 1 == 1 {
                out[j >> 3] |= 1 << (j & 7);
            }
        }
        out
    }

    #[test]
    fn slice_sign_bits_matches_naive_over_all_offsets() {
        // Exhaustive (start, len) sweep over pseudo-random bitmaps: hits
        // the aligned copy_from_slice path, every misaligned shift, and
        // every tail-mask width.
        for d in [1usize, 7, 8, 9, 15, 16, 17, 31, 40, 65] {
            let bits: Vec<u8> =
                (0..d.div_ceil(8)).map(|i| ((i * 131 + 89) % 251) as u8).collect();
            for start in 0..d {
                for len in 1..=(d - start) {
                    assert_eq!(
                        slice_sign_bits(&bits, start, len),
                        naive_slice_sign_bits(&bits, start, len),
                        "d={d} start={start} len={len}"
                    );
                }
            }
        }
    }

    fn sample_payloads() -> Vec<Payload> {
        let x: Vec<f32> = (0..21).map(|i| (i as f32 - 9.5) * 0.7).collect();
        vec![
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 21, idx: vec![0, 3, 9, 20], val: vec![1.0, -2.0, 3.5, 0.25] },
            Payload::Signs { dim: 21, block: 6, scales: vec![2.0, 0.5, 1.5, 0.75], bits: pack_signs(&x) },
            Payload::LayeredSigns {
                dim: 21,
                sizes: vec![4, 11, 6],
                scales: vec![1.0, 0.75, 4.0],
                bits: pack_signs(&x),
            },
            Payload::Quantized {
                dim: 21,
                norm: 8.0,
                levels: 4,
                q: (0..21).map(|i| (i % 9) as i8 - 4).collect(),
            },
            Payload::SparseF16 {
                dim: 21,
                idx: vec![2, 7, 8, 13],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0), f32_to_f16(1.25), f32_to_f16(9.0)],
            },
        ]
    }

    #[test]
    fn encode_into_is_byte_identical_to_encode_and_appends() {
        for p in sample_payloads() {
            let owned = p.encode();
            // Appends after existing content, does not clear.
            let mut buf = vec![0xAA, 0xBB, 0xCC];
            p.encode_into(&mut buf);
            assert_eq!(&buf[..3], &[0xAA, 0xBB, 0xCC]);
            assert_eq!(&buf[3..], &owned[..], "{p:?}");
            // Scratch reuse: clear + re-encode reproduces exactly encode().
            buf.clear();
            p.encode_into(&mut buf);
            assert_eq!(buf, owned);
        }
    }

    #[test]
    fn view_parse_matches_owned_decode_for_every_kind() {
        for p in sample_payloads() {
            let bytes = p.encode();
            let view = PayloadView::parse(&bytes).unwrap();
            assert_eq!(view.to_owned(), p, "to_owned roundtrip");
            assert_eq!(view.dim(), p.dim());
            assert_eq!(view.wire_bits(), p.wire_bits());
            // Wire-backed encode_into reproduces the bytes by memcpy.
            let mut re = Vec::new();
            view.encode_into(&mut re);
            assert_eq!(re, bytes);
        }
    }

    #[test]
    fn view_ops_match_owned_ops_bitwise() {
        for p in sample_payloads() {
            let d = p.dim();
            let bytes = p.encode();
            let view = PayloadView::parse(&bytes).unwrap();
            // to_dense parity.
            let a = p.to_dense(d).unwrap();
            let b = view.to_dense(d).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{p:?}");
            }
            // add_into parity.
            let mut acc_a = vec![0.125f32; d];
            let mut acc_b = vec![0.125f32; d];
            p.add_into(&mut acc_a).unwrap();
            view.add_into(&mut acc_b).unwrap();
            for (x, y) in acc_a.iter().zip(&acc_b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            // slice_range on the view equals slice_range on the owned
            // payload (same Payload output, compared structurally), and
            // bad ranges fail on both.
            for (lo, hi) in [(0, d), (0, 5), (3, 11), (7, 8), (d - 1, d)] {
                assert_eq!(view.slice_range(lo, hi).unwrap(), p.slice_range(lo, hi).unwrap());
            }
            assert!(view.slice_range(3, 3).is_err());
            assert!(view.slice_range(0, d + 1).is_err());
            // slice_into_shards parity.
            let bounds = [0usize, 5, 11, d];
            assert_eq!(
                view.slice_into_shards(&bounds).unwrap(),
                p.slice_into_shards(&bounds).unwrap()
            );
        }
    }

    #[test]
    fn view_parse_rejects_exactly_what_decode_rejects() {
        // Corruption parity: the borrowed parse and the owned decode must
        // accept/reject identical byte strings.
        let mut cases: Vec<Vec<u8>> = Vec::new();
        for p in sample_payloads() {
            let good = p.encode();
            cases.push(good.clone()); // accepted
            let mut bad_tag = good.clone();
            bad_tag[0] = 99;
            cases.push(bad_tag);
            cases.push(good[..good.len() - 1].to_vec()); // truncated
            let mut trailing = good.clone();
            trailing.push(0);
            cases.push(trailing);
            let mut flip = good.clone();
            flip[5] ^= 0xFF; // corrupt first body byte (k / block / norm...)
            cases.push(flip);
        }
        // Out-of-range sparse index and zero quantizer levels.
        cases.push(Payload::Sparse { dim: 4, idx: vec![9], val: vec![1.0] }.encode());
        let q = Payload::Quantized { dim: 3, norm: 1.0, levels: 2, q: vec![0, 1, -1] };
        let mut zl = q.encode();
        zl[9] = 0; // levels byte
        cases.push(zl);
        for bytes in cases {
            let owned = Payload::decode(&bytes);
            let view = PayloadView::parse(&bytes);
            assert_eq!(
                owned.is_ok(),
                view.is_ok(),
                "decode/parse disagree on {bytes:?}"
            );
            if let (Ok(o), Ok(v)) = (owned, view) {
                assert_eq!(o, v.to_owned());
            }
        }
    }
}
