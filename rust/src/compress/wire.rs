//! Wire formats for gradient messages + the exact bit ledger.
//!
//! Figure 2 of the paper plots loss/accuracy against *bits transmitted to
//! the central server*; this module defines precisely what those bits are.
//!
//! ## Byte layout
//!
//! Every payload serializes to a deterministic **little-endian** byte
//! stream opening with a 5-byte header: `tag u8 | dim u32`, where `dim`
//! is the dense dimension the payload decodes to. The bodies are:
//!
//! | variant                | body after the header                                        |
//! |------------------------|--------------------------------------------------------------|
//! | [`Payload::Dense`]     | `d × f32`                                                    |
//! | [`Payload::Sparse`]    | `k u32 \| k × u32 idx \| k × f32 val` (Top-k / Random-k)     |
//! | [`Payload::Signs`]     | `block u32 \| nb u32 \| nb × f32 scales \| ceil(d/8) bytes`  |
//! | [`Payload::LayeredSigns`] | `nb u32 \| nb × u32 sizes \| nb × f32 scales \| ceil(d/8) bytes` |
//! | [`Payload::Quantized`] | `norm f32 \| levels u8 \| d × i8`                            |
//! | [`Payload::SparseF16`] | `k u32 \| k × u32 idx \| k × u16 (IEEE half) val`            |
//!
//! Sign bitmaps store one bit per coordinate, little-endian within each
//! byte (coordinate `i` is bit `i & 7` of byte `i >> 3`); a **set** bit
//! means negative ([`pack_signs`]).
//!
//! ## Bit-accounting rules
//!
//! [`Payload::wire_bits`] is the ledger's source of truth and obeys two
//! invariants, both asserted by the tests here and re-checked by the
//! `uplink_bits` assertions in the coordinator tests:
//!
//! 1. `wire_bits() == 8 * encode().len()` exactly — the ledger counts
//!    real bytes-on-wire, never an estimate;
//! 2. bits are charged **where the payload is produced** (the worker
//!    thread in the threaded backend), so the accounting is identical
//!    across execution backends.
//!
//! Transport framing is layered *on top* of this codec: the event-driven
//! runtime wraps each message in an
//! [`Envelope`](crate::coordinator::transport::Envelope) (worker id +
//! round tag + loss, a fixed 16-byte header ahead of these payload
//! bytes). The envelope header is surfaced via `Envelope::wire_bits` but
//! deliberately excluded from the uplink ledger, so the bit accounting
//! is invariant across transports.
//!
//! ## Shard slicing
//!
//! [`Payload::slice_range`] restricts a payload to a contiguous
//! coordinate range without decoding it, which is how the sharded server
//! ([`crate::algo::sharded`]) routes one uplink message to S per-shard
//! optimizers. Decoding a slice is bitwise identical to slicing the full
//! decode (the slicing property test), so sharded and unsharded servers
//! produce identical trajectories.

use anyhow::{bail, Result};

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_SIGNS: u8 = 3;
const TAG_LAYERED: u8 = 4;
const TAG_QUANTIZED: u8 = 5;
const TAG_SPARSE16: u8 = 6;

#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Dense(Vec<f32>),
    Sparse { dim: u32, idx: Vec<u32>, val: Vec<f32> },
    Signs { dim: u32, block: u32, scales: Vec<f32>, bits: Vec<u8> },
    /// Block-Sign with explicit per-layer block sizes (paper Def. 2 with
    /// blocks = network layers): header | nb u32 | nb*u32 sizes |
    /// nb*f32 scales | ceil(d/8) sign bytes.
    LayeredSigns { dim: u32, sizes: Vec<u32>, scales: Vec<f32>, bits: Vec<u8> },
    /// QSGD stochastic quantization: per-coordinate signed level in
    /// [-levels, levels], reconstructed as q/levels · ‖x‖₂.
    Quantized { dim: u32, norm: f32, levels: u8, q: Vec<i8> },
    /// Top-k with half-precision values (48 bits/coordinate instead of
    /// 64 — the encoding that reaches the paper's ~100× at k/d = 1%).
    SparseF16 { dim: u32, idx: Vec<u32>, val: Vec<u16> },
}

/// f32 -> IEEE 754 half (round-to-nearest-even), software conversion.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf/NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half.
        let half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        // round-to-nearest-even on the truncated 13 bits
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        let out = (half_exp << 10) + half_mant; // mant carry bumps exp
        return sign | out as u16;
    }
    if unbiased >= -24 {
        // Subnormal half: value = half_mant * 2^-24, so
        // half_mant = full_mant * 2^(unbiased + 1) = full >> (-unbiased - 1).
        let shift = (-unbiased - 1) as u32; // 14..=23
        let full = mant | 0x80_0000;
        let mut half_mant = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    sign // underflow -> ±0
}

/// IEEE 754 half -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal half: value = m * 2^-24 (exact in f32)
            let v = m as f32 * (1.0 / (1 << 24) as f32);
            return if sign != 0 { -v } else { v };
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

impl Payload {
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { dim, .. } => *dim as usize,
            Payload::Signs { dim, .. } => *dim as usize,
            Payload::LayeredSigns { dim, .. } => *dim as usize,
            Payload::Quantized { dim, .. } => *dim as usize,
            Payload::SparseF16 { dim, .. } => *dim as usize,
        }
    }

    /// Dense reconstruction (the server-side decode).
    pub fn to_dense(&self, d: usize) -> Result<Vec<f32>> {
        if self.dim() != d {
            bail!("payload dim {} != expected {d}", self.dim());
        }
        Ok(match self {
            Payload::Dense(v) => v.clone(),
            Payload::Sparse { idx, val, .. } => {
                let mut out = vec![0.0f32; d];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
                out
            }
            Payload::Signs { block, scales, bits, .. } => {
                let mut out = vec![0.0f32; d];
                decode_signs_into(&mut out, *block as usize, scales, bits);
                out
            }
            Payload::LayeredSigns { sizes, scales, bits, .. } => {
                let mut out = vec![0.0f32; d];
                let mut off = 0usize;
                for (&sz, &scale) in sizes.iter().zip(scales) {
                    let end = off + sz as usize;
                    write_signs_range(&mut out[off..end], off, scale, bits);
                    off = end;
                }
                out
            }
            Payload::Quantized { norm, levels, q, .. } => {
                let scale = norm / *levels as f32;
                q.iter().map(|&qi| qi as f32 * scale).collect()
            }
            Payload::SparseF16 { idx, val, .. } => {
                let mut out = vec![0.0f32; d];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = f16_to_f32(v);
                }
                out
            }
        })
    }

    /// Accumulate decode into `acc` (server averaging hot path — avoids
    /// allocating a dense temp per worker).
    pub fn add_into(&self, acc: &mut [f32]) -> Result<()> {
        if self.dim() != acc.len() {
            bail!("payload dim {} != acc {}", self.dim(), acc.len());
        }
        match self {
            Payload::Dense(v) => {
                for (a, &x) in acc.iter_mut().zip(v) {
                    *a += x;
                }
            }
            Payload::Sparse { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val) {
                    acc[i as usize] += v;
                }
            }
            Payload::Signs { block, scales, bits, .. } => {
                let b = *block as usize;
                for (bi, &scale) in scales.iter().enumerate() {
                    let start = bi * b;
                    let end = (start + b).min(acc.len());
                    add_signs_range(&mut acc[start..end], start, scale, bits);
                }
            }
            Payload::LayeredSigns { sizes, scales, bits, .. } => {
                let mut off = 0usize;
                for (&sz, &scale) in sizes.iter().zip(scales) {
                    let end = off + sz as usize;
                    add_signs_range(&mut acc[off..end], off, scale, bits);
                    off = end;
                }
            }
            Payload::Quantized { norm, levels, q, .. } => {
                let scale = norm / *levels as f32;
                for (a, &qi) in acc.iter_mut().zip(q) {
                    *a += qi as f32 * scale;
                }
            }
            Payload::SparseF16 { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val) {
                    acc[i as usize] += f16_to_f32(v);
                }
            }
        }
        Ok(())
    }

    /// Restrict this payload to the contiguous coordinate range
    /// `[start, end)` without decoding it, yielding a payload over
    /// `end - start` local coordinates (index 0 = global `start`).
    ///
    /// Decoding the slice is **bitwise identical** to slicing the full
    /// decode: sparse indices are filtered and rebased, sign bitmaps are
    /// repacked from bit `start`, and per-block/per-layer scales keep
    /// their original f32 values (a [`Payload::Signs`] slice becomes a
    /// [`Payload::LayeredSigns`] whose segments are the block overlaps,
    /// so a range may start or end mid-block). `Quantized` keeps the
    /// *full-vector* norm so the reconstruction scale is unchanged.
    ///
    /// This is the routing primitive of the sharded server
    /// ([`crate::algo::sharded::ShardedServer`]): each worker uplink is
    /// sliced once per shard and handed to that shard's optimizer.
    pub fn slice_range(&self, start: usize, end: usize) -> Result<Payload> {
        let d = self.dim();
        if start >= end || end > d {
            bail!("bad payload slice [{start}, {end}) of dim {d}");
        }
        let len = (end - start) as u32;
        Ok(match self {
            Payload::Dense(v) => Payload::Dense(v[start..end].to_vec()),
            Payload::Sparse { idx, val, .. } => {
                let (si, sv) = slice_sparse(idx, val, start, end);
                Payload::Sparse { dim: len, idx: si, val: sv }
            }
            Payload::SparseF16 { idx, val, .. } => {
                let (si, sv) = slice_sparse(idx, val, start, end);
                Payload::SparseF16 { dim: len, idx: si, val: sv }
            }
            Payload::Signs { block, scales, bits, .. } => {
                let b = *block as usize;
                let mut sizes = Vec::new();
                let mut ss = Vec::new();
                for bi in start / b..=(end - 1) / b {
                    let lo = (bi * b).max(start);
                    let hi = ((bi + 1) * b).min(end);
                    sizes.push((hi - lo) as u32);
                    ss.push(scales[bi]);
                }
                Payload::LayeredSigns {
                    dim: len,
                    sizes,
                    scales: ss,
                    bits: slice_sign_bits(bits, start, end - start),
                }
            }
            Payload::LayeredSigns { sizes, scales, bits, .. } => {
                let mut out_sizes = Vec::new();
                let mut out_scales = Vec::new();
                let mut off = 0usize;
                for (&sz, &sc) in sizes.iter().zip(scales) {
                    let seg_end = off + sz as usize;
                    let lo = off.max(start);
                    let hi = seg_end.min(end);
                    if lo < hi {
                        out_sizes.push((hi - lo) as u32);
                        out_scales.push(sc);
                    }
                    off = seg_end;
                }
                Payload::LayeredSigns {
                    dim: len,
                    sizes: out_sizes,
                    scales: out_scales,
                    bits: slice_sign_bits(bits, start, end - start),
                }
            }
            Payload::Quantized { norm, levels, q, .. } => Payload::Quantized {
                dim: len,
                norm: *norm,
                levels: *levels,
                q: q[start..end].to_vec(),
            },
        })
    }

    /// Split this payload across the contiguous partition described by
    /// `bounds` (S + 1 strictly ascending fenceposts, `bounds[s]..
    /// bounds[s+1]` per shard; `bounds.last()` ≤ dim) — the sharded
    /// server's per-uplink routing step, done in **one pass**.
    ///
    /// Equivalent to calling [`Payload::slice_range`] once per shard
    /// (bitwise — asserted by the slicing property test), but sparse
    /// payloads walk their k indices once for all S shards instead of
    /// rescanning per shard (the O(S·k) routing cost this replaces). The
    /// single pass needs ascending indices, which Top-k/Random-k emit by
    /// construction; a guarded sortedness check routes hand-built
    /// unsorted `Sparse` payloads through the per-shard fallback.
    pub fn slice_into_shards(&self, bounds: &[usize]) -> Result<Vec<Payload>> {
        let d = self.dim();
        if bounds.len() < 2
            || bounds.windows(2).any(|w| w[0] >= w[1])
            || *bounds.last().unwrap() > d
        {
            bail!("bad shard bounds {bounds:?} for payload dim {d}");
        }
        match self {
            Payload::Sparse { idx, val, .. } if is_strictly_ascending(idx) => {
                Ok(split_sorted_sparse(idx, val, bounds)
                    .into_iter()
                    .zip(bounds.windows(2))
                    .map(|((si, sv), w)| Payload::Sparse {
                        dim: (w[1] - w[0]) as u32,
                        idx: si,
                        val: sv,
                    })
                    .collect())
            }
            Payload::SparseF16 { idx, val, .. } if is_strictly_ascending(idx) => {
                Ok(split_sorted_sparse(idx, val, bounds)
                    .into_iter()
                    .zip(bounds.windows(2))
                    .map(|((si, sv), w)| Payload::SparseF16 {
                        dim: (w[1] - w[0]) as u32,
                        idx: si,
                        val: sv,
                    })
                    .collect())
            }
            // Dense/sign/quantized slices each copy only their own range
            // (already O(d) total across shards); unsorted sparse falls
            // back to the rescan.
            _ => bounds
                .windows(2)
                .map(|w| self.slice_range(w[0], w[1]))
                .collect(),
        }
    }

    /// Exact message size in bits (== 8 * encode().len()).
    pub fn wire_bits(&self) -> u64 {
        let body = match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::Sparse { idx, val, .. } => 4 + 4 * idx.len() + 4 * val.len(),
            Payload::Signs { scales, bits, .. } => 4 + 4 + 4 * scales.len() + bits.len(),
            Payload::LayeredSigns { sizes, scales, bits, .. } => {
                4 + 4 * sizes.len() + 4 * scales.len() + bits.len()
            }
            Payload::Quantized { q, .. } => 4 + 1 + q.len(),
            Payload::SparseF16 { idx, val, .. } => 4 + 4 * idx.len() + 2 * val.len(),
        };
        ((5 + body) as u64) * 8
    }

    // ---- byte codec --------------------------------------------------------

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bits() as usize / 8);
        match self {
            Payload::Dense(v) => {
                out.push(TAG_DENSE);
                out.extend((v.len() as u32).to_le_bytes());
                for &x in v {
                    out.extend(x.to_le_bytes());
                }
            }
            Payload::Sparse { dim, idx, val } => {
                out.push(TAG_SPARSE);
                out.extend(dim.to_le_bytes());
                out.extend((idx.len() as u32).to_le_bytes());
                for &i in idx {
                    out.extend(i.to_le_bytes());
                }
                for &v in val {
                    out.extend(v.to_le_bytes());
                }
            }
            Payload::Signs { dim, block, scales, bits } => {
                out.push(TAG_SIGNS);
                out.extend(dim.to_le_bytes());
                out.extend(block.to_le_bytes());
                out.extend((scales.len() as u32).to_le_bytes());
                for &s in scales {
                    out.extend(s.to_le_bytes());
                }
                out.extend_from_slice(bits);
            }
            Payload::LayeredSigns { dim, sizes, scales, bits } => {
                out.push(TAG_LAYERED);
                out.extend(dim.to_le_bytes());
                out.extend((sizes.len() as u32).to_le_bytes());
                for &s in sizes {
                    out.extend(s.to_le_bytes());
                }
                for &s in scales {
                    out.extend(s.to_le_bytes());
                }
                out.extend_from_slice(bits);
            }
            Payload::Quantized { dim, norm, levels, q } => {
                out.push(TAG_QUANTIZED);
                out.extend(dim.to_le_bytes());
                out.extend(norm.to_le_bytes());
                out.push(*levels);
                out.extend(q.iter().map(|&v| v as u8));
            }
            Payload::SparseF16 { dim, idx, val } => {
                out.push(TAG_SPARSE16);
                out.extend(dim.to_le_bytes());
                out.extend((idx.len() as u32).to_le_bytes());
                for &i in idx {
                    out.extend(i.to_le_bytes());
                }
                for &v in val {
                    out.extend(v.to_le_bytes());
                }
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Payload> {
        let mut r = Reader { b: buf, i: 0 };
        let tag = r.u8()?;
        let dim = r.u32()?;
        let p = match tag {
            TAG_DENSE => {
                let v = r.f32s(dim as usize)?;
                Payload::Dense(v)
            }
            TAG_SPARSE => {
                let k = r.u32()? as usize;
                if k > dim as usize {
                    bail!("sparse k {k} > dim {dim}");
                }
                let idx = r.u32s(k)?;
                if idx.iter().any(|&i| i >= dim) {
                    bail!("sparse index out of range");
                }
                let val = r.f32s(k)?;
                Payload::Sparse { dim, idx, val }
            }
            TAG_SIGNS => {
                let block = r.u32()?;
                if block == 0 {
                    bail!("signs block=0");
                }
                let nb = r.u32()? as usize;
                let expect_nb = (dim as usize).div_ceil(block as usize);
                if nb != expect_nb {
                    bail!("signs nb {nb} != ceil(d/b) {expect_nb}");
                }
                let scales = r.f32s(nb)?;
                let bits = r.bytes((dim as usize).div_ceil(8))?;
                Payload::Signs { dim, block, scales, bits }
            }
            TAG_LAYERED => {
                let nb = r.u32()? as usize;
                let sizes = r.u32s(nb)?;
                if sizes.iter().map(|&s| s as u64).sum::<u64>() != dim as u64 {
                    bail!("layered sizes do not sum to dim");
                }
                let scales = r.f32s(nb)?;
                let bits = r.bytes((dim as usize).div_ceil(8))?;
                Payload::LayeredSigns { dim, sizes, scales, bits }
            }
            TAG_QUANTIZED => {
                let norm = f32::from_le_bytes(r.take(4)?.try_into().unwrap());
                let levels = r.u8()?;
                if levels == 0 {
                    bail!("quantized levels=0");
                }
                let q = r.bytes(dim as usize)?.iter().map(|&b| b as i8).collect();
                Payload::Quantized { dim, norm, levels, q }
            }
            TAG_SPARSE16 => {
                let k = r.u32()? as usize;
                if k > dim as usize {
                    bail!("sparse16 k {k} > dim {dim}");
                }
                let idx = r.u32s(k)?;
                if idx.iter().any(|&i| i >= dim) {
                    bail!("sparse16 index out of range");
                }
                let raw = r.take(2 * k)?;
                let val = raw
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Payload::SparseF16 { dim, idx, val }
            }
            t => bail!("bad payload tag {t}"),
        };
        if r.i != buf.len() {
            bail!("trailing bytes in payload");
        }
        Ok(p)
    }
}

fn decode_signs_into(out: &mut [f32], block: usize, scales: &[f32], bits: &[u8]) {
    for (bi, &scale) in scales.iter().enumerate() {
        let start = bi * block;
        let end = (start + block).min(out.len());
        write_signs_range(&mut out[start..end], start, scale, bits);
    }
}

/// `acc[j] += ±scale` for the sign bits of global coordinates
/// `[global_start, global_start + acc.len())`. Branchless: the sign bit
/// from the bitmap is OR-ed straight into the f32 sign position (scales
/// are non-negative by construction), which is ~15x faster than the
/// naive branch per coordinate (EXPERIMENTS.md §Perf, L3 iteration 1).
#[inline]
fn add_signs_range(acc: &mut [f32], global_start: usize, scale: f32, bits: &[u8]) {
    let sbits = scale.to_bits();
    for (j, a) in acc.iter_mut().enumerate() {
        let i = global_start + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *a += f32::from_bits(sbits | (bit << 31));
    }
}

/// `out[j] = ±scale` variant of [`add_signs_range`].
#[inline]
fn write_signs_range(out: &mut [f32], global_start: usize, scale: f32, bits: &[u8]) {
    let sbits = scale.to_bits();
    for (j, o) in out.iter_mut().enumerate() {
        let i = global_start + j;
        let bit = ((bits[i >> 3] >> (i & 7)) & 1) as u32;
        *o = f32::from_bits(sbits | (bit << 31));
    }
}

/// Strictly ascending (therefore duplicate-free) index stream? The
/// sortedness guard for the `partition_point`/single-pass sparse slicing
/// paths — Top-k and Random-k emit ascending indices by construction,
/// but hand-built `Sparse` payloads are not required to.
fn is_strictly_ascending(idx: &[u32]) -> bool {
    idx.windows(2).all(|w| w[0] < w[1])
}

/// Restrict a sparse (index, value) stream to `[start, end)`, rebasing
/// indices. Ascending streams locate the kept run with two binary
/// searches ([`slice::partition_point`]) and copy it; unsorted streams
/// fall back to the full scan.
fn slice_sparse<V: Copy>(
    idx: &[u32],
    val: &[V],
    start: usize,
    end: usize,
) -> (Vec<u32>, Vec<V>) {
    if is_strictly_ascending(idx) {
        let lo = idx.partition_point(|&i| (i as usize) < start);
        let hi = lo + idx[lo..].partition_point(|&i| (i as usize) < end);
        let si = idx[lo..hi].iter().map(|&i| (i as usize - start) as u32).collect();
        (si, val[lo..hi].to_vec())
    } else {
        let mut si = Vec::new();
        let mut sv = Vec::new();
        for (&i, &v) in idx.iter().zip(val) {
            let i = i as usize;
            if (start..end).contains(&i) {
                si.push((i - start) as u32);
                sv.push(v);
            }
        }
        (si, sv)
    }
}

/// One-pass split of an **ascending** sparse stream across the partition
/// `bounds`: each index is visited exactly once, the shard cursor only
/// moves forward. Returns one rebased (idx, val) pair per shard.
fn split_sorted_sparse<V: Copy>(
    idx: &[u32],
    val: &[V],
    bounds: &[usize],
) -> Vec<(Vec<u32>, Vec<V>)> {
    let shards = bounds.len() - 1;
    let mut out: Vec<(Vec<u32>, Vec<V>)> = (0..shards).map(|_| (Vec::new(), Vec::new())).collect();
    let mut s = 0usize;
    for (&i, &v) in idx.iter().zip(val) {
        let i = i as usize;
        if i < bounds[0] {
            continue;
        }
        while s < shards && i >= bounds[s + 1] {
            s += 1;
        }
        if s == shards {
            break; // past the last fencepost (ascending: nothing left)
        }
        out[s].0.push((i - bounds[s]) as u32);
        out[s].1.push(v);
    }
    out
}

/// Repack the sign bits of global coordinates `[start, start + len)`
/// into a fresh bitmap whose bit 0 is global coordinate `start` (the
/// [`Payload::slice_range`] helper for the sign-based payloads).
fn slice_sign_bits(bits: &[u8], start: usize, len: usize) -> Vec<u8> {
    let mut out = vec![0u8; len.div_ceil(8)];
    for j in 0..len {
        let i = start + j;
        if (bits[i >> 3] >> (i & 7)) & 1 == 1 {
            out[j >> 3] |= 1 << (j & 7);
        }
    }
    out
}

/// Pack sign bits: bit set == negative. `sign(0) := +1` (bit clear), the
/// convention the Pallas blocksign kernel and the paper's Definition 2 use.
pub fn pack_signs(x: &[f32]) -> Vec<u8> {
    let mut bits = vec![0u8; x.len().div_ceil(8)];
    for (i, &v) in x.iter().enumerate() {
        if v < 0.0 {
            bits[i >> 3] |= 1 << (i & 7);
        }
    }
    bits
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("payload truncated");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        Ok(self.take(n)?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: &Payload) {
        let buf = p.encode();
        assert_eq!(buf.len() as u64 * 8, p.wire_bits(), "ledger must match bytes");
        let q = Payload::decode(&buf).unwrap();
        assert_eq!(&q, p);
    }

    #[test]
    fn dense_roundtrip() {
        roundtrip(&Payload::Dense(vec![1.5, -2.0, 0.0, f32::MIN_POSITIVE]));
    }

    #[test]
    fn sparse_roundtrip_and_decode() {
        let p = Payload::Sparse { dim: 10, idx: vec![1, 7], val: vec![0.5, -3.0] };
        roundtrip(&p);
        let d = p.to_dense(10).unwrap();
        assert_eq!(d[1], 0.5);
        assert_eq!(d[7], -3.0);
        assert_eq!(d.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn signs_roundtrip_and_decode() {
        let x = vec![1.0f32, -1.0, 2.0, -0.5, 0.0];
        let p = Payload::Signs {
            dim: 5,
            block: 3,
            scales: vec![2.0, 0.25],
            bits: pack_signs(&x),
        };
        roundtrip(&p);
        let d = p.to_dense(5).unwrap();
        assert_eq!(d, vec![2.0, -2.0, 2.0, -0.25, 0.25]); // sign(0) = +1
    }

    #[test]
    fn add_into_matches_to_dense() {
        let ps = [
            Payload::Dense(vec![1.0, 2.0, 3.0, 4.0, 5.0]),
            Payload::Sparse { dim: 5, idx: vec![0, 4], val: vec![-1.0, 2.0] },
            Payload::Signs {
                dim: 5,
                block: 2,
                scales: vec![1.0, 2.0, 3.0],
                bits: pack_signs(&[1.0, -1.0, 1.0, 1.0, -1.0]),
            },
        ];
        for p in &ps {
            let mut acc = vec![0.5f32; 5];
            p.add_into(&mut acc).unwrap();
            let want: Vec<f32> = p
                .to_dense(5)
                .unwrap()
                .iter()
                .map(|&x| x + 0.5)
                .collect();
            assert_eq!(acc, want);
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let p = Payload::Sparse { dim: 8, idx: vec![3], val: vec![1.0] };
        let mut buf = p.encode();
        buf[0] = 99; // bad tag
        assert!(Payload::decode(&buf).is_err());
        let buf = p.encode();
        assert!(Payload::decode(&buf[..buf.len() - 1]).is_err()); // truncated
        let mut buf = p.encode();
        buf.push(0); // trailing
        assert!(Payload::decode(&buf).is_err());
        // out-of-range index
        let bad = Payload::Sparse { dim: 4, idx: vec![9], val: vec![1.0] };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    #[test]
    fn wire_bits_formulas() {
        // Dense d floats: 5 + 4d bytes.
        assert_eq!(Payload::Dense(vec![0.0; 100]).wire_bits(), (5 + 400) * 8);
        // Sparse k of d: 5 + 4 + 8k bytes.
        let p = Payload::Sparse { dim: 1000, idx: vec![0; 10], val: vec![0.0; 10] };
        assert_eq!(p.wire_bits(), (5 + 4 + 80) * 8);
        // Signs: 5 + 8 + 4*nb + ceil(d/8) bytes.
        let p = Payload::Signs {
            dim: 64,
            block: 16,
            scales: vec![0.0; 4],
            bits: vec![0; 8],
        };
        assert_eq!(p.wire_bits(), (5 + 8 + 16 + 8) * 8);
    }

    #[test]
    fn layered_roundtrip_and_decode() {
        let x = vec![1.0f32, -1.0, 5.0, -5.0, 5.0];
        let p = Payload::LayeredSigns {
            dim: 5,
            sizes: vec![2, 3],
            scales: vec![1.0, 5.0],
            bits: pack_signs(&x),
        };
        roundtrip(&p);
        assert_eq!(p.to_dense(5).unwrap(), x);
        let mut acc = vec![1.0f32; 5];
        p.add_into(&mut acc).unwrap();
        assert_eq!(acc, vec![2.0, 0.0, 6.0, -4.0, 6.0]);
        // corrupted sizes rejected
        let bad = Payload::LayeredSigns {
            dim: 5,
            sizes: vec![2, 2],
            scales: vec![1.0, 5.0],
            bits: pack_signs(&x),
        };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    #[test]
    fn f16_conversion_roundtrips_representable_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.5e-5] {
            let h = f32_to_f16(x);
            let back = f16_to_f32(h);
            // 2e-3 relative: subnormal halves (the 1.5e-5 case) quantize
            // at absolute 2^-24.
            assert!(
                (back - x).abs() <= x.abs() * 2e-3 + 1e-7,
                "{x} -> {h:#x} -> {back}"
            );
        }
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // overflow saturates to inf, underflow to zero
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0);
    }

    #[test]
    fn f16_relative_error_bounded_over_random_values() {
        let mut rng = crate::util::rng::Rng::seed(5);
        for _ in 0..5000 {
            let x = rng.normal() * 100.0;
            let back = f16_to_f32(f32_to_f16(x));
            assert!(
                (back - x).abs() <= x.abs() * 1e-3,
                "{x} -> {back}"
            );
        }
    }

    #[test]
    fn quantized_roundtrip_and_decode() {
        let p = Payload::Quantized {
            dim: 4,
            norm: 8.0,
            levels: 4,
            q: vec![-4, 0, 2, 4],
        };
        roundtrip(&p);
        assert_eq!(p.to_dense(4).unwrap(), vec![-8.0, 0.0, 4.0, 8.0]);
        let mut acc = vec![1.0f32; 4];
        p.add_into(&mut acc).unwrap();
        assert_eq!(acc, vec![-7.0, 1.0, 5.0, 9.0]);
        // corrupted levels rejected
        let mut buf = p.encode();
        buf[9] = 0; // levels byte
        assert!(Payload::decode(&buf).is_err());
    }

    #[test]
    fn sparse16_roundtrip_and_decode() {
        let p = Payload::SparseF16 {
            dim: 6,
            idx: vec![1, 5],
            val: vec![f32_to_f16(0.5), f32_to_f16(-3.0)],
        };
        roundtrip(&p);
        let d = p.to_dense(6).unwrap();
        assert_eq!(d[1], 0.5);
        assert_eq!(d[5], -3.0);
        // 48 bits per kept coordinate + 9-byte header + k field
        assert_eq!(p.wire_bits(), (5 + 4 + 2 * 6) as u64 * 8);
        // out-of-range index rejected
        let bad = Payload::SparseF16 { dim: 2, idx: vec![7], val: vec![0] };
        assert!(Payload::decode(&bad.encode()).is_err());
    }

    /// Slice `p` at `bounds` fenceposts and check every slice decodes to
    /// exactly the corresponding range of the full decode (bitwise), for
    /// both `to_dense` and `add_into`, and still round-trips the codec.
    fn assert_slices_match(p: &Payload, bounds: &[usize]) {
        let d = p.dim();
        let full = p.to_dense(d).unwrap();
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let s = p.slice_range(lo, hi).unwrap();
            assert_eq!(s.dim(), hi - lo);
            roundtrip(&s);
            let dec = s.to_dense(hi - lo).unwrap();
            for (j, &x) in dec.iter().enumerate() {
                assert_eq!(
                    x.to_bits(),
                    full[lo + j].to_bits(),
                    "coord {} of [{lo}, {hi})",
                    lo + j
                );
            }
            let mut acc = vec![0.25f32; hi - lo];
            s.add_into(&mut acc).unwrap();
            for (j, &x) in acc.iter().enumerate() {
                assert_eq!(x.to_bits(), (full[lo + j] + 0.25).to_bits());
            }
        }
    }

    #[test]
    fn slice_range_all_kinds_uneven_partition() {
        // d = 11 over 3 shards: 4 | 4 | 3 (d % S != 0), and sign blocks of
        // 4 so shard boundaries fall mid-block.
        let bounds = [0usize, 4, 8, 11];
        let x: Vec<f32> = (0..11).map(|i| (i as f32 - 5.0) * 0.5).collect();
        let ps = [
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 11, idx: vec![0, 3, 4, 10], val: vec![1.0, -2.0, 3.5, 0.25] },
            Payload::SparseF16 {
                dim: 11,
                idx: vec![2, 7, 8],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0), f32_to_f16(1.25)],
            },
            Payload::Signs {
                dim: 11,
                block: 4,
                scales: vec![2.0, 0.5, 1.5],
                bits: pack_signs(&x),
            },
            Payload::LayeredSigns {
                dim: 11,
                sizes: vec![3, 6, 2],
                scales: vec![1.0, 0.75, 4.0],
                bits: pack_signs(&x),
            },
            Payload::Quantized {
                dim: 11,
                norm: 8.0,
                levels: 4,
                q: vec![-4, -3, -2, -1, 0, 1, 2, 3, 4, 0, -4],
            },
        ];
        for p in &ps {
            assert_slices_match(p, &bounds);
        }
    }

    #[test]
    fn slice_range_single_coordinate_and_full_range() {
        let p = Payload::Signs {
            dim: 5,
            block: 3,
            scales: vec![2.0, 0.25],
            bits: pack_signs(&[1.0, -1.0, 2.0, -0.5, 0.0]),
        };
        // Whole range: slice is equivalent to the original decode.
        assert_slices_match(&p, &[0, 5]);
        // Every single-coordinate slice.
        assert_slices_match(&p, &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn slice_range_rejects_bad_ranges() {
        let p = Payload::Dense(vec![1.0, 2.0, 3.0]);
        assert!(p.slice_range(1, 1).is_err()); // empty
        assert!(p.slice_range(2, 1).is_err()); // inverted
        assert!(p.slice_range(0, 4).is_err()); // past the end
    }

    #[test]
    fn sparse_slice_filters_and_rebases_indices() {
        let p = Payload::Sparse { dim: 10, idx: vec![1, 4, 7], val: vec![0.5, -3.0, 2.0] };
        let s = p.slice_range(4, 8).unwrap();
        assert_eq!(
            s,
            Payload::Sparse { dim: 4, idx: vec![0, 3], val: vec![-3.0, 2.0] }
        );
        // A range with no surviving indices decodes to zeros.
        let empty = p.slice_range(8, 10).unwrap();
        assert_eq!(empty, Payload::Sparse { dim: 2, idx: vec![], val: vec![] });
        assert_eq!(empty.to_dense(2).unwrap(), vec![0.0, 0.0]);
    }

    #[test]
    fn unsorted_sparse_slices_via_fallback_identically() {
        // Hand-built Sparse payloads need not be sorted; the guarded
        // sortedness check must route them through the rescan and still
        // produce exactly the filtered+rebased result.
        let p = Payload::Sparse {
            dim: 10,
            idx: vec![7, 1, 4],
            val: vec![2.0, 0.5, -3.0],
        };
        let s = p.slice_range(4, 8).unwrap();
        assert_eq!(s, Payload::Sparse { dim: 4, idx: vec![3, 0], val: vec![2.0, -3.0] });
        // slice_into_shards falls back per shard, so concatenated decodes
        // still reproduce the full decode.
        let full = p.to_dense(10).unwrap();
        let mut rebuilt = Vec::new();
        for sh in p.slice_into_shards(&[0, 4, 8, 10]).unwrap() {
            let dim = sh.dim();
            rebuilt.extend(sh.to_dense(dim).unwrap());
        }
        assert_eq!(rebuilt, full);
    }

    #[test]
    fn slice_into_shards_matches_per_shard_slice_range() {
        // The one-pass split must agree payload-for-payload with the S
        // independent slice_range calls (sorted sparse takes the fast
        // path; everything else delegates).
        let bounds = [0usize, 4, 8, 11];
        let x: Vec<f32> = (0..11).map(|i| (i as f32 - 5.0) * 0.5).collect();
        let ps = [
            Payload::Dense(x.clone()),
            Payload::Sparse { dim: 11, idx: vec![0, 3, 4, 10], val: vec![1.0, -2.0, 3.5, 0.25] },
            Payload::SparseF16 {
                dim: 11,
                idx: vec![2, 7, 8],
                val: vec![f32_to_f16(0.5), f32_to_f16(-3.0), f32_to_f16(1.25)],
            },
            Payload::Signs { dim: 11, block: 4, scales: vec![2.0, 0.5, 1.5], bits: pack_signs(&x) },
            Payload::Quantized {
                dim: 11,
                norm: 8.0,
                levels: 4,
                q: vec![-4, -3, -2, -1, 0, 1, 2, 3, 4, 0, -4],
            },
        ];
        for p in &ps {
            let split = p.slice_into_shards(&bounds).unwrap();
            assert_eq!(split.len(), bounds.len() - 1);
            for (k, w) in bounds.windows(2).enumerate() {
                assert_eq!(split[k], p.slice_range(w[0], w[1]).unwrap(), "{p:?} shard {k}");
            }
        }
        // A sparse stream with indices entirely inside one shard.
        let p = Payload::Sparse { dim: 11, idx: vec![5, 6], val: vec![1.0, 2.0] };
        let split = p.slice_into_shards(&bounds).unwrap();
        assert_eq!(split[0], Payload::Sparse { dim: 4, idx: vec![], val: vec![] });
        assert_eq!(split[1], Payload::Sparse { dim: 4, idx: vec![1, 2], val: vec![1.0, 2.0] });
        assert_eq!(split[2], Payload::Sparse { dim: 3, idx: vec![], val: vec![] });
        // Bad bounds are rejected.
        assert!(p.slice_into_shards(&[0]).is_err());
        assert!(p.slice_into_shards(&[0, 4, 4, 11]).is_err());
        assert!(p.slice_into_shards(&[0, 4, 12]).is_err());
    }

    #[test]
    fn pack_signs_zero_is_positive() {
        let bits = pack_signs(&[0.0, -0.0, -1.0]);
        assert_eq!(bits[0] & 1, 0); // +0 -> positive
        // note: -0.0 < 0.0 is false in IEEE, so -0.0 also encodes positive.
        assert_eq!(bits[0] >> 1 & 1, 0);
        assert_eq!(bits[0] >> 2 & 1, 1);
    }
}
