//! Gradient compression: the paper's §3.1 compressors plus the wire
//! codecs and error-feedback machinery around them.
//!
//! A [`Compressor`] maps a dense gradient to a [`wire::Payload`], the
//! exact byte-level message a worker uplinks. Compressors here are
//! **q-deviate** (paper Assumption 1): `||C(x) - x|| <= q ||x||` with
//! `q < 1`; the property tests in `testing` check this bound for every
//! implementation.

pub mod blocksign;
pub mod error_feedback;
pub mod qsgd;
pub mod randomk;
pub mod topk;
pub mod wire;

pub use blocksign::BlockSign;
pub use error_feedback::ErrorFeedback;
pub use qsgd::Qsgd;
pub use randomk::RandomK;
pub use topk::TopK;
pub use wire::{as_views, Payload, PayloadView, Scalars};

use anyhow::{bail, Result};

/// A (possibly stateful — Random-k carries an RNG) gradient compressor.
pub trait Compressor: Send {
    fn name(&self) -> String;

    /// Compress a dense vector into a wire payload.
    fn compress(&mut self, x: &[f32]) -> Payload;

    /// The deviate factor `q` for dimension `d` (paper Remark 1);
    /// used by analysis-side diagnostics, not by the protocol itself.
    fn q(&self, d: usize) -> f32;

    /// Serialize compressor state for suspend/resume. Stateless
    /// compressors (Top-k, Block-Sign, identity) have nothing to save;
    /// the stochastic ones (Random-k, QSGD) snapshot their RNG stream so
    /// a resumed run draws the exact same coordinates/roundings as an
    /// uninterrupted one.
    fn export_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore a blob produced by [`Compressor::export_state`].
    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        if !bytes.is_empty() {
            bail!(
                "compressor '{}' is stateless but got a {}-byte state blob",
                self.name(),
                bytes.len()
            );
        }
        Ok(())
    }
}

/// Serialize an [`Rng`](crate::util::rng::Rng) stream for suspend/resume
/// (shared by the stochastic compressors and the gradient sources).
pub(crate) fn export_rng(rng: &crate::util::rng::Rng) -> Vec<u8> {
    use crate::util::bytes::{put_f32, put_u32, put_u64};
    let (s, spare) = rng.state();
    let mut out = Vec::with_capacity(4 * 8 + 4 + 4);
    for lane in s {
        put_u64(&mut out, lane);
    }
    match spare {
        Some(x) => {
            put_u32(&mut out, 1);
            put_f32(&mut out, x);
        }
        None => put_u32(&mut out, 0),
    }
    out
}

/// Inverse of [`export_rng`].
pub(crate) fn import_rng(bytes: &[u8]) -> Result<crate::util::rng::Rng> {
    let mut c = crate::util::bytes::Cursor::new(bytes);
    let s = [c.u64()?, c.u64()?, c.u64()?, c.u64()?];
    let spare = match c.u32()? {
        0 => None,
        1 => Some(c.f32()?),
        k => bail!("bad rng spare flag {k}"),
    };
    c.finish()?;
    Ok(crate::util::rng::Rng::restore(s, spare))
}

/// The identity "compressor": dense f32 payload (full-precision baseline).
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn compress(&mut self, x: &[f32]) -> Payload {
        Payload::Dense(x.to_vec())
    }

    fn q(&self, _d: usize) -> f32 {
        0.0
    }
}

/// Compressor spec as it appears in configs / CLI flags.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorSpec {
    Identity,
    /// Top-k with ratio k/d (paper uses 0.01).
    TopK { ratio: f32 },
    /// Block-Sign with a fixed block size (uniform blocks; the paper's
    /// per-layer blocks are approximated by `block` = typical layer size —
    /// see `algo` for the layer-block variant wired from the manifest).
    BlockSign { block: usize },
    /// Random-k (unbiased sparsifier baseline).
    RandomK { ratio: f32, seed: u64 },
    /// Top-k with half-precision values (48 bits/coordinate — the
    /// encoding behind the paper's ~100x claim at 1% sparsity).
    TopK16 { ratio: f32 },
    /// QSGD stochastic quantization with `levels` magnitude levels.
    Qsgd { levels: u8, seed: u64 },
}

impl CompressorSpec {
    pub fn build(&self) -> Box<dyn Compressor> {
        match self {
            CompressorSpec::Identity => Box::new(Identity),
            CompressorSpec::TopK { ratio } => Box::new(TopK::new(*ratio)),
            CompressorSpec::BlockSign { block } => Box::new(BlockSign::new(*block)),
            CompressorSpec::RandomK { ratio, seed } => {
                Box::new(RandomK::new(*ratio, *seed))
            }
            CompressorSpec::TopK16 { ratio } => Box::new(TopK::new_fp16(*ratio)),
            CompressorSpec::Qsgd { levels, seed } => Box::new(Qsgd::new(*levels, *seed)),
        }
    }

    pub fn parse(s: &str) -> Result<CompressorSpec> {
        // "identity" | "topk:0.01" | "blocksign:4096" | "randomk:0.01"
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        Ok(match kind {
            "identity" | "none" => CompressorSpec::Identity,
            "topk" => CompressorSpec::TopK {
                ratio: arg.unwrap_or("0.01").parse()?,
            },
            "blocksign" | "bsign" => CompressorSpec::BlockSign {
                block: arg.map(|a| a.parse()).transpose()?.unwrap_or(4096),
            },
            "randomk" => CompressorSpec::RandomK {
                ratio: arg.unwrap_or("0.01").parse()?,
                seed: 0,
            },
            "topk16" => CompressorSpec::TopK16 {
                ratio: arg.unwrap_or("0.01").parse()?,
            },
            "qsgd" => CompressorSpec::Qsgd {
                levels: arg.map(|a| a.parse()).transpose()?.unwrap_or(4),
                seed: 0,
            },
            _ => bail!("unknown compressor '{s}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrips_exactly() {
        let x = vec![1.0f32, -2.0, 0.5];
        let p = Identity.compress(&x);
        assert_eq!(p.to_dense(3).unwrap(), x);
        assert_eq!(Identity.q(100), 0.0);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(
            CompressorSpec::parse("topk:0.05").unwrap(),
            CompressorSpec::TopK { ratio: 0.05 }
        );
        assert_eq!(
            CompressorSpec::parse("blocksign:128").unwrap(),
            CompressorSpec::BlockSign { block: 128 }
        );
        assert_eq!(CompressorSpec::parse("none").unwrap(), CompressorSpec::Identity);
        assert!(CompressorSpec::parse("bogus").is_err());
    }

    #[test]
    fn spec_builds_named_compressors() {
        assert_eq!(CompressorSpec::parse("topk:0.01").unwrap().build().name(), "topk(0.01)");
        assert_eq!(
            CompressorSpec::parse("blocksign:64").unwrap().build().name(),
            "blocksign(64)"
        );
    }
}
