//! QSGD-style stochastic quantizer (Alistarh et al. 2017; paper §2.1).
//!
//! Quantizes each coordinate to one of `s` levels of |x|/‖x‖₂ with
//! *unbiased* stochastic rounding: E[Q(x)] = x. Unlike Top-k/Block-Sign
//! it is not biased, so it converges without error feedback — it is the
//! quantization-family baseline for the ablation benches, and its wire
//! cost (⌈log2(s+1)⌉+1 bits/coordinate + one f32 norm) sits between
//! Block-Sign and the sparsifiers.
//!
//! Wire format: the quantized magnitudes ride in a `Sparse`-free dense
//! small-int layout — we reuse `Payload::Quantized`.

use crate::util::rng::Rng;

use super::wire::Payload;
use super::Compressor;

pub struct Qsgd {
    /// Number of quantization levels (e.g. 1 = ternary sign·‖x‖, 255 = 8-bit).
    levels: u8,
    rng: Rng,
}

impl Qsgd {
    pub fn new(levels: u8, seed: u64) -> Self {
        assert!(levels >= 1);
        Qsgd { levels, rng: Rng::seed(seed ^ 0x4590D) }
    }

    pub fn levels(&self) -> u8 {
        self.levels
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd({})", self.levels)
    }

    fn compress(&mut self, x: &[f32]) -> Payload {
        let norm = crate::util::math::norm2(x) as f32;
        let s = self.levels as f32;
        let mut q = Vec::with_capacity(x.len());
        if norm == 0.0 {
            q.resize(x.len(), 0i8);
            return Payload::Quantized { dim: x.len() as u32, norm: 0.0, levels: self.levels, q };
        }
        for &v in x {
            let r = v.abs() / norm * s; // in [0, s]
            let floor = r.floor();
            let p = r - floor; // stochastic rounding up with prob p
            let mag = floor + if (self.rng.next_f32() as f32) < p { 1.0 } else { 0.0 };
            let signed = if v < 0.0 { -mag } else { mag };
            q.push(signed as i8);
        }
        Payload::Quantized { dim: x.len() as u32, norm, levels: self.levels, q }
    }

    /// QSGD is unbiased, not q-deviate; its *variance* bound plays the
    /// analogous role. We report the worst-case relative second moment
    /// sqrt(min(d/s², √d/s)) capped below 1 for diagnostics.
    fn q(&self, d: usize) -> f32 {
        let s = self.levels as f32;
        let v = (d as f32 / (s * s)).min((d as f32).sqrt() / s);
        v.sqrt().min(0.999)
    }

    fn export_state(&self) -> Vec<u8> {
        super::export_rng(&self.rng)
    }

    fn import_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        self.rng = super::import_rng(bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math;

    #[test]
    fn reconstruction_is_unbiased() {
        let x: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.3).sin()).collect();
        let mut c = Qsgd::new(4, 1);
        let mut mean = vec![0.0f32; 64];
        let n = 3000;
        for _ in 0..n {
            let d = c.compress(&x).to_dense(64).unwrap();
            math::axpy(1.0 / n as f32, &d, &mut mean);
        }
        for i in 0..64 {
            assert!(
                (mean[i] - x[i]).abs() < 0.05,
                "coord {i}: {} vs {}",
                mean[i],
                x[i]
            );
        }
    }

    #[test]
    fn levels_bound_quantized_values() {
        let mut c = Qsgd::new(8, 2);
        let mut rng = Rng::seed(3);
        let x = rng.normal_vec(500);
        match c.compress(&x) {
            Payload::Quantized { q, .. } => {
                assert!(q.iter().all(|&v| v.unsigned_abs() <= 8));
            }
            _ => panic!("expected quantized payload"),
        }
    }

    #[test]
    fn zero_vector_roundtrips() {
        let mut c = Qsgd::new(4, 4);
        let x = vec![0.0f32; 32];
        let p = c.compress(&x);
        assert_eq!(p.to_dense(32).unwrap(), x);
    }

    #[test]
    fn wire_cost_one_byte_per_coord_plus_header() {
        let mut c = Qsgd::new(4, 5);
        let x = vec![1.0f32; 10_000];
        let p = c.compress(&x);
        // header 5 + norm 4 + levels 1 + q bytes
        assert_eq!(p.wire_bits(), (5 + 4 + 1 + 10_000) as u64 * 8);
        let dense = Payload::Dense(x).wire_bits();
        assert!(p.wire_bits() * 3 < dense); // ~4x smaller than f32
    }
}
