//! Random-k sparsifier (baseline compressor, Stich et al. 2018): keep k
//! uniformly random coordinates. Unlike Top-k it is oblivious to the
//! gradient, so it satisfies the q-deviate bound only in expectation —
//! still covered by error feedback. Used by the ablation benches to show
//! magnitude-aware selection matters.

use crate::util::rng::Rng;

use super::wire::Payload;
use super::Compressor;

pub struct RandomK {
    ratio: f32,
    rng: Rng,
}

impl RandomK {
    pub fn new(ratio: f32, seed: u64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        RandomK { ratio, rng: Rng::seed(seed ^ 0x52414E_444B) }
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.ratio * d as f32).round() as usize).clamp(1, d)
    }
}

impl Compressor for RandomK {
    fn name(&self) -> String {
        format!("randomk({})", self.ratio)
    }

    fn compress(&mut self, x: &[f32]) -> Payload {
        let d = x.len();
        let k = self.k_for(d);
        // Floyd's algorithm: k distinct uniform indices in O(k).
        let mut chosen = std::collections::BTreeSet::new();
        for j in (d - k)..d {
            let t = self.rng.gen_range(j + 1) as u32;
            if !chosen.insert(t) {
                chosen.insert(j as u32);
            }
        }
        let idx: Vec<u32> = chosen.into_iter().collect();
        let val: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        Payload::Sparse { dim: d as u32, idx, val }
    }

    fn q(&self, d: usize) -> f32 {
        (1.0 - self.k_for(d) as f32 / d as f32).max(0.0).sqrt()
    }

    fn export_state(&self) -> Vec<u8> {
        super::export_rng(&self.rng)
    }

    fn import_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        self.rng = super::import_rng(bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_distinct_indices_in_range() {
        let mut c = RandomK::new(0.1, 7);
        let x = vec![1.0f32; 500];
        for _ in 0..10 {
            match c.compress(&x) {
                Payload::Sparse { idx, .. } => {
                    assert_eq!(idx.len(), 50);
                    let set: std::collections::BTreeSet<_> = idx.iter().collect();
                    assert_eq!(set.len(), 50);
                    assert!(idx.iter().all(|&i| i < 500));
                }
                _ => panic!("expected sparse"),
            }
        }
    }

    #[test]
    fn values_match_source() {
        let x: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut c = RandomK::new(0.2, 3);
        if let Payload::Sparse { idx, val, .. } = c.compress(&x) {
            for (&i, &v) in idx.iter().zip(&val) {
                assert_eq!(v, x[i as usize]);
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn different_rounds_pick_different_sets() {
        let x = vec![1.0f32; 1000];
        let mut c = RandomK::new(0.01, 11);
        let a = c.compress(&x);
        let b = c.compress(&x);
        assert_ne!(a, b);
    }

    #[test]
    fn seeded_reproducibility() {
        let x = vec![2.0f32; 64];
        let mut a = RandomK::new(0.25, 42);
        let mut b = RandomK::new(0.25, 42);
        assert_eq!(a.compress(&x), b.compress(&x));
    }
}
