//! Error feedback (paper Algorithm 2, lines 7-8).
//!
//! Each worker keeps a residual `e` of everything compression has dropped
//! so far. On each round it compresses the *corrected* gradient
//! `g + e` and retains the new residual `e' = (g + e) - C(g + e)`.
//!
//! The invariant tested here (and by `testing::prop`) is the telescoping
//! conservation law:  `decode(C(g+e)) + e' == g + e`  exactly (up to f32
//! rounding of the subtraction), which is what makes biased compressors
//! convergent (Karimireddy et al. 2019; paper Theorem 1).

use anyhow::Result;

use super::wire::Payload;
use super::Compressor;

pub struct ErrorFeedback {
    e: Vec<f32>,
    enabled: bool,
    /// Scratch for the corrected gradient (avoids per-round allocation).
    corrected: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize, enabled: bool) -> Self {
        ErrorFeedback { e: vec![0.0; dim], enabled, corrected: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    pub fn residual(&self) -> &[f32] {
        &self.e
    }

    pub fn residual_norm(&self) -> f64 {
        crate::util::math::norm2(&self.e)
    }

    /// Serialize the residual for suspend/resume (the `enabled` flag and
    /// dimension are rebuilt from the config; only `e` is trajectory
    /// state).
    pub fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + 4 * self.e.len());
        crate::util::bytes::put_f32s(&mut out, &self.e);
        out
    }

    /// Restore a blob produced by [`ErrorFeedback::export_state`].
    pub fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = crate::util::bytes::Cursor::new(bytes);
        let e = c.f32s()?;
        c.finish()?;
        anyhow::ensure!(
            e.len() == self.e.len(),
            "error-feedback residual dim mismatch: blob {} vs {}",
            e.len(),
            self.e.len()
        );
        self.e = e;
        Ok(())
    }

    /// Compress `g` with residual correction; updates the residual.
    pub fn compress(&mut self, g: &[f32], c: &mut dyn Compressor) -> Result<Payload> {
        assert_eq!(g.len(), self.e.len());
        if !self.enabled {
            return Ok(c.compress(g));
        }
        // corrected = g + e
        for ((dst, &gi), &ei) in self.corrected.iter_mut().zip(g).zip(&self.e) {
            *dst = gi + ei;
        }
        let payload = c.compress(&self.corrected);
        // e' = corrected - decode(payload). Exploit payload structure to
        // avoid a dense decode for sparse messages (hot path).
        match &payload {
            Payload::Sparse { idx, .. } => {
                self.e.copy_from_slice(&self.corrected);
                for &i in idx {
                    self.e[i as usize] = 0.0;
                }
            }
            _ => {
                let dense = payload.to_dense(g.len())?;
                for ((ei, &ci), &di) in
                    self.e.iter_mut().zip(&self.corrected).zip(&dense)
                {
                    *ei = ci - di;
                }
            }
        }
        Ok(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{BlockSign, Identity, TopK};
    use crate::util::rng::Rng;

    fn conservation_check(c: &mut dyn Compressor, dim: usize, rounds: usize) {
        let mut ef = ErrorFeedback::new(dim, true);
        let mut rng = Rng::seed(1234);
        for _ in 0..rounds {
            let g = rng.normal_vec(dim);
            let before: Vec<f32> =
                g.iter().zip(ef.residual()).map(|(&a, &b)| a + b).collect();
            let p = ef.compress(&g, c).unwrap();
            let decoded = p.to_dense(dim).unwrap();
            for ((&c_i, &e_i), &b_i) in
                decoded.iter().zip(ef.residual()).zip(&before)
            {
                assert!((c_i + e_i - b_i).abs() <= 1e-5 * b_i.abs().max(1.0));
            }
        }
    }

    #[test]
    fn conservation_topk() {
        conservation_check(&mut TopK::new(0.05), 500, 20);
    }

    #[test]
    fn conservation_blocksign() {
        conservation_check(&mut BlockSign::new(64), 500, 20);
    }

    #[test]
    fn identity_leaves_zero_residual() {
        let mut ef = ErrorFeedback::new(100, true);
        let mut rng = Rng::seed(5);
        let g = rng.normal_vec(100);
        ef.compress(&g, &mut Identity).unwrap();
        assert!(ef.residual_norm() < 1e-6);
    }

    #[test]
    fn disabled_ef_never_accumulates() {
        let mut ef = ErrorFeedback::new(200, false);
        let mut c = TopK::new(0.01);
        let mut rng = Rng::seed(6);
        for _ in 0..5 {
            let g = rng.normal_vec(200);
            let p = ef.compress(&g, &mut c).unwrap();
            // Without EF the payload is exactly C(g).
            assert_eq!(p, c.compress(&g));
            assert_eq!(ef.residual_norm(), 0.0);
        }
    }

    #[test]
    fn residual_bounded_over_time() {
        // Lemma 2: ||e_t||^2 <= 4q^2/(1-q^2)^2 * G^2 for bounded gradients.
        let dim = 1000;
        let mut ef = ErrorFeedback::new(dim, true);
        let mut c = TopK::new(0.1);
        let mut rng = Rng::seed(7);
        let mut max_norm: f64 = 0.0;
        for _ in 0..100 {
            let g = rng.normal_vec(dim);
            ef.compress(&g, &mut c).unwrap();
            max_norm = max_norm.max(ef.residual_norm());
        }
        let g_bound = (dim as f64).sqrt() * 4.0; // ~max ||g|| whp
        let q = c.q(dim) as f64;
        let lemma2 = 2.0 * q / (1.0 - q * q) * g_bound;
        assert!(max_norm <= lemma2, "{max_norm} vs {lemma2}");
    }
}
