//! Gradient sources: where a worker's stochastic gradient comes from.
//!
//! The coordinator is generic over [`GradSource`], with two families:
//!
//! - [`pjrt_model::PjrtSource`] — the real path: the AOT-compiled JAX
//!   model (L2, with L1 Pallas kernels inside) executed via PJRT on a
//!   synthetic-data shard.
//! - [`quadratic::QuadraticSource`] / [`logistic::LogisticSource`] —
//!   analytic pure-Rust objectives with *controllable* local variance σ²
//!   and global variance σ_g² (Assumption 4), used by the property /
//!   integration tests and the fast mode of the speedup experiment where
//!   thousands of rounds are needed.

pub mod logistic;
pub mod pjrt_model;
pub mod quadratic;

pub use logistic::LogisticSource;
pub use pjrt_model::{PjrtEvaluator, PjrtSource};
pub use quadratic::QuadraticSource;

use anyhow::Result;

/// A worker-local stochastic gradient oracle.
pub trait GradSource {
    fn dim(&self) -> usize;

    /// Loss and gradient of the worker's objective on its next local
    /// mini-batch, evaluated at `theta`. `round` seeds per-round
    /// randomness (dropout) deterministically.
    fn grad(&mut self, theta: &[f32], round: u64) -> Result<(f32, Vec<f32>)>;

    /// Serialize mini-batch stream state for suspend/resume. The analytic
    /// sources snapshot their RNG so a resumed run draws the exact batches
    /// an uninterrupted one would; sources without capturable stream state
    /// (PJRT) keep the default and fail loudly instead of silently
    /// resuming on a diverged batch stream.
    fn export_state(&self) -> Result<Vec<u8>> {
        anyhow::bail!("gradient source does not support suspend/resume")
    }

    /// Restore a blob produced by [`GradSource::export_state`].
    fn import_state(&mut self, _bytes: &[u8]) -> Result<()> {
        anyhow::bail!("gradient source does not support suspend/resume")
    }
}

/// Test-set statistics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalStats {
    pub loss: f32,
    /// Fraction correct in [0,1]; NaN for objectives without accuracy.
    pub accuracy: f32,
}

/// Periodic held-out evaluation of the global model.
pub trait Evaluator {
    fn eval(&mut self, theta: &[f32]) -> Result<EvalStats>;
}
