//! The real gradient path: AOT-compiled JAX model via PJRT.
//!
//! Each worker holds a shared reference to the compiled [`ModelBundle`]
//! (executables are stateless), its own data RNG stream, and — in non-iid
//! mode — its own label-distribution weights. One `grad()` call is one
//! PJRT execution of the model's fused fwd+bwd HLO.

use std::rc::Rc;

use anyhow::Result;

use crate::data::{self, lm::ByteCorpus, Dataset};
use crate::runtime::executable::Batch;
use crate::runtime::ModelBundle;
use crate::util::rng::Rng;

use super::{EvalStats, Evaluator, GradSource};

/// The worker's local data stream.
pub enum ShardStream {
    /// Labeled classification dataset, optional label weights (non-iid).
    Classif { ds: Rc<dyn Dataset>, weights: Option<Vec<f32>> },
    /// Byte-LM corpus windows.
    Lm { corpus: Rc<ByteCorpus> },
}

impl ShardStream {
    fn next_batch(&self, rng: &mut Rng, batch: usize) -> Batch {
        match self {
            ShardStream::Classif { ds, weights } => {
                data::make_batch(ds.as_ref(), rng, batch, weights.as_deref())
            }
            ShardStream::Lm { corpus } => corpus.make_lm_batch(rng, batch),
        }
    }
}

pub struct PjrtSource {
    bundle: Rc<ModelBundle>,
    stream: ShardStream,
    rng: Rng,
    worker: usize,
}

impl PjrtSource {
    pub fn new(bundle: Rc<ModelBundle>, stream: ShardStream, seed: u64, worker: usize) -> Self {
        PjrtSource {
            bundle,
            stream,
            rng: Rng::seed(seed ^ (worker as u64 + 1).wrapping_mul(0x9E37_79B9)),
            worker,
        }
    }
}

impl GradSource for PjrtSource {
    fn dim(&self) -> usize {
        self.bundle.entry.p
    }

    fn grad(&mut self, theta: &[f32], round: u64) -> Result<(f32, Vec<f32>)> {
        let batch = self.stream.next_batch(&mut self.rng, self.bundle.entry.batch);
        // Dropout seed: unique per (round, worker), reproducible.
        let seed = (round as i32)
            .wrapping_mul(1_000_003)
            .wrapping_add(self.worker as i32);
        self.bundle.grad.run(theta, &batch, seed)
    }
}

/// Held-out evaluation: a fixed set of pre-drawn test batches.
pub struct PjrtEvaluator {
    bundle: Rc<ModelBundle>,
    test_batches: Vec<Batch>,
}

impl PjrtEvaluator {
    /// Draw `n_batches` test batches from the dataset with a dedicated
    /// seed stream (disjoint from all training streams).
    pub fn new(bundle: Rc<ModelBundle>, stream: &ShardStream, seed: u64, n_batches: usize) -> Self {
        let mut rng = Rng::seed(seed ^ 0x7E57_7E57);
        let test_batches = (0..n_batches)
            .map(|_| stream.next_batch(&mut rng, bundle.entry.batch))
            .collect();
        PjrtEvaluator { bundle, test_batches }
    }
}

impl Evaluator for PjrtEvaluator {
    fn eval(&mut self, theta: &[f32]) -> Result<EvalStats> {
        let mut loss = 0.0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for b in &self.test_batches {
            let (l, c) = self.bundle.eval.run(theta, b)?;
            loss += l as f64;
            correct += c as u64;
            total += self.bundle.entry.labels_per_batch() as u64;
        }
        Ok(EvalStats {
            loss: (loss / self.test_batches.len() as f64) as f32,
            accuracy: correct as f32 / total as f32,
        })
    }
}
