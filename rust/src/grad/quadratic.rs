//! Analytic quadratic objective with controllable σ² and σ_g².
//!
//! Worker i minimizes f_i(θ) = 0.5 θᵀ A θ − b_iᵀ θ with a shared PSD
//! diagonal A and worker-specific b_i = b̄ + δ_i. Then:
//!   ∇f_i(θ) = Aθ − b_i,         global optimum θ* = A⁻¹ b̄,
//!   σ_g² = mean ‖δ_i‖²          (Assumption 4(ii), exactly),
//! and the stochastic oracle adds N(0, σ²/d I) noise (Assumption 4(i)).
//!
//! Because every quantity is closed-form, the integration tests can
//! assert convergence *to θ\** and the speedup experiment can measure
//! iterations-to-ε cheaply over thousands of rounds.

use anyhow::Result;

use crate::util::math;
use crate::util::rng::Rng;

use super::{EvalStats, Evaluator, GradSource};

/// Shared problem definition (one per experiment; workers hold clones).
#[derive(Clone)]
pub struct QuadraticProblem {
    /// Diagonal of A (condition number controls difficulty).
    pub a: Vec<f32>,
    /// Mean linear term b̄.
    pub b_mean: Vec<f32>,
    /// Per-worker offsets δ_i (empty ⇒ iid, σ_g = 0).
    pub deltas: Vec<Vec<f32>>,
    /// Stochastic gradient noise std (total, split across coords).
    pub sigma: f32,
}

impl QuadraticProblem {
    pub fn new(seed: u64, dim: usize, n_workers: usize, cond: f32, sigma: f32, sigma_g: f32) -> Self {
        let mut rng = Rng::seed(seed ^ 0x9A4D);
        // Log-uniform spectrum in [1, cond].
        let a: Vec<f32> = (0..dim)
            .map(|i| cond.powf(i as f32 / (dim.max(2) - 1) as f32))
            .collect();
        let b_mean: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let deltas: Vec<Vec<f32>> = (0..n_workers)
            .map(|_| {
                let mut d = rng.normal_vec(dim);
                let norm = math::norm2(&d) as f32;
                let target = sigma_g;
                for x in &mut d {
                    *x *= target / norm.max(1e-9);
                }
                d
            })
            .collect();
        // Center deltas so that mean_i b_i == b_mean exactly.
        let mut mean_delta = vec![0.0f32; dim];
        for d in &deltas {
            math::axpy(1.0 / n_workers as f32, d, &mut mean_delta);
        }
        let deltas = deltas
            .into_iter()
            .map(|mut d| {
                for (x, &m) in d.iter_mut().zip(&mean_delta) {
                    *x -= m;
                }
                d
            })
            .collect();
        QuadraticProblem { a, b_mean, deltas, sigma }
    }

    pub fn dim(&self) -> usize {
        self.a.len()
    }

    /// Global optimum θ* = A⁻¹ b̄.
    pub fn optimum(&self) -> Vec<f32> {
        self.a.iter().zip(&self.b_mean).map(|(&a, &b)| b / a).collect()
    }

    /// Global objective f(θ) (average over workers; the δ_i average out
    /// in the linear term because they are centered).
    pub fn global_loss(&self, theta: &[f32]) -> f32 {
        let mut f = 0.0f64;
        for i in 0..self.dim() {
            f += 0.5 * self.a[i] as f64 * (theta[i] as f64).powi(2)
                - self.b_mean[i] as f64 * theta[i] as f64;
        }
        f as f32
    }

    /// Exact σ_g² of this instance (Assumption 4(ii)).
    pub fn sigma_g_sq(&self) -> f32 {
        if self.deltas.is_empty() {
            return 0.0;
        }
        let total: f64 = self.deltas.iter().map(|d| math::norm2_sq(d)).sum();
        (total / self.deltas.len() as f64) as f32
    }

    pub fn source_for(&self, worker: usize, seed: u64) -> QuadraticSource {
        QuadraticSource {
            problem: self.clone(),
            worker,
            rng: Rng::seed(seed ^ (worker as u64).wrapping_mul(0xABCD_1234_5678)),
        }
    }
}

pub struct QuadraticSource {
    problem: QuadraticProblem,
    worker: usize,
    rng: Rng,
}

impl GradSource for QuadraticSource {
    fn dim(&self) -> usize {
        self.problem.dim()
    }

    fn grad(&mut self, theta: &[f32], _round: u64) -> Result<(f32, Vec<f32>)> {
        let p = &self.problem;
        let d = p.dim();
        let noise_std = p.sigma / (d as f32).sqrt();
        let delta = p.deltas.get(self.worker);
        let mut g = Vec::with_capacity(d);
        let mut loss = 0.0f64;
        for i in 0..d {
            let b_i = p.b_mean[i] + delta.map(|dl| dl[i]).unwrap_or(0.0);
            let gi = p.a[i] * theta[i] - b_i + noise_std * self.rng.normal();
            g.push(gi);
            loss += 0.5 * p.a[i] as f64 * (theta[i] as f64).powi(2)
                - b_i as f64 * theta[i] as f64;
        }
        Ok((loss as f32, g))
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        Ok(crate::compress::export_rng(&self.rng))
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.rng = crate::compress::import_rng(bytes)?;
        Ok(())
    }
}

/// Evaluator: exact global loss (no accuracy notion).
pub struct QuadraticEvaluator {
    pub problem: QuadraticProblem,
}

impl Evaluator for QuadraticEvaluator {
    fn eval(&mut self, theta: &[f32]) -> Result<EvalStats> {
        Ok(EvalStats { loss: self.problem.global_loss(theta), accuracy: f32::NAN })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_zeroes_mean_gradient() {
        let p = QuadraticProblem::new(1, 50, 4, 10.0, 0.0, 2.0);
        let opt = p.optimum();
        // Average worker gradient at θ* must vanish (deltas are centered).
        let mut avg = vec![0.0f32; 50];
        for w in 0..4 {
            let mut s = p.source_for(w, 9);
            let (_, g) = s.grad(&opt, 0).unwrap();
            math::axpy(0.25, &g, &mut avg);
        }
        assert!(math::norm2(&avg) < 1e-3, "{}", math::norm2(&avg));
    }

    #[test]
    fn sigma_g_matches_request() {
        let p = QuadraticProblem::new(2, 64, 8, 5.0, 0.0, 3.0);
        // Centering shifts norms slightly; should be in the ballpark.
        let sg = p.sigma_g_sq().sqrt();
        assert!((sg - 3.0).abs() < 1.0, "sigma_g={sg}");
        let p0 = QuadraticProblem::new(2, 64, 8, 5.0, 0.0, 0.0);
        assert!(p0.sigma_g_sq() < 1e-9);
    }

    #[test]
    fn gradient_descent_converges_to_optimum() {
        let p = QuadraticProblem::new(3, 20, 1, 4.0, 0.0, 0.0);
        let mut s = p.source_for(0, 1);
        let mut theta = vec![0.0f32; 20];
        for _ in 0..400 {
            let (_, g) = s.grad(&theta, 0).unwrap();
            math::axpy(-0.2, &g, &mut theta);
        }
        let opt = p.optimum();
        assert!(math::dist_sq(&theta, &opt) < 1e-6);
    }

    #[test]
    fn noisy_gradient_is_unbiased() {
        let p = QuadraticProblem::new(4, 10, 1, 2.0, 1.0, 0.0);
        let mut s = p.source_for(0, 2);
        let theta = vec![0.5f32; 10];
        let mut mean = vec![0.0f32; 10];
        let n = 2000;
        for _ in 0..n {
            let (_, g) = s.grad(&theta, 0).unwrap();
            math::axpy(1.0 / n as f32, &g, &mut mean);
        }
        let mut s2 = p.source_for(0, 3);
        let (_, exact) = {
            let mut p2 = p.clone();
            p2.sigma = 0.0;
            let mut sx = QuadraticSource { problem: p2, worker: 0, rng: Rng::seed(1) };
            sx.grad(&theta, 0).unwrap()
        };
        let _ = &mut s2;
        assert!(math::dist_sq(&mean, &exact) < 0.01);
    }
}
