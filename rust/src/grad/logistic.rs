//! Pure-Rust multinomial logistic regression on synthetic Gaussian data.
//!
//! A planted weight matrix W* defines labels y = argmax(W* x + margin
//! noise); workers draw fresh (x, y) mini-batches from their own stream
//! and compute the exact softmax-CE gradient. This is the fast substrate
//! for the linear-speedup sweep (Fig. 3 fast mode): a full 16-worker,
//! several-thousand-round run takes milliseconds, with real
//! classification accuracy as the metric.

use anyhow::Result;

use crate::util::math;
use crate::util::rng::Rng;

use super::{EvalStats, Evaluator, GradSource};

#[derive(Clone)]
pub struct LogisticProblem {
    pub dim: usize,
    pub classes: usize,
    pub batch: usize,
    /// Planted weights, classes x dim.
    w_star: Vec<f32>,
    /// Label margin noise (larger = noisier labels = higher σ²).
    pub label_noise: f32,
}

impl LogisticProblem {
    pub fn new(seed: u64, dim: usize, classes: usize, batch: usize, label_noise: f32) -> Self {
        let mut rng = Rng::seed(seed ^ 0x106157);
        let w_star = rng.normal_vec(classes * dim);
        LogisticProblem { dim, classes, batch, w_star, label_noise }
    }

    /// Parameter dimension: weights + bias.
    pub fn p(&self) -> usize {
        self.classes * (self.dim + 1)
    }

    fn draw_example(&self, rng: &mut Rng, x: &mut [f32]) -> usize {
        for xi in x.iter_mut() {
            *xi = rng.normal();
        }
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for c in 0..self.classes {
            let row = &self.w_star[c * self.dim..(c + 1) * self.dim];
            let mut v: f32 = row.iter().zip(x.iter()).map(|(&w, &xi)| w * xi).sum();
            v += self.label_noise * rng.normal();
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }

    /// Loss + gradient of softmax CE on a fresh batch at `theta`
    /// (layout: [classes*dim weights, classes biases]).
    pub fn loss_grad(&self, theta: &[f32], rng: &mut Rng, batch: usize) -> (f32, Vec<f32>) {
        assert_eq!(theta.len(), self.p());
        let (w, bias) = theta.split_at(self.classes * self.dim);
        let mut grad = vec![0.0f32; self.p()];
        let mut x = vec![0.0f32; self.dim];
        let mut logits = vec![0.0f32; self.classes];
        let mut loss = 0.0f64;
        for _ in 0..batch {
            let y = self.draw_example(rng, &mut x);
            for c in 0..self.classes {
                let row = &w[c * self.dim..(c + 1) * self.dim];
                logits[c] =
                    row.iter().zip(&x).map(|(&wi, &xi)| wi * xi).sum::<f32>() + bias[c];
            }
            math::log_softmax_row(&mut logits);
            loss -= logits[y] as f64;
            // dL/dlogit_c = softmax_c - 1[c==y]
            for c in 0..self.classes {
                let p = logits[c].exp() - if c == y { 1.0 } else { 0.0 };
                let grow = &mut grad[c * self.dim..(c + 1) * self.dim];
                math::axpy(p, &x, grow);
                grad[self.classes * self.dim + c] += p;
            }
        }
        let inv = 1.0 / batch as f32;
        for g in &mut grad {
            *g *= inv;
        }
        ((loss / batch as f64) as f32, grad)
    }

    /// Accuracy/loss on a held-out set.
    pub fn evaluate(&self, theta: &[f32], seed: u64, n: usize) -> EvalStats {
        let mut rng = Rng::seed(seed ^ 0xE7A1);
        let (w, bias) = theta.split_at(self.classes * self.dim);
        let mut x = vec![0.0f32; self.dim];
        let mut logits = vec![0.0f32; self.classes];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for _ in 0..n {
            let y = self.draw_example(&mut rng, &mut x);
            for c in 0..self.classes {
                let row = &w[c * self.dim..(c + 1) * self.dim];
                logits[c] =
                    row.iter().zip(&x).map(|(&wi, &xi)| wi * xi).sum::<f32>() + bias[c];
            }
            math::log_softmax_row(&mut logits);
            loss -= logits[y] as f64;
            if math::argmax(&logits) == y {
                correct += 1;
            }
        }
        EvalStats {
            loss: (loss / n as f64) as f32,
            accuracy: correct as f32 / n as f32,
        }
    }

    pub fn source_for(&self, worker: usize, seed: u64) -> LogisticSource {
        LogisticSource {
            problem: self.clone(),
            rng: Rng::seed(seed ^ (worker as u64).wrapping_mul(0x51ED_5EED)),
        }
    }
}

pub struct LogisticSource {
    problem: LogisticProblem,
    rng: Rng,
}

impl GradSource for LogisticSource {
    fn dim(&self) -> usize {
        self.problem.p()
    }

    fn grad(&mut self, theta: &[f32], _round: u64) -> Result<(f32, Vec<f32>)> {
        let b = self.problem.batch;
        Ok(self.problem.loss_grad(theta, &mut self.rng, b))
    }

    fn export_state(&self) -> Result<Vec<u8>> {
        Ok(crate::compress::export_rng(&self.rng))
    }

    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.rng = crate::compress::import_rng(bytes)?;
        Ok(())
    }
}

pub struct LogisticEvaluator {
    pub problem: LogisticProblem,
    pub seed: u64,
    pub n: usize,
}

impl Evaluator for LogisticEvaluator {
    fn eval(&mut self, theta: &[f32]) -> Result<EvalStats> {
        Ok(self.problem.evaluate(theta, self.seed, self.n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_learns_planted_weights() {
        let p = LogisticProblem::new(1, 16, 4, 32, 0.0);
        let mut src = p.source_for(0, 7);
        let mut theta = vec![0.0f32; p.p()];
        for _ in 0..300 {
            let (_, g) = src.grad(&theta, 0).unwrap();
            math::axpy(-0.5, &g, &mut theta);
        }
        let stats = p.evaluate(&theta, 99, 2000);
        assert!(stats.accuracy > 0.9, "acc={}", stats.accuracy);
    }

    #[test]
    fn random_init_is_chance_level() {
        let p = LogisticProblem::new(2, 8, 4, 16, 0.0);
        let theta = vec![0.0f32; p.p()];
        let stats = p.evaluate(&theta, 1, 4000);
        // Zero logits: loss is exactly ln(4). Accuracy = P(label == 0),
        // which for a *fixed* planted W* is only approximately 1/4.
        assert!((0.08..0.45).contains(&stats.accuracy), "acc={}", stats.accuracy);
        assert!((stats.loss - (4.0f32).ln()).abs() < 0.02);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = LogisticProblem::new(3, 5, 3, 64, 0.0);
        let theta: Vec<f32> = (0..p.p()).map(|i| (i as f32 * 0.37).sin() * 0.3).collect();
        // Same rng stream for both evaluations => same batch.
        let (_, g) = p.loss_grad(&theta, &mut Rng::seed(42), 64);
        let eps = 1e-3f32;
        for &i in &[0usize, 7, p.p() - 1] {
            let mut tp = theta.clone();
            tp[i] += eps;
            let (lp, _) = p.loss_grad(&tp, &mut Rng::seed(42), 64);
            let mut tm = theta.clone();
            tm[i] -= eps;
            let (lm, _) = p.loss_grad(&tm, &mut Rng::seed(42), 64);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 2e-2, "coord {i}: fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn label_noise_lowers_achievable_accuracy() {
        let clean = LogisticProblem::new(5, 16, 4, 32, 0.0);
        let noisy = LogisticProblem::new(5, 16, 4, 32, 3.0);
        let train = |p: &LogisticProblem| {
            let mut src = p.source_for(0, 1);
            let mut theta = vec![0.0f32; p.p()];
            for _ in 0..200 {
                let (_, g) = src.grad(&theta, 0).unwrap();
                math::axpy(-0.5, &g, &mut theta);
            }
            p.evaluate(&theta, 2, 2000).accuracy
        };
        assert!(train(&clean) > train(&noisy) + 0.1);
    }
}
