//! Adam (Kingma & Ba 2015) with bias correction — the server optimizer
//! inside the QAdam / 1BitAdam baselines (their underlying method is Adam,
//! not AMSGrad; see paper §5.4 discussion).

use super::ServerOpt;

pub struct Adam {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, beta1: f32, beta2: f32, eps: f32) -> Self {
        Adam { m: vec![0.0; dim], v: vec![0.0; dim], beta1, beta2, eps, t: 0 }
    }

    pub fn default_hp(dim: usize) -> Self {
        Self::new(dim, super::BETA1, super::BETA2, super::EPS)
    }

    /// Freeze and return the current second-moment estimate (1BitAdam's
    /// end-of-warm-up step).
    pub fn freeze_v(&self) -> Vec<f32> {
        self.v.clone()
    }

    /// Bias-correction step counter (number of [`ServerOpt::step`] calls
    /// applied so far) — part of the resumable optimizer state.
    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Restore the bias-correction step counter (suspend/resume).
    pub fn set_step_count(&mut self, t: u64) {
        self.t = t;
    }
}

impl ServerOpt for Adam {
    fn name(&self) -> String {
        "adam".into()
    }

    fn dim(&self) -> usize {
        self.m.len()
    }

    fn step(&mut self, theta: &mut [f32], grad: &[f32], lr: f32) {
        let dim = self.m.len();
        assert_eq!(theta.len(), dim, "adam θ length mismatch");
        assert_eq!(grad.len(), dim, "adam gradient length mismatch");
        self.t += 1;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        // Exact-length zips (no bounds checks, autovectorizable); the
        // per-coordinate expression order matches the indexed form, so
        // trajectories stay bitwise identical.
        let iter =
            theta.iter_mut().zip(&grad[..dim]).zip(&mut self.m[..dim]).zip(&mut self.v[..dim]);
        for (((t, &g), m), v) in iter {
            let mn = b1 * *m + (1.0 - b1) * g;
            let vn = b2 * *v + (1.0 - b2) * g * g;
            *m = mn;
            *v = vn;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            *t -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ServerOpt;

    #[test]
    fn first_step_is_lr_sized_regardless_of_grad_scale() {
        // Bias correction makes the first Adam step ≈ lr * sign(g).
        for &scale in &[0.01f32, 1.0, 100.0] {
            let mut opt = Adam::default_hp(1);
            let mut theta = vec![0.0f32];
            opt.step(&mut theta, &[scale], 0.05);
            assert!((theta[0] + 0.05).abs() < 1e-3, "scale={scale} got {}", theta[0]);
        }
    }

    #[test]
    fn freeze_v_snapshots_state() {
        let mut opt = Adam::default_hp(4);
        let mut theta = vec![1.0f32; 4];
        for _ in 0..10 {
            opt.step(&mut theta, &[0.5, -0.5, 1.0, -1.0], 0.01);
        }
        let frozen = opt.freeze_v();
        assert_eq!(frozen, opt.v);
        assert!(frozen.iter().all(|&v| v > 0.0));
    }
}
