//! Heavy-ball momentum SGD — the effective server update of 1BitAdam
//! after its warm-up freezes v (paper §3.2: "1BitAdam is actually more
//! like a distributed momentum SGD with pre-conditioned coordinate-wise
//! learning rates"), and the Dist-SGD appendix baseline's optional
//! momentum.

use super::ServerOpt;

pub struct MomentumSgd {
    pub buf: Vec<f32>,
    mu: f32,
}

impl MomentumSgd {
    pub fn new(dim: usize, mu: f32) -> Self {
        MomentumSgd { buf: vec![0.0; dim], mu }
    }

    /// Momentum step with a per-coordinate preconditioner `precond[i]`
    /// multiplying the learning rate (1BitAdam's frozen 1/√(v+ε)).
    pub fn step_preconditioned(
        &mut self,
        theta: &mut [f32],
        grad: &[f32],
        lr: f32,
        precond: &[f32],
    ) {
        for i in 0..theta.len() {
            let b = self.mu * self.buf[i] + (1.0 - self.mu) * grad[i];
            self.buf[i] = b;
            theta[i] -= lr * b * precond[i];
        }
    }
}

impl ServerOpt for MomentumSgd {
    fn name(&self) -> String {
        format!("momentum({})", self.mu)
    }

    fn dim(&self) -> usize {
        self.buf.len()
    }

    fn step(&mut self, theta: &mut [f32], grad: &[f32], lr: f32) {
        for i in 0..theta.len() {
            let b = self.mu * self.buf[i] + (1.0 - self.mu) * grad[i];
            self.buf[i] = b;
            theta[i] -= lr * b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ServerOpt;

    #[test]
    fn zero_momentum_equals_sgd() {
        let mut m = MomentumSgd::new(2, 0.0);
        let mut a = vec![1.0f32, 2.0];
        m.step(&mut a, &[0.5, -0.5], 0.1);
        assert_eq!(a, vec![1.0 - 0.05, 2.0 + 0.05]);
    }

    #[test]
    fn preconditioner_scales_coordinates() {
        let mut m = MomentumSgd::new(2, 0.0);
        let mut a = vec![0.0f32, 0.0];
        m.step_preconditioned(&mut a, &[1.0, 1.0], 0.1, &[1.0, 10.0]);
        assert!((a[0] + 0.1).abs() < 1e-6);
        assert!((a[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut m = MomentumSgd::new(1, 0.9);
        let mut a = vec![0.0f32];
        let mut steps = Vec::new();
        for _ in 0..30 {
            let before = a[0];
            m.step(&mut a, &[1.0], 0.1);
            steps.push((before - a[0]).abs());
        }
        // step size grows toward lr as buffer saturates at g
        assert!(steps[29] > steps[0]);
        assert!((steps[29] - 0.1).abs() < 0.01);
    }
}
