//! AMSGrad (Reddi et al. 2018) — the paper's server optimizer.
//!
//! m_t   = β1 m_{t-1} + (1-β1) g_t
//! v_t   = β2 v_{t-1} + (1-β2) g_t²
//! v̂_t  = max(v̂_{t-1}, v_t)
//! θ_{t+1} = θ_t − η m_t / √(v̂_t + ε)
//!
//! Two backends: the pure-Rust loop below (default, and the reference for
//! the property tests), and the AOT-compiled L1 Pallas fused kernel via
//! PJRT ([`crate::runtime::OptimizerExe`]) — selected by the coordinator
//! config and compared in `bench_optim`.

use super::ServerOpt;

pub struct AmsGrad {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub vhat: Vec<f32>,
    beta1: f32,
    beta2: f32,
    eps: f32,
}

impl AmsGrad {
    pub fn new(dim: usize, beta1: f32, beta2: f32, eps: f32) -> Self {
        AmsGrad {
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            vhat: vec![0.0; dim],
            beta1,
            beta2,
            eps,
        }
    }

    pub fn default_hp(dim: usize) -> Self {
        Self::new(dim, super::BETA1, super::BETA2, super::EPS)
    }
}

impl ServerOpt for AmsGrad {
    fn name(&self) -> String {
        "amsgrad".into()
    }

    fn dim(&self) -> usize {
        self.m.len()
    }

    fn step(&mut self, theta: &mut [f32], grad: &[f32], lr: f32) {
        let dim = self.m.len();
        assert_eq!(theta.len(), dim, "amsgrad θ length mismatch");
        assert_eq!(grad.len(), dim, "amsgrad gradient length mismatch");
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        // Exact-length zips let LLVM elide every bounds check and
        // autovectorize the loop; the per-coordinate expression order is
        // unchanged, so trajectories stay bitwise identical to the
        // indexed form.
        let iter = theta
            .iter_mut()
            .zip(&grad[..dim])
            .zip(&mut self.m[..dim])
            .zip(&mut self.v[..dim])
            .zip(&mut self.vhat[..dim]);
        for ((((t, &g), m), v), vh) in iter {
            let mn = b1 * *m + (1.0 - b1) * g;
            let vn = b2 * *v + (1.0 - b2) * g * g;
            let vhn = vh.max(vn);
            *m = mn;
            *v = vn;
            *vh = vhn;
            *t -= lr * mn / (vhn + eps).sqrt();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{ServerOpt, BETA1, BETA2, EPS};

    #[test]
    fn single_step_matches_hand_math() {
        let mut opt = AmsGrad::new(1, 0.9, 0.99, 1e-8);
        let mut theta = vec![1.0f32];
        opt.step(&mut theta, &[2.0], 0.1);
        let m = 0.1 * 2.0;
        let v = 0.01 * 4.0;
        let want = 1.0 - 0.1 * m / (v as f32 + 1e-8).sqrt();
        assert!((theta[0] - want).abs() < 1e-6, "{} vs {want}", theta[0]);
    }

    #[test]
    fn vhat_is_monotone_nondecreasing() {
        let mut opt = AmsGrad::default_hp(8);
        let mut theta = vec![0.5f32; 8];
        let mut prev = opt.vhat.clone();
        for t in 0..50 {
            let g: Vec<f32> = (0..8).map(|i| ((t * i) as f32).sin()).collect();
            opt.step(&mut theta, &g, 0.01);
            for (a, b) in opt.vhat.iter().zip(&prev) {
                assert!(a >= b);
            }
            prev = opt.vhat.clone();
        }
    }

    #[test]
    fn update_magnitude_bounded_by_lr_over_sqrt_eps_region() {
        // |Δθ| = lr |m| / sqrt(vhat+eps); with constant gradient the ratio
        // |m|/sqrt(vhat) -> 1, so steps approach lr.
        let mut opt = AmsGrad::new(1, BETA1, BETA2, EPS);
        let mut theta = vec![0.0f32];
        let mut last = 0.0f32;
        for _ in 0..2000 {
            let before = theta[0];
            opt.step(&mut theta, &[1.0], 0.01);
            last = (theta[0] - before).abs();
        }
        assert!((last - 0.01).abs() < 0.002, "step={last}");
    }
}
