//! Plain SGD — the Dist-SGD baseline of the paper's appendix (Fig. 4).

use super::ServerOpt;

pub struct Sgd {
    dim: usize,
}

impl Sgd {
    pub fn new(dim: usize) -> Self {
        Sgd { dim }
    }
}

impl ServerOpt for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn step(&mut self, theta: &mut [f32], grad: &[f32], lr: f32) {
        crate::util::math::axpy(-lr, grad, theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ServerOpt;

    #[test]
    fn exact_update() {
        let mut opt = Sgd::new(3);
        let mut theta = vec![1.0f32, 2.0, 3.0];
        opt.step(&mut theta, &[1.0, -1.0, 0.0], 0.5);
        assert_eq!(theta, vec![0.5, 2.5, 3.0]);
    }
}
