//! Server-side optimizers.
//!
//! In COMP-AMS all adaptive state lives on the leader (the paper's memory
//! argument vs. QAdam/1BitAdam, §3.2): workers only ever hold their error
//! accumulator. Each optimizer here consumes the decoded average gradient
//! and updates `theta` in place.

pub mod adam;
pub mod amsgrad;
pub mod momentum;
pub mod sgd;

pub use adam::Adam;
pub use amsgrad::AmsGrad;
pub use momentum::MomentumSgd;
pub use sgd::Sgd;

/// A stateful server optimizer over a flat f32 parameter vector.
pub trait ServerOpt: Send {
    fn name(&self) -> String;

    /// Apply one update with the given (averaged) gradient and step size.
    fn step(&mut self, theta: &mut [f32], grad: &[f32], lr: f32);

    /// Dimension the optimizer state was allocated for.
    fn dim(&self) -> usize;
}

/// Paper-default hyper-parameters (β1, β2, ε) shared by AMSGrad/Adam.
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

#[cfg(test)]
mod tests {
    use super::*;

    /// All optimizers must descend a simple quadratic f(x) = 0.5||x||^2.
    #[test]
    fn all_optimizers_descend_quadratic() {
        let d = 32;
        let opts: Vec<Box<dyn ServerOpt>> = vec![
            Box::new(Sgd::new(d)),
            Box::new(MomentumSgd::new(d, 0.9)),
            Box::new(Adam::new(d, BETA1, BETA2, EPS)),
            Box::new(AmsGrad::new(d, BETA1, BETA2, EPS)),
        ];
        for mut opt in opts {
            let mut theta = vec![1.0f32; d];
            for _ in 0..300 {
                let grad: Vec<f32> = theta.clone(); // ∇(0.5||x||²) = x
                opt.step(&mut theta, &grad, 0.05);
            }
            let norm = crate::util::math::norm2(&theta);
            assert!(norm < 0.25, "{} stalled at {norm}", opt.name());
        }
    }
}
