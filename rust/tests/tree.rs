//! Tree-topology integration tests: fault-injected sub-leader death
//! under a root quorum, the two-way compression bit claims (root ingress
//! and root broadcast both shrink versus the flat star at equal rounds),
//! exact per-level ledger sums, and descent with a compressed downlink
//! on both analytic substrates. The degenerate-tree bitwise gate lives
//! in tests/properties.rs.

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;

fn tree_cfg(algo: &str, topology: &str) -> TrainConfig {
    let mut cfg = TrainConfig::preset("quadratic", algo);
    cfg.workers = 8;
    cfg.rounds = 800;
    cfg.lr = 0.02;
    cfg.eval_every = 0;
    cfg.topology = topology.into();
    cfg
}

#[test]
fn killed_subleader_degrades_to_surviving_groups_under_quorum() {
    // 8 workers at degree 2 = 4 sub-leader groups; the root waits for 3
    // of them. Killing sub-leader 1 at round 100 must not end the run:
    // the root's quorum floor shrinks to the survivors (exactly like a
    // dead worker in the flat star), the dead group's two worker-side EF
    // accumulators are charged to the ledger, and the remaining 6
    // workers still descend the quadratic.
    let mut cfg = tree_cfg("comp-ams-topk:0.05", "tree:2");
    cfg.quorum = 3;
    cfg.max_staleness = 2;
    cfg.tree_kill = "1:100".into();
    let run = train(&cfg).unwrap();

    assert_eq!(run.metrics.len(), 800, "run ended early after the kill");
    let first = run.metrics[0].train_loss;
    let last = run.final_train_loss(20);
    assert!(last < first - 0.3, "degraded run stalled: {first:.3} -> {last:.3}");

    // The kill charges the group's worker-side EF residuals (2 workers
    // at degree 2); the sub-leader's own EF state is 0 bits here (the
    // identity group compressor forwards without error feedback), so
    // nothing else is charged.
    assert_eq!(run.ef_resets, 2, "expected one EF reset per killed group worker");
    assert!(run.ef_residual_lost_bits > 0);
    assert_eq!(run.ef_residual_lost_bits % 2, 0);

    // K < n over the synchronous tree: one group uplink is left over
    // each pre-kill round and consumed next round as a 1-round straggler
    // — within max_staleness, so nothing is dropped.
    assert!(run.stale_uplinks > 0, "quorum 3-of-4 produced no stragglers");
    assert_eq!(run.dropped_uplinks, 0);
}

#[test]
fn group_recompression_shrinks_root_ingress_and_levels_sum_exactly() {
    // Two-way compression claim, uplink side: with dense (dist-ams)
    // workers and Top-k re-compression at the sub-leaders, the bits
    // entering the root (level 0) must be a small fraction of the flat
    // star's uplink total at equal rounds — the whole point of the
    // aggregate-and-forward layer. And the per-level split must be an
    // exact partition of the headline ledger, not an estimate.
    let mut flat_cfg = tree_cfg("dist-ams", "flat");
    flat_cfg.rounds = 60;
    let mut deep_cfg = tree_cfg("dist-ams", "tree:4:topk:0.05");
    deep_cfg.rounds = 60;
    let flat = train(&flat_cfg).unwrap();
    let tree = train(&deep_cfg).unwrap();

    // Flat runs report the single root level only.
    assert_eq!(flat.uplink_bits_by_level.len(), 1);
    assert_eq!(flat.uplink_bits_by_level[0], flat.uplink_bits());

    // Tree runs report [root hop, worker hop], summing exactly to the
    // headline totals (full participation: nothing left in flight).
    assert_eq!(tree.uplink_bits_by_level.len(), 2);
    assert_eq!(
        tree.uplink_bits_by_level.iter().sum::<u64>(),
        tree.uplink_bits(),
        "per-level uplink bits must partition the total"
    );
    assert_eq!(
        tree.downlink_bits_by_level.iter().sum::<u64>(),
        tree.metrics.last().unwrap().downlink_bits,
        "per-level downlink bits must partition the total"
    );
    assert_eq!(
        tree.framing_bits_by_level.iter().sum::<u64>(),
        tree.framing_bits,
        "per-level framing bits must partition the total"
    );

    // 2 sparse forwarded aggregates per round vs 8 dense worker uplinks:
    // root ingress shrinks by far more than the 8x asserted here.
    assert!(
        tree.uplink_bits_by_level[0] * 8 < flat.uplink_bits(),
        "root ingress {} bits not << flat uplink {} bits",
        tree.uplink_bits_by_level[0],
        flat.uplink_bits()
    );
    // The worker hop still exists and is billed — level 1 carries the
    // same dense uplinks the flat star did.
    assert!(tree.uplink_bits_by_level[1] > tree.uplink_bits_by_level[0]);
}

#[test]
fn compressed_downlink_descends_on_quadratic_and_shrinks_root_broadcast() {
    // Two-way compression claim, downlink side (Wang et al. two-way
    // setup): the root broadcasts C(θ − θ̂) instead of dense θ. The
    // θ̂-reconstruction workers see is approximate, but the remainder
    // is next round's delta, so the quadratic still descends — and the
    // root's broadcast (level 0) is far below the flat star's dense
    // rounds × workers × θ bill.
    let mut cfg = tree_cfg("comp-ams-topk:0.05", "tree:4");
    cfg.downlink_compress = "topk:0.25".into();
    let run = train(&cfg).unwrap();
    let first = run.metrics[0].train_loss;
    let last = run.final_train_loss(20);
    assert!(last < first - 0.3, "compressed downlink stalled: {first:.3} -> {last:.3}");

    let flat = train(&tree_cfg("comp-ams-topk:0.05", "flat")).unwrap();
    let flat_down = flat.metrics.last().unwrap().downlink_bits;
    assert!(
        run.downlink_bits_by_level[0] * 2 < flat_down,
        "root broadcast {} bits not below flat downlink {} bits",
        run.downlink_bits_by_level[0],
        flat_down
    );
}

#[test]
fn compressed_downlink_descends_on_logistic() {
    // Same contract on the non-convex-ish substrate: logistic regression
    // under a Top-k θ-delta broadcast must still reach a useful loss.
    let mut cfg = TrainConfig::preset("logistic", "comp-ams-topk:0.05");
    cfg.workers = 8;
    cfg.rounds = 3000;
    cfg.lr = 0.01;
    cfg.eval_every = 0;
    cfg.topology = "tree:4".into();
    cfg.downlink_compress = "topk:0.25".into();
    let run = train(&cfg).unwrap();
    let first = run.metrics[0].train_loss;
    let last = run.final_train_loss(25);
    assert!(
        last < first - 0.3,
        "logistic under compressed downlink stalled: {first:.3} -> {last:.3}"
    );
}
