//! Coordinator-level integration tests on the analytic substrates:
//! protocol convergence, determinism, communication accounting, and the
//! paper's qualitative claims at test scale.

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::{train, Trainer};

fn quad_cfg(algo: &str) -> TrainConfig {
    let mut cfg = TrainConfig::preset("quadratic", algo);
    cfg.workers = 4;
    cfg.rounds = 800;
    cfg.lr = 0.02;
    cfg.eval_every = 0;
    cfg
}

#[test]
fn every_protocol_descends_the_quadratic() {
    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "comp-ams-randomk:0.1",
        "qadam",
        "1bitadam:80",
        "dist-sgd",
    ] {
        let mut cfg = quad_cfg(algo);
        if algo.starts_with("1bitadam") {
            // 1BitAdam's frozen preconditioner needs a per-method lr (the
            // paper tunes each method over its own grid — Table 1); with
            // the shared lr it diverges here, which is exactly the
            // warm-up sensitivity §5.4 describes (see the ablation).
            cfg.lr = 0.002;
        }
        let run = train(&cfg).unwrap_or_else(|e| panic!("{algo}: {e:#}"));
        let first = run.metrics[0].train_loss;
        let last = run.final_train_loss(20);
        assert!(last < first - 0.3, "{algo}: {first:.3} -> {last:.3}");
    }
}

#[test]
fn identical_seeds_are_bit_deterministic() {
    let cfg = quad_cfg("comp-ams-topk:0.02");
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
        assert_eq!(ma.uplink_bits, mb.uplink_bits);
    }
}

#[test]
fn different_seeds_differ() {
    let mut cfg = quad_cfg("comp-ams-topk:0.02");
    let a = train(&cfg).unwrap();
    cfg.seed = 43;
    let b = train(&cfg).unwrap();
    assert_ne!(
        a.metrics.last().unwrap().train_loss.to_bits(),
        b.metrics.last().unwrap().train_loss.to_bits()
    );
}

#[test]
fn comp_ams_matches_dist_ams_loss_with_fraction_of_bits() {
    // The paper's headline (C1 + C2) at test scale: similar final loss,
    // order-of-magnitude less uplink.
    let dense = train(&quad_cfg("dist-ams")).unwrap();
    let sparse = train(&quad_cfg("comp-ams-topk:0.05")).unwrap();
    let dl = dense.final_train_loss(20);
    let sl = sparse.final_train_loss(20);
    // Within 2.5% of the dense loss *range* (loss drops 0 -> ~-35.6).
    assert!(
        sl < dl + 0.025 * dl.abs(),
        "comp-ams loss {sl:.3} far above dist-ams {dl:.3}"
    );
    assert!(sparse.uplink_bits() * 8 < dense.uplink_bits());
}

#[test]
fn error_feedback_fixes_biased_compression_under_heterogeneity() {
    // Where EF provably matters (paper §2.1): with non-iid workers the
    // per-worker Top-k selections are mutually biased — without EF the
    // aggregate stalls above the optimum; EF telescopes the residuals
    // through and closes the gap.
    let run_with = |algo: &str| {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 8;
        cfg.sharding = "dirichlet:0.2".into();
        cfg.rounds = 3000;
        cfg.lr = 0.02;
        cfg.eval_every = 0;
        train(&cfg).unwrap().final_train_loss(50)
    };
    let le = run_with("comp-ams-topk:0.05");
    let ln = run_with("comp-ams-topk:0.05:noef");
    assert!(le < ln - 0.15, "EF {le:.3} should beat no-EF {ln:.3}");
}

#[test]
fn linear_speedup_direction_on_logistic() {
    // More workers with lr ∝ √n must not be slower to a fixed loss
    // (Corollary 2 at smoke scale: n=8 ≤ half the rounds of n=1).
    let rounds_for = |n: usize| {
        let mut cfg = TrainConfig::preset("logistic", "comp-ams-topk:0.05");
        cfg.workers = n;
        cfg.rounds = 4000;
        cfg.lr = 0.005 * (n as f32).sqrt();
        cfg.eval_every = 0;
        let run = train(&cfg).unwrap();
        run.rounds_to_loss(0.25, 25)
    };
    let r1 = rounds_for(1).expect("n=1 never hit target");
    let r8 = rounds_for(8).expect("n=8 never hit target");
    assert!(
        r8 * 2 <= r1,
        "no speedup: n=1 took {r1} rounds, n=8 took {r8}"
    );
}

#[test]
fn non_iid_sharding_still_converges() {
    let mut cfg = quad_cfg("comp-ams-blocksign:64");
    cfg.sharding = "dirichlet:0.5".into();
    let run = train(&cfg).unwrap();
    assert!(run.final_train_loss(20) < run.metrics[0].train_loss - 0.3);
}

#[test]
fn partial_participation_descends_and_reports_staleness() {
    // The K < n acceptance bar: with a quorum of half the workers the
    // quadratic run still descends, straggler uplinks show up in the
    // stale counter, and nothing is dropped while max_staleness covers
    // the one-round lag the in-process transport produces.
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.workers = 8;
    cfg.quorum = 4;
    cfg.max_staleness = 2;
    let run = train(&cfg).unwrap();
    let first = run.metrics[0].train_loss;
    let last = run.final_train_loss(20);
    assert!(last < first - 0.3, "K<n run stalled: {first:.3} -> {last:.3}");
    assert!(run.stale_uplinks > 0, "no stale uplinks recorded");
    assert_eq!(run.dropped_uplinks, 0);

    // With max_staleness = 0 the same lag is dropped instead of applied,
    // and the drops are accounted.
    cfg.max_staleness = 0;
    cfg.rounds = 60;
    let run = train(&cfg).unwrap();
    assert!(run.dropped_uplinks > 0, "no dropped uplinks recorded");
    assert_eq!(run.stale_uplinks, 0);

    // Full participation keeps both counters at zero.
    cfg.quorum = 0;
    cfg.max_staleness = 2;
    let run = train(&cfg).unwrap();
    assert_eq!(run.stale_uplinks, 0);
    assert_eq!(run.dropped_uplinks, 0);
}

#[test]
fn downlink_accounting_is_rounds_times_workers_times_theta() {
    let mut cfg = quad_cfg("dist-ams");
    cfg.rounds = 7;
    cfg.workers = 3;
    let mut t = Trainer::new(&cfg).unwrap();
    for r in 0..7 {
        t.step(r).unwrap();
    }
    let expect = 7 * 3 * 8 * (5 + 4 * t.theta.len() as u64);
    assert_eq!(t.ledger().downlink_bits, expect);
}

#[test]
fn uplink_ledger_scales_with_compression_ratio() {
    let bits_for = |ratio: &str| {
        let mut cfg = quad_cfg(&format!("comp-ams-topk:{ratio}"));
        cfg.rounds = 5;
        train(&cfg).unwrap().uplink_bits()
    };
    let b01 = bits_for("0.01");
    let b10 = bits_for("0.10");
    let ratio = b10 as f64 / b01 as f64;
    assert!((6.0..14.0).contains(&ratio), "expected ~10x, got {ratio:.1}x");
}

#[test]
fn trainer_rejects_invalid_configs() {
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.workers = 0;
    assert!(Trainer::new(&cfg).is_err());
    let cfg = quad_cfg("not-an-algo");
    assert!(Trainer::new(&cfg).is_err());
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.sharding = "bogus".into();
    assert!(Trainer::new(&cfg).is_err());
}

#[test]
fn qadam_and_onebit_report_worker_memory_overhead() {
    use comp_ams::algo::{AlgoSpec, WorkerAlgo};
    let (q, _) = AlgoSpec::parse("qadam").unwrap().build(1000, 4, 100);
    let (o, _) = AlgoSpec::parse("1bitadam:10").unwrap().build(1000, 4, 100);
    let (c, _) = AlgoSpec::parse("comp-ams-topk:0.01").unwrap().build(1000, 4, 100);
    assert_eq!(q[0].state_bytes(), 8000); // m + v
    assert_eq!(o[0].state_bytes(), 4000); // m
    assert_eq!(c[0].state_bytes(), 0); // the paper's §3.2 point
}

#[test]
fn lossy_wan_speedup_sweep_holds_under_simulated_impairment() {
    // Corollary 2 under adversarial networking: the n ∈ {1, 2, 4, 8}
    // sweep with lr ∝ √n runs over the seeded lossy-WAN simulator and
    // rounds-to-target must still improve monotonically (small slack for
    // the discrete target crossing), ending at the ≥2× endpoint bar.
    let run_for = |n: usize| {
        let mut cfg = TrainConfig::preset("logistic", "comp-ams-topk:0.05");
        cfg.workers = n;
        cfg.rounds = 4000;
        cfg.lr = 0.005 * (n as f32).sqrt();
        cfg.eval_every = 0;
        cfg.transport = "sim:inproc".into();
        cfg.sim_profile = "lossy-wan".into();
        cfg.sim_seed = 23;
        train(&cfg).unwrap()
    };
    let mut rounds = Vec::new();
    let mut drops = 0u64;
    for n in [1usize, 2, 4, 8] {
        let run = run_for(n);
        drops += run.sim_links.iter().map(|l| l.drops).sum::<u64>();
        rounds.push(run.rounds_to_loss(0.25, 25).unwrap_or_else(|| {
            panic!("n={n} never hit the target loss under lossy-wan")
        }));
    }
    for w in rounds.windows(2) {
        assert!(
            w[1] as f64 <= w[0] as f64 * 1.15 + 5.0,
            "speedup not monotone under lossy-wan: {rounds:?}"
        );
    }
    assert!(
        rounds[3] * 2 <= rounds[0],
        "no 2x speedup at n=8 under lossy-wan: {rounds:?}"
    );
    assert!(drops > 0, "lossy-wan sweep recorded no seeded drops");
}

#[test]
fn trimmed_mean_survives_byzantine_worker_where_mean_stalls() {
    // The adversarial acceptance bar. On the iid quadratic every honest
    // worker's expected gradient is the same g, so one worker scaled by
    // -3 makes the plain batch mean pure zero-mean noise — averaging
    // provably cannot descend. Trimmed-mean (k=1) discards the outlier
    // coordinate-wise and recovers honest descent on the same run.
    let mut cfg = quad_cfg("dist-ams");
    cfg.byzantine = "0:scale:-3".into();

    let mean = train(&cfg).unwrap();
    let first = mean.metrics[0].train_loss;
    let mean_last = mean.final_train_loss(20);
    assert!(
        mean_last >= first - 0.2,
        "plain averaging should stall under scale:-3: {first:.3} -> {mean_last:.3}"
    );

    cfg.robust_agg = "trimmed:1".into();
    let robust = train(&cfg).unwrap();
    let robust_last = robust.final_train_loss(20);
    assert!(
        robust_last < first - 0.4,
        "trimmed:1 should descend under scale:-3: {first:.3} -> {robust_last:.3}"
    );
    assert!(
        robust_last < mean_last - 0.2,
        "trimmed:1 ({robust_last:.3}) should beat mean ({mean_last:.3})"
    );
}

#[test]
fn robust_estimators_descend_with_sign_flipped_worker() {
    // Both robust estimators discard the extremes coordinate-wise; with
    // one sign-flipped worker and three honest ones each reduces to a
    // mean over the middle honest values wherever |g| dominates the
    // noise — both runs must keep descending.
    for robust in ["median", "trimmed:1"] {
        let mut cfg = quad_cfg("dist-ams");
        cfg.byzantine = "0:signflip".into();
        cfg.robust_agg = robust.into();
        let run = train(&cfg).unwrap();
        let first = run.metrics[0].train_loss;
        let last = run.final_train_loss(20);
        assert!(
            last < first - 0.4,
            "{robust} stalled under signflip: {first:.3} -> {last:.3}"
        );
    }
}

#[test]
fn per_worker_uplink_breakdown_reflects_compression() {
    // Figure-2-style reporting: the per-worker uplink breakdown must sum
    // to the total and be uniform for a deterministic same-ratio sparsifier.
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.rounds = 20;
    let run = train(&cfg).unwrap();
    assert_eq!(run.uplink_bits_by_worker.len(), cfg.workers);
    assert_eq!(run.uplink_bits_by_worker.iter().sum::<u64>(), run.uplink_bits());
    let first = run.uplink_bits_by_worker[0];
    assert!(first > 0);
    assert!(run.uplink_bits_by_worker.iter().all(|&b| b == first));
}
