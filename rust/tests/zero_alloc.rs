//! Counting-allocator proof of the zero-copy wire contract: once a
//! warm-up round has grown every pooled buffer, a steady-state dense
//! round — worker-side envelope encode into pooled scratch, leader-side
//! borrowed-view decode, server AMSGrad step, and the θ downlink encoded
//! once with per-worker wid re-patching — performs **zero** heap
//! allocations.
//!
//! The counter is armed only on the test thread and only inside the
//! measured window, so allocator traffic from the libtest harness or
//! concurrently running test threads cannot leak into the assertion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

use comp_ams::algo::{AlgoSpec, RoundCtx, ServerAlgo};
use comp_ams::compress::{PayloadView, Scalars};
use comp_ams::coordinator::transport::{encode_envelope_into, EnvelopeView};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
}

fn bump() {
    // try_with: an allocation during TLS teardown must not abort.
    let _ = ARMED.try_with(|a| {
        if a.get() {
            ALLOCS.fetch_add(1, Relaxed);
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const N: usize = 4;
const DIM: usize = 4096;

/// One full dense round over the zero-copy path: deterministic in-place
/// gradient refresh, per-worker envelope encode into pooled scratch,
/// borrowed-view decode into a stack-held batch, server step, and the
/// fan-out downlink (encode θ once, re-patch only the wid per worker).
fn round(
    r: u64,
    grads: &mut [Vec<f32>; N],
    uplink_scratch: &mut [Vec<u8>; N],
    downlink_scratch: &mut Vec<u8>,
    theta: &mut Vec<f32>,
    server: &mut dyn ServerAlgo,
) {
    let lr = 0.01f32;
    let ctx = RoundCtx::sync(r, lr);
    for (w, g) in grads.iter_mut().enumerate() {
        for (i, gi) in g.iter_mut().enumerate() {
            *gi = ((r as usize * 31 + w * 7 + i) as f32 * 0.001).sin();
        }
    }
    for (w, buf) in uplink_scratch.iter_mut().enumerate() {
        buf.clear();
        encode_envelope_into(
            w as u32,
            r,
            0.5,
            &PayloadView::Dense(Scalars::Slice(&grads[w])),
            buf,
        );
    }
    let views: [PayloadView<'_>; N] =
        std::array::from_fn(|w| EnvelopeView::parse(&uplink_scratch[w]).unwrap().payload);
    server.step(theta, &views, &ctx).unwrap();
    downlink_scratch.clear();
    encode_envelope_into(
        0,
        r,
        lr,
        &PayloadView::Dense(Scalars::Slice(theta)),
        downlink_scratch,
    );
    for w in 0..N as u32 {
        downlink_scratch[0..4].copy_from_slice(&w.to_le_bytes());
        let env = EnvelopeView::parse(downlink_scratch).unwrap();
        assert_eq!(env.wid, w);
        assert_eq!(env.payload.dim(), DIM);
    }
}

#[test]
fn dense_steady_state_round_makes_zero_heap_allocations() {
    let spec = AlgoSpec::parse("dist-ams").unwrap();
    let (_, mut server) = spec.build(DIM, N, 1_000_000);
    let mut theta = vec![0.2f32; DIM];
    let mut grads: [Vec<f32>; N] = std::array::from_fn(|_| vec![0.0f32; DIM]);
    let mut uplink: [Vec<u8>; N] = std::array::from_fn(|_| Vec::new());
    let mut downlink: Vec<u8> = Vec::new();

    // Warm-up: grow every pooled buffer (the per-link scratch vectors and
    // the server's recycled averaging buffer; the moments are pre-sized).
    for r in 0..3 {
        round(r, &mut grads, &mut uplink, &mut downlink, &mut theta, server.as_mut());
    }

    let before = ALLOCS.load(Relaxed);
    ARMED.with(|a| a.set(true));
    for r in 3..13 {
        round(r, &mut grads, &mut uplink, &mut downlink, &mut theta, server.as_mut());
    }
    ARMED.with(|a| a.set(false));
    let delta = ALLOCS.load(Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state dense rounds must not touch the heap \
         ({delta} allocations across 10 rounds)"
    );
}
