//! Seeded network-simulator properties through the full Trainer: the
//! ideal profile is bitwise transparent over both in-process transports,
//! impairments under full quorum change link statistics but never the
//! math, and a fixed `--sim-seed` reproduces the whole impaired run —
//! losses, staleness counters, and per-link stats — bit for bit.

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;
use comp_ams::coordinator::LinkStats;

/// The acceptance-bar protocol list (ROADMAP tier 1).
const PROTOCOLS: [&str; 6] = [
    "dist-ams",
    "comp-ams-topk:0.05",
    "comp-ams-blocksign:64",
    "qadam",
    "1bitadam:10",
    "dist-sgd",
];

fn sim_cfg(algo: &str, transport: &str, profile: &str) -> TrainConfig {
    let mut cfg = TrainConfig::preset("quadratic", algo);
    cfg.workers = 3;
    cfg.rounds = 30;
    cfg.lr = 0.01;
    cfg.eval_every = 0;
    cfg.transport = transport.into();
    cfg.sim_profile = profile.into();
    cfg
}

fn total_delay(links: &[LinkStats]) -> u64 {
    links.iter().map(|l| l.delay_us).sum()
}

fn total_drops(links: &[LinkStats]) -> u64 {
    links.iter().map(|l| l.drops).sum()
}

#[test]
fn ideal_sim_is_bitwise_transparent_across_protocols() {
    // Zero impairment ⇒ the wrapper must be invisible: per-round losses
    // and uplink bits identical to the bare transport, for every protocol
    // string and for both wrappable transports.
    for algo in PROTOCOLS {
        for (bare, wrapped) in [("inproc", "sim:inproc"), ("loopback", "sim:loopback")] {
            let base = train(&sim_cfg(algo, bare, "ideal")).unwrap();
            let sim = train(&sim_cfg(algo, wrapped, "ideal")).unwrap();
            assert!(base.sim_links.is_empty(), "{algo}/{bare}: bare run has link stats");
            assert_eq!(base.metrics.len(), sim.metrics.len(), "{algo}/{wrapped}");
            for (ma, mb) in base.metrics.iter().zip(&sim.metrics) {
                assert_eq!(
                    ma.train_loss.to_bits(),
                    mb.train_loss.to_bits(),
                    "{algo}/{wrapped}: loss diverged at round {}",
                    ma.round
                );
                assert_eq!(
                    ma.uplink_bits, mb.uplink_bits,
                    "{algo}/{wrapped}: uplink bits diverged at round {}",
                    ma.round
                );
            }
            // The ideal profile delivers every uplink with zero delay and
            // zero drops — and the stats prove it.
            assert_eq!(sim.sim_links.len(), 3, "{algo}/{wrapped}");
            for (wid, l) in sim.sim_links.iter().enumerate() {
                assert_eq!(
                    *l,
                    LinkStats { delivered: 30, ..LinkStats::default() },
                    "{algo}/{wrapped}: link {wid}"
                );
            }
        }
    }
}

#[test]
fn impairments_under_full_quorum_change_stats_not_math() {
    // With K = n the runtime waits for the whole batch and sorts it by
    // wid before aggregating, so WAN-shaped delays, jitter, and seeded
    // retransmits may only show up in the link statistics — the loss
    // trajectory stays bitwise identical to the bare transport.
    for algo in ["dist-ams", "comp-ams-topk:0.05"] {
        let mut base_cfg = sim_cfg(algo, "inproc", "ideal");
        base_cfg.workers = 4;
        base_cfg.rounds = 60;
        let mut wan_cfg = sim_cfg(algo, "sim:inproc", "lossy-wan");
        wan_cfg.workers = 4;
        wan_cfg.rounds = 60;
        wan_cfg.sim_seed = 17;
        let base = train(&base_cfg).unwrap();
        let wan = train(&wan_cfg).unwrap();
        for (ma, mb) in base.metrics.iter().zip(&wan.metrics) {
            assert_eq!(
                ma.train_loss.to_bits(),
                mb.train_loss.to_bits(),
                "{algo}: lossy-wan sim perturbed the math at round {}",
                ma.round
            );
        }
        assert_eq!(wan.stale_uplinks, 0, "{algo}: staleness under full quorum");
        assert_eq!(wan.dropped_uplinks, 0, "{algo}");
        // 240 seeded uplinks at 5% drop probability and 60 ms base
        // latency: the stats must show real impairment.
        assert!(total_delay(&wan.sim_links) > 0, "{algo}: no link delay recorded");
        assert!(total_drops(&wan.sim_links) > 0, "{algo}: no seeded drops recorded");
        let delivered: u64 = wan.sim_links.iter().map(|l| l.delivered).sum();
        assert_eq!(delivered, 4 * 60, "{algo}: exactly-once delivery");
    }
}

fn lossy_quorum_cfg(sim_seed: u64) -> TrainConfig {
    let mut cfg = sim_cfg("comp-ams-topk:0.05", "sim:inproc", "lossy-wan");
    cfg.workers = 4;
    cfg.quorum = 3;
    cfg.max_staleness = 2;
    cfg.rounds = 80;
    cfg.sim_seed = sim_seed;
    cfg
}

#[test]
fn fixed_sim_seed_is_bit_for_bit_reproducible() {
    // Under K < n the seeded schedule decides which link straggles each
    // round, so staleness — and through error feedback, the trajectory
    // itself — is a pure function of --sim-seed. Two runs with the same
    // seed must agree on everything; a different seed must draw a
    // different schedule.
    let a = train(&lossy_quorum_cfg(7)).unwrap();
    let b = train(&lossy_quorum_cfg(7)).unwrap();
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
        assert_eq!(ma.uplink_bits, mb.uplink_bits);
    }
    assert_eq!(a.stale_uplinks, b.stale_uplinks);
    assert_eq!(a.dropped_uplinks, b.dropped_uplinks);
    assert_eq!(a.sim_links, b.sim_links);
    // The whole point of the testbed: the impaired schedule actually
    // produced stragglers, deterministically.
    assert!(a.stale_uplinks > 0, "lossy-wan quorum run produced no stragglers");
    assert!(total_drops(&a.sim_links) > 0);

    let c = train(&lossy_quorum_cfg(8)).unwrap();
    assert_ne!(
        total_delay(&a.sim_links),
        total_delay(&c.sim_links),
        "different sim seeds drew identical schedules"
    );
}
